"""KV caches: vanilla, masked-DMS (reference), and slot-compacted DMS (production).

Two DMS cache implementations with identical attention semantics:

* :class:`MaskedDMSCache` — logical cache of the full sequence length with a
  ``retained`` bitmap.  Simple, used as the correctness oracle.
* :class:`SlotDMSCache` — *physically compacted* cache with ``P << S`` slots,
  a free-list ring allocator, and a pending-eviction ring implementing the
  paper's **delayed eviction** (§3.3): the decision made at step *t* frees the
  slot at step *t + w*.  Evicted slots are overwritten by incoming tokens, so
  DMS adds no KV read/write traffic.  Keys are stored post-RoPE ("with
  positional information", §3.3).

All caches are registered pytrees and fully functional (update returns a new
cache), so they pass through ``jax.jit`` / ``lax.scan`` / pjit unscathed.

Layout: ``k, v``: (B, Hkv, P, Dh); per-slot metadata (B, Hkv, P); ``length``
is **per lane** (B,) — batch rows are independent *lanes* that may sit at
different sequence positions (continuous batching: staggered admission,
chunked prefill, EOS early-exit all advance lanes independently).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import block_pool

INVALID_POS = jnp.iinfo(jnp.int32).max


class LaneSliceable:
    """Per-lane snapshot/restore for lane-leading cache pytrees.

    Every cache in this repo stores *all* of its per-lane state in array
    leaves whose lane (batch) axis is leading (or at ``axis`` when the cache
    is stacked over superblocks), so one lane's complete state at a token
    boundary — arena contents, free lists, pending eviction rings, score
    accumulators, page metadata — is exactly the width-1 slice of every leaf.
    That is the invariant the cross-request prefix cache relies on: a slice
    taken after prefilling L tokens, written back into a pristine lane,
    continues bitwise-identically to a cold prefill of those L tokens.

    Mixed into every cache class (``kv_cache`` / ``baselines`` /
    ``keyformer``); a cache with non-lane-leading state must override both
    methods together (the same override point as ``KVPolicy.fork_cache``).
    """

    def export_lane(self, lane, *, axis: int = 0):
        """Width-1 slice of lane ``lane`` (traced int32 ok) of every leaf."""
        return jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, lane, 1, axis=axis),
            self)

    def import_lane(self, snap, lane, *, axis: int = 0):
        """Write a width-1 snapshot back into lane ``lane`` of every leaf."""
        return jax.tree_util.tree_map(
            lambda a, s: jax.lax.dynamic_update_slice_in_dim(
                a, s.astype(a.dtype), lane, axis=axis),
            self, snap)


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m if m else x


def _tree_dataclass(cls):
    """Dataclass + pytree registration; fields with metadata {'static': True}
    go into aux_data (hashable, not traced).  Children are keyed by field name
    so sharding rules can match on tree paths."""
    cls = dataclass(cls)
    child_names = [f.name for f in dataclasses.fields(cls) if not f.metadata.get("static")]
    static_names = [f.name for f in dataclasses.fields(cls) if f.metadata.get("static")]

    def flatten_with_keys(o):
        return (
            [(jax.tree_util.GetAttrKey(n), getattr(o, n)) for n in child_names],
            tuple(getattr(o, n) for n in static_names),
        )

    def flatten(o):
        return (
            tuple(getattr(o, n) for n in child_names),
            tuple(getattr(o, n) for n in static_names),
        )

    def unflatten(aux, children):
        kw = dict(zip(child_names, children))
        kw.update(zip(static_names, aux))
        return cls(**kw)

    jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys, unflatten,
                                            flatten_func=flatten)
    return cls


# ---------------------------------------------------------------------------
# Block tables: compacted live-block indices for the flash-decode kernel
# ---------------------------------------------------------------------------


@_tree_dataclass
class BlockTable:
    """Per-(lane, kv-head) compacted index table of *live* KV blocks.

    The flash-decode kernel grids over this table instead of the raw arena:
    its scalar-prefetched entries drive the K/V block index maps, so blocks
    with zero live slots are never DMA'd — decode HBM traffic scales with
    live tokens, not arena capacity (see docs/kernels.md).

    Maintained **incrementally**: :meth:`insert` / :meth:`evict` are O(NB)
    vector ops fired once per cache mutation (a slot turning live/dead), not
    a per-step O(P) reduction over the arena.  The table is an unordered
    compacted list — eviction swaps the last entry into the hole — which is
    fine because flash attention is order-invariant.  Invariant (pinned by
    ``tests/test_block_tables.py``): ``{tbl[..., :n]}`` equals the set of
    blocks with at least one live slot, and ``count`` equals the per-block
    live-slot population of the arena's ``valid`` bitmap.

    ``block_p == 0`` disables the machinery entirely (zero-width arrays, all
    updates no-ops): the legacy dense-streaming configuration.
    """

    count: jnp.ndarray   # (B, H, NB) int32 — live slots per block
    tbl: jnp.ndarray     # (B, H, NB) int32 — live block ids, first n entries
    pos: jnp.ndarray     # (B, H, NB) int32 — block id -> index in tbl, or -1
    n: jnp.ndarray       # (B, H) int32 — number of live blocks
    block_p: int = dataclasses.field(metadata={"static": True}, default=0)

    @staticmethod
    def init(batch: int, kv_heads: int, num_slots: int, block_p: int
             ) -> "BlockTable":
        nb = num_slots // block_p if block_p else 0
        z = jnp.zeros((batch, kv_heads, nb), jnp.int32)
        return BlockTable(count=z, tbl=z,
                          pos=jnp.full((batch, kv_heads, nb), -1, jnp.int32),
                          n=jnp.zeros((batch, kv_heads), jnp.int32),
                          block_p=block_p)

    def spec(self):
        """The ``(block_tbl, block_n, block_p)`` triple an ``AttendSpec``
        carries to the kernel; ``(None, None, 0)`` when tables are off."""
        if not self.block_p:
            return None, None, 0
        return self.tbl, self.n, self.block_p

    @staticmethod
    def from_valid(valid: jnp.ndarray, block_p: int) -> "BlockTable":
        """Recompute the canonical table from a ``valid`` bitmap (one O(P)
        pass — prefill import and the test oracle, never the step path).
        Canonical order: live block ids ascending."""
        b, h, p = valid.shape
        if not block_p:
            return BlockTable.init(b, h, 0, 0)
        nb = p // block_p
        count = jnp.sum(valid.reshape(b, h, nb, block_p), axis=-1
                        ).astype(jnp.int32)
        live = count > 0
        tbl = jnp.argsort(~live, axis=-1, stable=True).astype(jnp.int32)
        rank = jnp.cumsum(live, axis=-1).astype(jnp.int32) - 1
        return BlockTable(count=count, tbl=tbl,
                          pos=jnp.where(live, rank, -1),
                          n=jnp.sum(live, axis=-1).astype(jnp.int32),
                          block_p=block_p)

    # -- O(NB) scatter helpers (one-hot writes, shapes fixed) ---------------

    @staticmethod
    def _take(arr, idx):
        return jnp.take_along_axis(arr, idx[..., None], axis=2)[..., 0]

    @staticmethod
    def _put(arr, idx, val, mask):
        nb = arr.shape[2]
        hit = (jnp.arange(nb)[None, None] == idx[..., None]) & mask[..., None]
        if hasattr(val, "ndim") and val.ndim == 2:
            val = val[..., None]
        return jnp.where(hit, val, arr)

    def insert(self, slot: jnp.ndarray, mask: jnp.ndarray) -> "BlockTable":
        """A slot turned live.  ``slot``/``mask``: (B, H); where ``mask`` is
        False nothing happened this step (no-op lanes/heads)."""
        return self.insert_ex(slot, mask)[0]

    def insert_ex(self, slot: jnp.ndarray, mask: jnp.ndarray
                  ) -> Tuple["BlockTable", jnp.ndarray]:
        """:meth:`insert` plus the per-(lane, head) *block turned live* event
        mask — the paged pool's page-allocation trigger."""
        if not self.block_p or self.count.shape[2] == 0:
            return self, jnp.zeros_like(mask)
        nb = self.count.shape[2]
        blk = jnp.clip(slot // self.block_p, 0, nb - 1)
        new_live = mask & (self._take(self.count, blk) == 0)
        count = self._put(self.count, blk, self._take(self.count, blk) + 1,
                          mask)
        tbl = self._put(self.tbl, jnp.minimum(self.n, nb - 1), blk, new_live)
        pos = self._put(self.pos, blk, self.n, new_live)
        return dataclasses.replace(self, count=count, tbl=tbl, pos=pos,
                                   n=self.n + new_live.astype(jnp.int32)), \
            new_live

    def evict(self, slot: jnp.ndarray, mask: jnp.ndarray) -> "BlockTable":
        """A slot turned dead.  When its block's population hits zero the
        block leaves the table: the last table entry swaps into its place."""
        return self.evict_ex(slot, mask)[0]

    def evict_ex(self, slot: jnp.ndarray, mask: jnp.ndarray
                 ) -> Tuple["BlockTable", jnp.ndarray]:
        """:meth:`evict` plus the per-(lane, head) *block turned dead* event
        mask — the paged pool's page-free trigger."""
        if not self.block_p or self.count.shape[2] == 0:
            return self, jnp.zeros_like(mask)
        nb = self.count.shape[2]
        blk = jnp.clip(slot // self.block_p, 0, nb - 1)
        cnt_after = self._take(self.count, blk) - 1
        count = self._put(self.count, blk, cnt_after, mask)
        dead = mask & (cnt_after == 0)
        hole = self._take(self.pos, blk)                       # index in tbl
        hole = jnp.clip(hole, 0, nb - 1)
        last_i = jnp.clip(self.n - 1, 0, nb - 1)
        last_blk = self._take(self.tbl, last_i)
        tbl = self._put(self.tbl, hole, last_blk, dead)
        pos = self._put(self.pos, last_blk, hole, dead)
        pos = self._put(pos, blk, -1, dead)    # after: blk==last_blk -> -1
        return dataclasses.replace(self, count=count, tbl=tbl, pos=pos,
                                   n=self.n - dead.astype(jnp.int32)), dead


class HasBlockTable:
    """Mixin for caches whose ``blocks`` field is an incrementally-maintained
    :class:`BlockTable`: exposes the uniform ``block_spec()`` the policy
    layer reads (see ``repro.core.policy._attend_spec``)."""

    def block_spec(self):
        return self.blocks.spec()


def prefix_block_spec(length: jnp.ndarray, num_slots: int, block_p: int,
                      kv_heads: int):
    """Derived block table for prefix-shaped occupancy (vanilla/DMC): live
    slots are exactly ``[0, length)`` per lane, so the table is just the
    first ``ceil(length / block_p)`` block ids — O(NB) from a scalar, no
    stored state.  Returns ``(tbl (B,H,NB) int32, n (B,H) int32)`` or
    ``(None, None)`` when tables are disabled."""
    if not block_p:
        return None, None
    nb = num_slots // block_p
    b = length.shape[0]
    length = length.reshape(b, -1)                      # (B,1) or (B,H)
    n = jnp.broadcast_to(-(-jnp.minimum(length, num_slots) // block_p),
                         (b, kv_heads)).astype(jnp.int32)
    tbl = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.int32)[None, None],
                           (b, kv_heads, nb))
    return tbl, n


# ---------------------------------------------------------------------------
# Paged-pool plumbing shared by every cache class
# ---------------------------------------------------------------------------
#
# In paged mode a cache's dense ``k``/``v`` arenas are allocated with a
# ZERO-width head axis (B, H, P, 0): every shape-derived invariant (valid
# masks, positions, LaneSliceable, block specs) keeps working, the in-place
# arena writes become free no-ops, and the actual bytes live in the shared
# :class:`~repro.core.block_pool.BlockPool` addressed through ``phys``.


def init_paged(batch: int, kv_heads: int, padded_slots: int, head_dim: int,
               block_p: int, dtype, pool_blocks: Optional[int]):
    """(pool, phys, zero-width arena) for a paged cache; validates block_p."""
    if not block_p:
        raise ValueError("paged KV cache requires block_p > 0")
    nb = padded_slots // block_p
    pool = block_pool.BlockPool.init(
        pool_blocks or batch * kv_heads * nb, block_p, head_dim, dtype)
    phys = jnp.full((batch, kv_heads, nb), -1, jnp.int32)
    return pool, phys, jnp.zeros((batch, kv_heads, padded_slots, 0), dtype)


def event_mask(active, shape) -> jnp.ndarray:
    """Broadcast the scheduler's per-lane ``active`` mask (B,) over event
    shape (B, H[, T]); None = all lanes live.  Pool mutations MUST be gated
    on this: the pool is shared state that ``lane_select`` cannot roll back,
    so inactive lanes may not allocate, free, or write pages."""
    if active is None:
        return jnp.ones(shape, bool)
    return jnp.broadcast_to(active.reshape((-1,) + (1,) * (len(shape) - 1)),
                            shape)


def cache_block_p(cache) -> int:
    """Kernel block granularity of any cache class (stored field, incremental
    table, or Quest's page size)."""
    bp = getattr(cache, "block_p", None)
    if bp is None and hasattr(cache, "blocks"):
        bp = cache.blocks.block_p
    if bp is None:
        bp = getattr(cache, "page_size", 0)
    return bp


def pack_dense(cache, pool_blocks: Optional[int] = None):
    """Convert a fixed-arena cache into its pooled twin (prefill import).

    Pages are allocated for every block holding at least one live slot and
    the dense arena content is copied page-by-page; dead blocks simply don't
    exist.  The result is bitwise-equivalent under attention (garbage in
    unmapped blocks is masked in both layouts)."""
    bp = cache_block_p(cache)
    b, h, p, dh = cache.k.shape
    if not bp:
        raise ValueError("pack_dense requires block_p > 0")
    nb = p // bp
    pool = block_pool.BlockPool.init(pool_blocks or b * h * nb, bp, dh,
                                     cache.k.dtype)
    valid = jnp.broadcast_to(cache.valid_mask(), (b, h, p))
    need = jnp.any(valid.reshape(b, h, nb, bp), axis=-1).reshape(-1)
    pool, page, ok = block_pool.alloc(pool, need)
    phys = jnp.where(need & ok, page, -1).reshape(b, h, nb)
    dst = jnp.where(need & ok, page, pool.num_blocks)
    pool = dataclasses.replace(
        pool,
        k=pool.k.at[dst].set(cache.k.reshape(b * h * nb, bp, dh),
                             mode="drop"),
        v=pool.v.at[dst].set(cache.v.reshape(b * h * nb, bp, dh),
                             mode="drop"))
    return dataclasses.replace(cache, k=cache.k[..., :0], v=cache.v[..., :0],
                               pool=pool, phys=phys)


# ---------------------------------------------------------------------------
# Vanilla (dense, append-only) cache
# ---------------------------------------------------------------------------


@_tree_dataclass
class VanillaCache(LaneSliceable):
    k: jnp.ndarray      # (B, Hkv, S, Dh) — S padded to a block_p multiple
    v: jnp.ndarray
    length: jnp.ndarray  # (B,) int32 — tokens written, per lane
    # kernel block granularity; 0 = no block tables (exact legacy arena).
    # Occupancy is a length-prefix, so the live-block table is *derived*
    # (prefix_block_spec) rather than stored.
    block_p: int = dataclasses.field(metadata={"static": True}, default=0)
    # paged mode: shared page arena + per-(lane, head) page map; the dense
    # k/v above are zero-width placeholders (see init_paged)
    pool: Optional[block_pool.BlockPool] = None
    phys: Optional[jnp.ndarray] = None       # (B, H, NB) int32, -1 = unmapped

    @staticmethod
    def init(batch: int, kv_heads: int, max_len: int, head_dim: int,
             dtype=jnp.bfloat16, block_p: int = 0, paged: bool = False,
             pool_blocks: Optional[int] = None):
        pool = phys = None
        if paged:
            pool, phys, z = init_paged(batch, kv_heads,
                                       _round_up(max_len, block_p), head_dim,
                                       block_p, dtype, pool_blocks)
        else:
            z = jnp.zeros(
                (batch, kv_heads, _round_up(max_len, block_p), head_dim),
                dtype)
        return VanillaCache(z, z, jnp.zeros((batch,), jnp.int32),
                            block_p=block_p, pool=pool, phys=phys)

    def block_spec(self):
        tbl, n = prefix_block_spec(self.length, self.k.shape[2], self.block_p,
                                   self.k.shape[1])
        return tbl, n, self.block_p

    def append(self, k_new: jnp.ndarray, v_new: jnp.ndarray,
               active=None) -> "VanillaCache":
        """k_new, v_new: (B, Hkv, T_new, Dh) written at [length, length+T_new)
        of each lane (per-lane offsets: a vmapped dynamic-slice scatter)."""
        t_new = k_new.shape[2]
        if self.pool is not None:
            b, h = self.k.shape[:2]
            slot = jnp.broadcast_to(
                self.length[:, None, None] + jnp.arange(t_new)[None, None],
                (b, h, t_new))
            pool, phys = block_pool.token_write(
                self.pool, self.phys, slot, k_new, v_new,
                event_mask(active, (b, h, t_new)))
            return dataclasses.replace(self, pool=pool, phys=phys,
                                       length=self.length + t_new)

        def upd(buf, new, off):
            return jax.lax.dynamic_update_slice_in_dim(buf, new, off, axis=1)

        k = jax.vmap(upd)(self.k, k_new.astype(self.k.dtype), self.length)
        v = jax.vmap(upd)(self.v, v_new.astype(self.v.dtype), self.length)
        return dataclasses.replace(self, k=k, v=v, length=self.length + t_new)

    def valid_mask(self) -> jnp.ndarray:
        # lazy (B, 1, S): broadcast happens inside the consumer's `where`
        s = self.k.shape[2]
        return jnp.arange(s)[None, None, :] < self.length[:, None, None]

    def positions(self) -> jnp.ndarray:
        s = self.k.shape[2]
        return jnp.arange(s, dtype=jnp.int32)[None, None, :]

    def retained_tokens(self) -> jnp.ndarray:
        b, h = self.k.shape[:2]
        return jnp.broadcast_to(self.length[:, None], (b, h))


# ---------------------------------------------------------------------------
# Masked DMS cache (reference semantics)
# ---------------------------------------------------------------------------


@_tree_dataclass
class MaskedDMSCache(LaneSliceable, HasBlockTable):
    k: jnp.ndarray          # (B, Hkv, S, Dh) — S padded to a block_p multiple
    v: jnp.ndarray
    retained: jnp.ndarray   # (B, Hkv, S) bool — False once evicted
    alpha: jnp.ndarray      # (B, Hkv, S) bool — recorded eviction decisions
    length: jnp.ndarray     # (B,) int32 — per lane
    blocks: BlockTable      # incremental live-block table (flash-decode)
    window: int = dataclasses.field(metadata={"static": True})
    pool: Optional[block_pool.BlockPool] = None
    phys: Optional[jnp.ndarray] = None       # (B, H, NB) int32, -1 = unmapped

    @staticmethod
    def init(batch: int, kv_heads: int, max_len: int, head_dim: int,
             window: int, dtype=jnp.bfloat16, block_p: int = 0,
             paged: bool = False, pool_blocks: Optional[int] = None):
        s = _round_up(max_len, block_p)
        pool = phys = None
        if paged:
            pool, phys, z = init_paged(batch, kv_heads, s, head_dim, block_p,
                                       dtype, pool_blocks)
        else:
            z = jnp.zeros((batch, kv_heads, s, head_dim), dtype)
        f = jnp.zeros((batch, kv_heads, s), bool)
        return MaskedDMSCache(z, z, f, f, jnp.zeros((batch,), jnp.int32),
                              BlockTable.init(batch, kv_heads, s, block_p),
                              window, pool=pool, phys=phys)

    def step(self, k_new, v_new, alpha_new, active=None) -> "MaskedDMSCache":
        """Append ONE token per head; execute the eviction scheduled w steps ago.

        k_new/v_new: (B, Hkv, 1, Dh); alpha_new: (B, Hkv) bool.
        """
        t = self.length                                     # (B,)
        s = self.k.shape[2]
        idx = jnp.arange(s)
        at_t = idx[None, None, :] == t[:, None, None]       # (B, 1, S)
        if self.pool is None:
            k = jnp.where(at_t[..., None], k_new.astype(self.k.dtype), self.k)
            v = jnp.where(at_t[..., None], v_new.astype(self.v.dtype), self.v)
        else:
            k, v = self.k, self.v       # zero-width placeholders; bytes go
            #                             to the pool below
        retained = jnp.where(at_t, True, self.retained)
        alpha = jnp.where(at_t, alpha_new[..., None], self.alpha)
        # execute eviction of token t - w (if it was marked)
        j = t - self.window                                 # (B,)
        evict_now = (idx[None, None, :] == j[:, None, None]) & alpha \
            & (j >= 0)[:, None, None]
        retained = retained & ~evict_now
        b, h = self.retained.shape[:2]
        ins = jnp.broadcast_to((t < s)[:, None], (b, h))
        blocks = self.blocks.insert(
            jnp.broadcast_to(t[:, None], (b, h)), ins)
        blocks, dead = blocks.evict_ex(
            jnp.broadcast_to(j[:, None], (b, h)),
            jnp.any(evict_now, axis=2))
        pool, phys = self.pool, self.phys
        if pool is not None:
            act = event_mask(active, (b, h))
            pool, phys = block_pool.token_write(
                pool, phys,
                jnp.broadcast_to(t[:, None, None], (b, h, 1)),
                k_new, v_new, (ins & act)[..., None])
            pool, phys = block_pool.free_block(
                pool, phys,
                jnp.broadcast_to(jnp.clip(j, 0, s - 1)[:, None], (b, h)),
                dead & act)
        return dataclasses.replace(self, k=k, v=v, retained=retained,
                                   alpha=alpha, length=t + 1, blocks=blocks,
                                   pool=pool, phys=phys)

    def valid_mask(self) -> jnp.ndarray:
        s = self.k.shape[2]
        written = jnp.arange(s)[None, None, :] < self.length[:, None, None]
        return self.retained & written

    def positions(self) -> jnp.ndarray:
        s = self.k.shape[2]
        pos = jnp.arange(s, dtype=jnp.int32)
        return jnp.broadcast_to(pos[None, None], self.k.shape[:2] + (s,))

    def retained_tokens(self) -> jnp.ndarray:
        return jnp.sum(self.valid_mask(), axis=-1)


# ---------------------------------------------------------------------------
# Slot-compacted DMS cache (production)
# ---------------------------------------------------------------------------


@_tree_dataclass
class SlotDMSCache(LaneSliceable, HasBlockTable):
    """Physically compacted cache: P slots per (batch, kv head).

    Allocation uses a ring free-list; the pending ring holds the last ``w``
    (slot, α) pairs so that decisions execute exactly ``w`` steps late.
    If the arena overflows (model under-evicts vs. provisioned CR) the
    allocator evicts the oldest *marked-for-eviction* slot early; as a last
    resort it recycles the oldest slot (StreamingLLM-style safety valve) and
    flags ``overflowed`` for observability.
    """

    k: jnp.ndarray            # (B, H, P, Dh) — post-RoPE keys; P padded to
    #                           a block_p multiple, slots >= `slots` are
    #                           physical padding (never allocated)
    v: jnp.ndarray            # (B, H, P, Dh)
    pos: jnp.ndarray          # (B, H, P) int32 — logical position; INVALID_POS = empty
    valid: jnp.ndarray        # (B, H, P) bool
    free_ring: jnp.ndarray    # (B, H, P) int32 — circular buffer of free slot ids
    free_head: jnp.ndarray    # (B, H) int32 — index of next free slot in ring
    free_count: jnp.ndarray   # (B, H) int32
    pending_slot: jnp.ndarray   # (B, H, w) int32
    pending_alpha: jnp.ndarray  # (B, H, w) bool
    length: jnp.ndarray       # (B,) int32 — logical tokens written, per lane
    overflowed: jnp.ndarray   # (B, H) bool
    blocks: BlockTable        # incremental live-block table (flash-decode)
    window: int = dataclasses.field(metadata={"static": True})
    #: logical arena capacity — overflow/window semantics key off this, NOT
    #: the (padded) physical extent of ``k``
    slots: int = dataclasses.field(metadata={"static": True})
    # False = plain ring-buffer use (local-attention window cache): eviction
    # decisions are never predicted, overflow recycling does the windowing
    dms_active: bool = dataclasses.field(metadata={"static": True}, default=True)
    pool: Optional[block_pool.BlockPool] = None
    phys: Optional[jnp.ndarray] = None       # (B, H, NB) int32, -1 = unmapped

    @staticmethod
    def init(batch: int, kv_heads: int, num_slots: int, head_dim: int,
             window: int, dtype=jnp.bfloat16, dms_active: bool = True,
             block_p: int = 0, paged: bool = False,
             pool_blocks: Optional[int] = None):
        p = _round_up(num_slots, block_p)
        pool = phys = None
        if paged:
            pool, phys, z = init_paged(batch, kv_heads, p, head_dim, block_p,
                                       dtype, pool_blocks)
        else:
            z = jnp.zeros((batch, kv_heads, p, head_dim), dtype)
        return SlotDMSCache(
            k=z, v=z,
            pos=jnp.full((batch, kv_heads, p), INVALID_POS, jnp.int32),
            valid=jnp.zeros((batch, kv_heads, p), bool),
            # ring contents are always *logical* slot ids; capacity is the
            # physical extent but occupancy never exceeds `num_slots`
            free_ring=jnp.broadcast_to(
                jnp.arange(p, dtype=jnp.int32) % num_slots,
                (batch, kv_heads, p)).copy(),
            free_head=jnp.zeros((batch, kv_heads), jnp.int32),
            free_count=jnp.full((batch, kv_heads), num_slots, jnp.int32),
            pending_slot=jnp.full((batch, kv_heads, window), -1, jnp.int32),
            pending_alpha=jnp.zeros((batch, kv_heads, window), bool),
            length=jnp.zeros((batch,), jnp.int32),
            overflowed=jnp.zeros((batch, kv_heads), bool),
            blocks=BlockTable.init(batch, kv_heads, p, block_p),
            window=window,
            slots=num_slots,
            dms_active=dms_active,
            pool=pool,
            phys=phys,
        )

    @staticmethod
    def provision_slots(seq_len: int, cr: float, window: int) -> int:
        """P = ceil(S / CR) + w + slack — the arena size for a target CR."""
        return int(seq_len / cr) + window + 16

    # -- internals ----------------------------------------------------------

    def _execute_pending(self, active=None) -> "SlotDMSCache":
        """Execute the eviction decision made ``w`` steps ago (ring slot t mod w)."""
        t = self.length                                     # (B,)
        w = self.window
        b, h = self.valid.shape[:2]
        ring_idx = jnp.broadcast_to(jnp.mod(t, w)[:, None, None], (b, h, 1))
        slot = jnp.take_along_axis(self.pending_slot, ring_idx, axis=2)[..., 0]
        alpha = jnp.take_along_axis(self.pending_alpha, ring_idx, axis=2)[..., 0]
        do_evict = (t >= w)[:, None] & alpha & (slot >= 0)
        # still-valid guard (overflow may have recycled it already)
        slot_c = jnp.clip(slot, 0, self.valid.shape[2] - 1)
        was_valid = jnp.take_along_axis(self.valid, slot_c[..., None], axis=2)[..., 0]
        do_evict = do_evict & was_valid

        p_idx = jnp.arange(self.valid.shape[2])
        hit = (p_idx[None, None] == slot_c[..., None]) & do_evict[..., None]
        valid = self.valid & ~hit
        pos = jnp.where(hit, INVALID_POS, self.pos)
        # push freed slot onto the free ring
        tail = jnp.mod(self.free_head + self.free_count, self.free_ring.shape[2])
        free_ring = jnp.where(
            (p_idx[None, None] == tail[..., None]) & do_evict[..., None],
            slot_c[..., None], self.free_ring)
        free_count = self.free_count + do_evict.astype(jnp.int32)
        blocks, dead = self.blocks.evict_ex(slot_c, do_evict)
        pool, phys = self.pool, self.phys
        if pool is not None:
            pool, phys = block_pool.free_block(
                pool, phys, slot_c, dead & event_mask(active, (b, h)))
        return dataclasses.replace(
            self, valid=valid, pos=pos, free_ring=free_ring,
            free_count=free_count, blocks=blocks, pool=pool, phys=phys)

    def _allocate(self) -> Tuple["SlotDMSCache", jnp.ndarray]:
        """Pop a slot per (B, H).  Returns (cache, slot (B,H))."""
        p = self.free_ring.shape[2]
        have_free = self.free_count > 0
        head_slot = jnp.take_along_axis(self.free_ring, self.free_head[..., None], axis=2)[..., 0]
        # overflow path: recycle the oldest valid slot
        oldest_pos = jnp.where(self.valid, self.pos, INVALID_POS)
        oldest_slot = jnp.argmin(oldest_pos, axis=2).astype(jnp.int32)
        slot = jnp.where(have_free, head_slot, oldest_slot)
        free_head = jnp.where(have_free, jnp.mod(self.free_head + 1, p), self.free_head)
        free_count = jnp.where(have_free, self.free_count - 1, self.free_count)
        overflowed = self.overflowed | ~have_free
        cache = dataclasses.replace(
            self, free_head=free_head, free_count=free_count, overflowed=overflowed)
        return cache, slot

    # -- public API ----------------------------------------------------------

    def step(self, k_new, v_new, alpha_new, active=None) -> "SlotDMSCache":
        """Append one token per (batch, head); execute delayed evictions.

        k_new/v_new: (B, H, 1, Dh) post-RoPE; alpha_new: (B, H) bool.
        """
        cache = self._execute_pending(active)
        cache, slot = cache._allocate()
        t = cache.length                                                  # (B,)
        p_idx = jnp.arange(cache.valid.shape[2])
        hit = p_idx[None, None] == slot[..., None]                        # (B,H,P)
        # overflow recycling overwrites a still-live slot: only a dead->live
        # transition is a block-table insert event
        was_valid = jnp.take_along_axis(cache.valid, slot[..., None],
                                        axis=2)[..., 0]
        blocks = cache.blocks.insert(slot, ~was_valid)
        if cache.pool is None:
            k = jnp.where(hit[..., None], k_new.astype(cache.k.dtype), cache.k)
            v = jnp.where(hit[..., None], v_new.astype(cache.v.dtype), cache.v)
        else:
            k, v = cache.k, cache.v     # zero-width; bytes go to the pool
        pos = jnp.where(hit, t[:, None, None], cache.pos)
        valid = cache.valid | hit
        ring_idx = jnp.mod(t, cache.window)                               # (B,)
        w_idx = jnp.arange(cache.window)
        ring_hit = w_idx[None, None, :] == ring_idx[:, None, None]        # (B,1,w)
        pending_slot = jnp.where(ring_hit, slot[..., None], cache.pending_slot)
        pending_alpha = jnp.where(ring_hit, alpha_new[..., None], cache.pending_alpha)
        pool, phys = cache.pool, cache.phys
        if pool is not None:
            act = event_mask(active, slot.shape)
            pool, phys = block_pool.token_write(
                pool, phys, slot[..., None], k_new, v_new, act[..., None])
        return dataclasses.replace(
            cache, k=k, v=v, pos=pos, valid=valid,
            pending_slot=pending_slot, pending_alpha=pending_alpha,
            length=t + 1, blocks=blocks, pool=pool, phys=phys)

    def valid_mask(self) -> jnp.ndarray:
        return self.valid

    def positions(self) -> jnp.ndarray:
        return self.pos

    def retained_tokens(self) -> jnp.ndarray:
        return jnp.sum(self.valid, axis=-1)

    @staticmethod
    def from_prefill(k, v, positions, retained, window: int, num_slots: int,
                     alpha_bin: Optional[jnp.ndarray] = None,
                     block_p: int = 0) -> "SlotDMSCache":
        """Build a compacted cache from prefill outputs.

        k/v: (B, H, T, Dh) post-RoPE; retained: (B, H, T) bool;
        positions: (T,).  Retained tokens are packed into the first slots
        (stable order).  Tokens still inside the delay window whose α = 1 are
        entered into the pending ring so they get evicted on schedule.
        """
        b, h, t, d = k.shape
        p = _round_up(num_slots, block_p)
        # stable pack: order retained tokens by position
        order_key = jnp.where(retained, positions[None, None, :], INVALID_POS)
        order = jnp.argsort(order_key, axis=2)                      # (B,H,T) token idx by slot
        n_keep = jnp.minimum(jnp.sum(retained, axis=2), num_slots)  # (B,H)
        slot_ids = jnp.arange(p)

        def gather(x, fill):
            idx = order[..., :p] if t >= p else jnp.pad(order, ((0, 0), (0, 0), (0, p - t)))
            g = jnp.take_along_axis(x, idx[..., None] if x.ndim == 4 else idx, axis=2)
            live = slot_ids[None, None] < n_keep[..., None]
            if x.ndim == 4:
                return jnp.where(live[..., None], g, fill)
            return jnp.where(live, g, fill)

        kc = gather(k, jnp.zeros((), k.dtype))
        vc = gather(v, jnp.zeros((), v.dtype))
        pos_full = jnp.broadcast_to(positions[None, None, :], (b, h, t)).astype(jnp.int32)
        posc = gather(pos_full, INVALID_POS)
        valid = slot_ids[None, None] < n_keep[..., None]
        free_count = num_slots - n_keep
        # free ring: logical slots [n_keep, num_slots) are free
        free_ring = jnp.mod(n_keep[..., None] + slot_ids[None, None],
                            num_slots).astype(jnp.int32)
        cache = SlotDMSCache(
            k=kc, v=vc, pos=posc, valid=valid,
            free_ring=free_ring,
            free_head=jnp.zeros((b, h), jnp.int32),
            free_count=free_count.astype(jnp.int32),
            pending_slot=jnp.full((b, h, window), -1, jnp.int32),
            pending_alpha=jnp.zeros((b, h, window), bool),
            length=jnp.full((b,), t, jnp.int32),
            overflowed=jnp.zeros((b, h), bool),
            blocks=BlockTable.from_valid(valid, block_p),
            window=window,
            slots=num_slots,
        )
        if alpha_bin is not None:
            # tokens in (t-w, t] have un-executed decisions -> fill pending ring
            w = window
            tok = jnp.arange(t)
            in_window = tok > (t - 1 - w)
            # slot of token j = its rank among retained (all in-window tokens are retained)
            rank = jnp.cumsum(retained, axis=2) - 1                  # (B,H,T)
            ring_pos = jnp.mod(tok, w)
            pend_slot = jnp.full((b, h, w), -1, jnp.int32)
            pend_alpha = jnp.zeros((b, h, w), bool)
            idx = jnp.where(in_window, ring_pos, w)  # w = dumped
            pend_slot = pend_slot.at[..., :].set(
                jnp.zeros((b, h, w), jnp.int32) - 1)
            # scatter (padded with an extra dump column)
            ps = jnp.concatenate([pend_slot, jnp.zeros((b, h, 1), jnp.int32)], axis=2)
            pa = jnp.concatenate([pend_alpha, jnp.zeros((b, h, 1), bool)], axis=2)
            bi = jnp.arange(b)[:, None, None]
            hi = jnp.arange(h)[None, :, None]
            ps = ps.at[bi, hi, idx[None, None, :]].set(
                jnp.where(in_window[None, None, :], rank, -1).astype(jnp.int32))
            pa = pa.at[bi, hi, idx[None, None, :]].set(
                jnp.where(in_window[None, None, :], alpha_bin, False))
            cache = dataclasses.replace(cache, pending_slot=ps[..., :w], pending_alpha=pa[..., :w])
        return cache
