"""Configuration system for the repro framework.

Every architecture in the zoo is described by an :class:`ArchConfig` made of
composable sub-configs.  Configs are plain (frozen) dataclasses so they hash,
compare, and serialize trivially; everything static that affects tracing lives
here (jit-static argument).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# DMS (the paper's technique)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DMSConfig:
    """Dynamic Memory Sparsification (paper §3)."""

    enabled: bool = True
    window: int = 256              # eviction delay w (sliding window)
    target_cr: float = 8.0         # target compression ratio
    tau: float = 0.3               # Gumbel-sigmoid temperature
    logit_bias: float = -5.0       # b: offset so training starts with alpha ~ 0
    steps_per_cr_unit: int = 100   # CR(t) = 1 + t / steps_per_cr_unit
    immediate_eviction: bool = False   # ablation (Fig. 5): evict at t instead of t+w
    # "borrow" the first neuron of the first query head per group (App. B).
    # When False, use a dedicated parameter vector w (DMC-style).
    borrow_neuron: bool = True
    neuron_zeroing_steps: int = 2000   # phase-1 schedule n_t (App. B)


@dataclass(frozen=True)
class KVPolicyConfig:
    """Which KV-cache policy runs at inference time.

    ``kind`` names a policy registered in :mod:`repro.core.policy` ("vanilla",
    "dms", "dms_masked", "tova", "h2o", "quest", "dmc", "window",
    "keyformer", ...); the registry validates it at cache-init time, so new
    policies plug in without touching this config.

    ``layer_map`` optionally overrides the policy per *layer kind* — e.g.
    ``{"attn_local": "window", "attn": "dms"}`` runs gemma2-style hybrid
    caching (FastGen-like per-layer policies).  Stored as a sorted tuple of
    pairs so the config stays hashable (jit-static).
    """

    kind: str = "vanilla"
    # Common budget knob: max retained tokens (tova/h2o/window) or CR (dms/dmc/quest).
    budget: Optional[int] = None
    cr: float = 1.0
    window: int = 256            # dms delay / h2o recency window
    quest_page_size: int = 16
    quest_top_pages: Optional[int] = None
    keyformer_tau: float = 1.0   # Gumbel-softmax temperature (score smoothing)
    # KV-block granularity of the flash-decode kernel: caches allocate their
    # arenas pre-padded to a block_p multiple and maintain compacted
    # live-block index tables so decode streams only live blocks (HBM traffic
    # ∝ live tokens, not arena capacity — see docs/kernels.md).  0 disables
    # the tables (legacy dense streaming; direct cache construction defaults
    # to this so low-level unit tests keep exact arena shapes).
    block_p: int = 16
    # Paged KV block pool (see repro.core.block_pool): lanes allocate
    # block_p-sized pages from one shared per-cache arena on demand instead of
    # owning fixed worst-case arenas, and shared-prefill fork is copy-on-write
    # page sharing.  Requires block_p > 0.  pool_blocks sizes the shared arena
    # in pages per cache instance; None provisions full parity capacity
    # (num_lanes x kv_heads x blocks-per-lane), i.e. paged mode can never be
    # tighter than the fixed-arena layout unless a budget is set.
    paged: bool = False
    pool_blocks: Optional[int] = None
    layer_map: Optional[Tuple[Tuple[str, str], ...]] = None

    def __post_init__(self):
        if isinstance(self.layer_map, dict):
            object.__setattr__(self, "layer_map",
                               tuple(sorted(self.layer_map.items())))

    def kind_for_layer(self, layer_kind: str) -> str:
        """Resolve the policy name for a layer kind ("attn" / "attn_local")."""
        if self.layer_map:
            for k, v in self.layer_map:
                if k == layer_kind:
                    return v
        return self.kind


# ---------------------------------------------------------------------------
# Attention / MLP / MoE / SSM / recurrent blocks
# ---------------------------------------------------------------------------

RopeKind = Literal["none", "full", "half", "mrope"]


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope: RopeKind = "full"
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()      # qwen2-vl M-RoPE section split
    window: Optional[int] = None              # local (sliding window) attention
    logit_softcap: Optional[float] = None     # gemma2 attn softcap
    causal: bool = True                       # False for encoder self-attention
    qk_norm: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLPConfig:
    d_ff: int
    kind: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    moe: Optional[MoEConfig] = None


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 / SSD."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """Griffin / RecurrentGemma RG-LRU recurrent block."""

    lru_width: Optional[int] = None   # default: d_model
    conv_kernel: int = 4
    block_width_multiplier: float = 1.0


# ---------------------------------------------------------------------------
# Layer pattern
# ---------------------------------------------------------------------------

LayerKind = Literal["attn", "attn_local", "ssd", "rglru"]


@dataclass(frozen=True)
class ArchConfig:
    name: str
    num_layers: int
    d_model: int
    vocab_size: int
    attn: Optional[AttentionConfig]
    mlp: Optional[MLPConfig]
    # Layer pattern, cycled over num_layers.  E.g. gemma2 = ("attn_local","attn"),
    # recurrentgemma = ("rglru","rglru","attn_local"), mamba2 = ("ssd",).
    layer_pattern: Tuple[LayerKind, ...] = ("attn",)
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-6
    post_norm: bool = False                 # gemma2 uses pre+post block norms
    logit_softcap: Optional[float] = None   # final-logit softcap (gemma2)
    tie_embeddings: bool = False
    embedding_multiplier: float = 1.0       # gemma-style sqrt(d) input scaling
    # encoder-decoder (seamless): number of encoder layers, 0 = decoder-only
    encoder_layers: int = 0
    encoder_bidirectional: bool = True
    cross_attention: bool = False
    # modality frontend stub: "none" | "vision_patches" | "audio_frames"
    frontend: Literal["none", "vision_patches", "audio_frames"] = "none"
    frontend_tokens: int = 0        # number of stub embedding tokens prepended
    dms: DMSConfig = field(default_factory=lambda: DMSConfig(enabled=False))
    dtype: str = "bfloat16"
    # families for bookkeeping / skip rules
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    sub_quadratic: bool = False     # True => long_500k shape runs

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a lane/shard-friendly multiple (Megatron
        convention) so the vocab dim shards on any mesh; pad logits are masked
        to -inf in the loss/sampler."""
        return (self.vocab_size + 127) // 128 * 128

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_superblocks(self) -> int:
        assert self.num_layers % self.pattern_period == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {self.pattern_period}"
        )
        return self.num_layers // self.pattern_period

    def with_dms(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, dms=dataclasses.replace(self.dms, enabled=True, **kw))

    def scaled_down(
        self,
        num_layers: Optional[int] = None,
        d_model: Optional[int] = None,
        vocab_size: int = 512,
        d_ff: Optional[int] = None,
        num_experts: Optional[int] = None,
    ) -> "ArchConfig":
        """Reduced config of the same family, for CPU smoke tests."""
        period = self.pattern_period
        nl = num_layers if num_layers is not None else 2 * period
        nl = max(period, (nl // period) * period)
        dm = d_model if d_model is not None else 64
        new = dataclasses.replace(self, num_layers=nl, d_model=dm, vocab_size=vocab_size)
        if self.attn is not None:
            # keep GQA structure but shrink
            nkv = min(self.attn.num_kv_heads, 2)
            nq = max(nkv, (self.attn.num_heads * nkv) // self.attn.num_kv_heads)
            nq = min(nq, 4)
            nq = (nq // nkv) * nkv or nkv
            head_dim = max(8, dm // max(nq, 1))
            head_dim = 16 if head_dim >= 16 else 8
            window = self.attn.window
            if window is not None:
                window = min(window, 16)
            new = dataclasses.replace(
                new,
                attn=dataclasses.replace(
                    self.attn, num_heads=nq, num_kv_heads=nkv, head_dim=head_dim,
                    window=window,
                ),
            )
        if self.mlp is not None:
            moe = self.mlp.moe
            if moe is not None:
                ne = num_experts if num_experts is not None else min(moe.num_experts, 8)
                moe = dataclasses.replace(moe, num_experts=ne, top_k=min(moe.top_k, 2))
            new = dataclasses.replace(
                new, mlp=dataclasses.replace(self.mlp, d_ff=d_ff or 4 * dm, moe=moe)
            )
        if self.ssm is not None:
            new = dataclasses.replace(
                new, ssm=dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk_size=32)
            )
        if self.rglru is not None:
            new = dataclasses.replace(new, rglru=dataclasses.replace(self.rglru, lru_width=dm))
        if self.encoder_layers:
            new = dataclasses.replace(new, encoder_layers=period)
        if self.frontend_tokens:
            new = dataclasses.replace(new, frontend_tokens=4)
        if self.dms.enabled:
            new = dataclasses.replace(
                new, dms=dataclasses.replace(self.dms, window=min(self.dms.window, 8))
            )
        return new

    # -- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ---------------

    def param_count(self, active_only: bool = False) -> int:
        n = 0
        embed = self.vocab_size * self.d_model
        n += embed
        if not self.tie_embeddings:
            n += embed
        for kind in _expand_pattern(self.layer_pattern, self.num_layers):
            n += self._layer_params(kind, active_only)
        if self.encoder_layers:
            for kind in _expand_pattern(self.layer_pattern, self.encoder_layers):
                n += self._layer_params(kind, active_only)
            if self.cross_attention and self.attn is not None:
                a = self.attn
                per_cross = (
                    self.d_model * a.num_heads * a.head_dim * 2
                    + self.d_model * a.num_kv_heads * a.head_dim * 2
                )
                n += self.num_layers * per_cross   # one cross-attn per decoder layer
        return n

    def _layer_params(self, kind: str, active_only: bool) -> int:
        d = self.d_model
        n = 0
        if kind in ("attn", "attn_local"):
            a = self.attn
            n += d * a.num_heads * a.head_dim          # Wq
            n += 2 * d * a.num_kv_heads * a.head_dim   # Wk, Wv
            n += a.num_heads * a.head_dim * d          # Wo
            n += self._mlp_params(active_only)
        elif kind == "ssd":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.num_heads(d)
            # in_proj: z, x, B, C, dt
            n += d * (2 * di + 2 * s.n_groups * s.d_state + nh)
            n += di * s.conv_kernel                    # depthwise conv (x path)
            n += 2 * nh                                # A_log, D
            n += di * d                                # out_proj
        elif kind == "rglru":
            r = self.rglru
            w = r.lru_width or d
            n += 2 * d * w + w * d                     # in (x,y branches) + out
            n += w * r.conv_kernel
            n += 2 * w * w // 1          # input & recurrence gates (diag-block approx)
            n += self._mlp_params(active_only)
        return n

    def _mlp_params(self, active_only: bool) -> int:
        if self.mlp is None:
            return 0
        d, f = self.d_model, self.mlp.d_ff
        per_expert = (3 if self.mlp.kind in ("swiglu", "geglu") else 2) * d * f
        if self.mlp.moe is None:
            return per_expert
        moe = self.mlp.moe
        n_experts = moe.top_k if active_only else moe.num_experts
        return n_experts * per_expert + d * moe.num_experts  # + router


def _expand_pattern(pattern: Sequence[str], n: int) -> Sequence[str]:
    return [pattern[i % len(pattern)] for i in range(n)]


# ---------------------------------------------------------------------------
# Input shapes (the assigned shape grid)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPE_GRID: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES = {s.name: s for s in SHAPE_GRID}
