"""Unified, pluggable KV cache-policy API: the ``KVPolicy`` registry.

The paper's hyper-scaling results hinge on *which* compression policy runs
(DMS vs. training-free baselines vs. DMC), so the policy abstraction must be
a first-class, extensible contract rather than ``if policy.kind == ...``
chains smeared across the model and engine.  This module defines that
contract; every policy owns its full lifecycle:

* ``init_cache(arch, batch, max_len, cfg, layer_window, dtype)`` — provision
  the cache arena for one attention layer.
* ``decode_update(cache, q, k_new, v_new, aux) -> (cache, AttendSpec)`` —
  absorb one decoded token and describe what this step's attention should
  read (keys/values, visibility, positions, whether post-softmax weights are
  needed back).
* ``post_attend(cache, weights)`` — optional second phase for policies whose
  eviction depends on the current step's attention weights (TOVA, H2O,
  Keyformer).
* ``prefill_import(...)`` — build the cache from full-attention prefill
  outputs (e.g. :meth:`SlotDMSCache.from_prefill`), including un-executed
  delayed-eviction decisions.
* ``fork_cache(cache, width)`` / ``gather_cache(cache, src)`` — the
  shared-prefill fork: prefill a prompt once, clone the cache pytree into W
  hyper-scaling chains instead of re-prefilling W times (``fork_cache``
  widens the batch; ``gather_cache`` is the in-place lane shuffle the
  scheduler uses inside its fixed lane arena).
* ``reclaim_cache(cache, reset_mask, fresh)`` — per-lane arena reset: lanes
  where ``reset_mask`` is True return to the pristine ``fresh`` state (EOS
  early-exit frees a lane's slots for the next admitted request).
* ``export_prefix(cache, lane)`` / ``import_prefix(cache, snap, lane)`` — the
  cross-request prefix lifecycle: snapshot one lane's complete state at a
  token boundary (everything needed to continue decoding, including pending
  eviction rings and score accumulators) and restore it into a pristine lane
  later, so even compressed/evicting caches can reuse a shared prompt prefix
  across requests (see :mod:`repro.serving.prefix_cache`).
* ``metrics(cache)`` — the paper's two budget axes, policy-defined instead of
  engine-guessed: ``live_tokens`` (peak-memory axis), ``reads_tokens``
  (KV-reads axis; differs from live for Quest) and ``peak_bytes`` (physical
  arena bytes, static).

Policies register by name with :func:`register_policy`; the model/engine
dispatch purely through the registry via the :class:`PolicyCache` pytree
wrapper, whose ``policy`` name rides in static (hashable) aux data — so
``jax.jit`` re-traces per policy but the *code* is policy-agnostic.  Adding a
new policy (see :mod:`repro.core.keyformer`) requires zero edits to
``models/`` or ``serving/``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dms as dms_lib
from repro.core.baselines import DMCCache, H2OCache, QuestCache, TOVACache
from repro.core.config import ArchConfig, KVPolicyConfig
from repro.core.kv_cache import (MaskedDMSCache, SlotDMSCache, VanillaCache,
                                 _tree_dataclass)


# ---------------------------------------------------------------------------
# wire types
# ---------------------------------------------------------------------------


@dataclass
class AttendSpec:
    """What one decode step's attention should read.

    ``k``/``v``: (B, Hkv, P, Dh); ``visible``: (B, Hkv, P) bool (broadcastable);
    ``positions``: per-slot logical positions for local-window masking, or
    ``None`` when positions are meaningless (merged DMC entries).
    ``needs_weights`` requests the group-summed post-softmax weights back via
    :meth:`KVPolicy.post_attend`.

    ``block_tbl``/``block_n``/``block_p`` are the **block-table contract**
    with the flash-decode kernel (docs/kernels.md): ``block_tbl`` (B, Hkv,
    NB) int32 lists the arena's live ``block_p``-sized K/V blocks per (lane,
    kv head), compacted into the first ``block_n`` (B, Hkv) entries.  The
    kernel's scalar-prefetched index maps stream exactly those blocks, so
    decode HBM traffic scales with live tokens instead of arena capacity.
    Every *visible* slot must be covered by a listed block (a listed block
    may still contain dead slots — the kernel masks those via ``visible``);
    ``block_p == 0`` means "no table" and the kernel falls back to streaming
    the whole arena.  When ``block_p > 0`` the arena extent P must be a
    ``block_p`` multiple (caches allocate pre-padded; see
    ``KVPolicyConfig.block_p``).
    """

    k: jnp.ndarray
    v: jnp.ndarray
    visible: jnp.ndarray
    positions: Optional[jnp.ndarray] = None
    needs_weights: bool = False
    block_tbl: Optional[jnp.ndarray] = None
    block_n: Optional[jnp.ndarray] = None
    block_p: int = 0


@_tree_dataclass
class PolicyCache:
    """Pytree wrapper binding a cache state to its policy *by name*.

    The name lives in static aux data, so dispatch inside jitted code is a
    trace-time registry lookup — no isinstance chains, and the cache pytree
    stays an opaque, shardable container for the engine.
    """

    cache: Any
    policy: str = dataclasses.field(metadata={"static": True}, default="vanilla")

    @property
    def length(self) -> jnp.ndarray:
        return self.cache.length


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, "KVPolicy"] = {}


def register_policy(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate and register a :class:`KVPolicy` by name."""

    def deco(cls: type) -> type:
        if name in _REGISTRY:
            raise ValueError(
                f"KV policy {name!r} already registered "
                f"(by {type(_REGISTRY[name]).__name__})")
        pol = cls()
        pol.name = name
        _REGISTRY[name] = pol
        return cls

    return deco


def get_policy(name: str) -> "KVPolicy":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown KV policy {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def init_policy_cache(arch: ArchConfig, batch: int, max_len: int,
                      cfg: KVPolicyConfig, *, layer_kind: str = "attn",
                      layer_window: Optional[int] = None,
                      dtype=None) -> PolicyCache:
    """Provision one attention layer's cache through the registry."""
    name = cfg.kind_for_layer(layer_kind)
    pol = get_policy(name)
    dtype = dtype or jnp.dtype(arch.dtype)
    inner = pol.init_cache(arch, batch, max_len, cfg,
                           layer_window=layer_window, dtype=dtype)
    return PolicyCache(cache=inner, policy=name)


def iter_policy_caches(tree: Any) -> Iterator[PolicyCache]:
    """Yield every :class:`PolicyCache` node in a decode-state pytree."""
    leaves = jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, PolicyCache))
    for leaf in leaves:
        if isinstance(leaf, PolicyCache):
            yield leaf


def state_peak_bytes(state: Any) -> int:
    """Physical KV arena bytes of a decode state (uniform metrics contract).

    Works on both per-layer caches and the stacked (superblock-leading)
    decode state — ``peak_bytes`` is purely shape-derived.
    """
    return sum(get_policy(pc.policy).peak_bytes(pc.cache)
               for pc in iter_policy_caches(state))


def _nbytes(a) -> int:
    n = 1
    for s in a.shape:
        n *= int(s)
    return n * jnp.dtype(a.dtype).itemsize


def _budget_tokens(cfg: KVPolicyConfig, max_len: int) -> int:
    return cfg.budget or max(int(max_len / cfg.cr), 1)


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


class KVPolicy:
    """Base contract.  Subclass, implement the lifecycle, decorate with
    ``@register_policy("name")`` — the model/engine pick it up untouched."""

    name: str = ""
    #: "none" — policy never sees eviction decisions;
    #: "dms"  — extract binarised DMS α when ``arch.dms.enabled``;
    #: "always" — extract α from the borrowed neuron unconditionally (DMC).
    alpha_mode: str = "none"

    # -- lifecycle -----------------------------------------------------------

    def init_cache(self, arch: ArchConfig, batch: int, max_len: int,
                   cfg: KVPolicyConfig, *, layer_window: Optional[int],
                   dtype) -> Any:
        raise NotImplementedError

    def decode_update(self, cache: Any, q: jnp.ndarray, k_new: jnp.ndarray,
                      v_new: jnp.ndarray, aux: Dict[str, Any]
                      ) -> Tuple[Any, AttendSpec]:
        """q: (B, 1, Hq, Dh) post-RoPE; k_new/v_new: (B, Hkv, 1, Dh) post-RoPE.

        aux carries ``alpha_bin`` ((B, Hkv) bool or None), ``pos_t``,
        ``attn_cfg``, ``arch`` and ``dtype``.
        """
        raise NotImplementedError

    def post_attend(self, cache: Any, weights: jnp.ndarray) -> Any:
        """Second phase when ``AttendSpec.needs_weights``; ``weights`` is the
        group-summed post-softmax distribution (B, Hkv, P)."""
        return cache

    def prefill_import(self, arch: ArchConfig, cfg: KVPolicyConfig,
                       k: jnp.ndarray, v: jnp.ndarray,
                       positions: jnp.ndarray, retained: Optional[jnp.ndarray],
                       alpha_bin: Optional[jnp.ndarray], *, max_len: int,
                       layer_window: Optional[int] = None, dtype=None) -> Any:
        """Build a cache from full-attention prefill outputs (k/v:
        (B, Hkv, T, Dh) post-RoPE, e.g. ``make_prefill_step``'s ``layer_kv``).

        ``Engine`` currently teacher-forces prompts through the decode path
        (exact eviction semantics for every policy); this hook is for callers
        that run a dense prefill and import the result — policies without an
        import path raise."""
        raise NotImplementedError(f"{self.name}: no prefill import path")

    # -- lane lifecycle (continuous batching / hyperscale fork) --------------

    def fork_cache(self, cache: Any, width: int, *, axis: int = 0) -> Any:
        """Clone every lane of ``cache`` into ``width`` adjacent lanes.

        The shared-prefill fork: prefill once at batch B, fork to B·W chains
        — forked chains see bitwise-identical cache contents, so their first
        decode step matches W independent prefills while the prefill-phase
        KV reads drop by W×.  The default tiles the lane axis of every array
        leaf (all caches are lane-leading pytrees); policies with non-lane
        state override.  ``axis`` selects the lane axis (1 for decode states
        stacked over superblocks)."""
        return jax.tree_util.tree_map(
            lambda a: jnp.repeat(a, width, axis=axis), cache)

    def gather_cache(self, cache: Any, src: jnp.ndarray, *,
                     axis: int = 0) -> Any:
        """Lane shuffle: new lane ``l`` takes old lane ``src[l]``'s state —
        how the scheduler forks a prefilled lane into free lanes of a
        fixed-size arena (``src`` is the identity except forked targets).
        Same override point as :meth:`fork_cache` for policies whose state
        is not purely lane-leading."""
        return jax.tree_util.tree_map(
            lambda a: jnp.take(a, src, axis=axis), cache)

    # -- prefix lifecycle (cross-request radix prefix cache) -----------------

    def export_prefix(self, cache: Any, lane, *, axis: int = 0) -> Any:
        """Snapshot one lane's complete cache state at a token boundary.

        Returns a width-1-lane pytree of the same structure as ``cache``
        (static fields ride along), suitable for host storage in the
        cross-request prefix cache and later re-import.  The contract: for a
        lane that has consumed exactly the L prefix tokens, the snapshot holds
        *everything* the policy needs to continue decoding — arena contents,
        free lists, pending eviction rings, score accumulators, page metadata
        — so ``import_prefix`` + suffix prefill is bitwise-equal to a cold
        prefill of the full prompt.  All built-in caches keep their per-lane
        state lane-leading (:class:`~repro.core.kv_cache.LaneSliceable`), so
        the default is a pure lane slice; policies with non-lane state must
        override both hooks together (same override point as
        :meth:`fork_cache`).  ``lane`` may be a traced int32 scalar."""
        return cache.export_lane(lane, axis=axis)

    def import_prefix(self, cache: Any, snap: Any, lane, *, axis: int = 0
                      ) -> Any:
        """Restore an :meth:`export_prefix` snapshot into lane ``lane``.

        The target lane must be pristine (just reclaimed/initialised); the
        snapshot overwrites every leaf's lane slice, so the lane continues
        exactly where the exporting request's prefill stood."""
        return cache.import_lane(snap, lane, axis=axis)

    def import_slab(self, slab: Any, snap: Any, slot, *, axis: int = 0
                    ) -> Any:
        """Device-side variant of :meth:`import_prefix` for the hot-tier
        snapshot slab: write a width-1 snapshot into storage slot ``slot``.

        The slab is *storage*, not a decode cache — it is ``slots`` stacked
        copies of whatever pytree :meth:`export_prefix` returns (see
        :func:`repro.models.transformer.init_snapshot_slab`), so the default
        is a pure ``dynamic_update_slice`` on the snapshot's own leaves.
        Runs jitted with both operands device-resident: a deferred export
        costs zero host↔device bytes.  A policy whose ``export_prefix``
        snapshot is not a width-1-lane pytree must override this pair
        alongside the prefix pair."""
        return jax.tree_util.tree_map(
            lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, axis=axis), slab, snap)

    def export_slab(self, slab: Any, slot, *, axis: int = 0) -> Any:
        """Device-side variant of :meth:`export_prefix`: fetch the snapshot
        stored in slab slot ``slot`` (the zero-copy hot-hit path — the
        result feeds :meth:`import_prefix` device-to-device)."""
        return jax.tree_util.tree_map(
            lambda d: jax.lax.dynamic_slice_in_dim(d, slot, 1, axis=axis),
            slab)

    def reclaim_cache(self, cache: Any, reset_mask: jnp.ndarray,
                      fresh: Any, *, axis: int = 0) -> Any:
        """Reset lanes where ``reset_mask`` (B,) is True to the pristine
        ``fresh`` cache: the EOS-reclamation hook.  A reclaimed lane's arena
        reads as empty (``live_tokens`` ≈ 0) and its free list is full, so
        the scheduler can admit the next request into it."""

        def sel(cur, init):
            m = reset_mask.reshape((1,) * axis + (-1,)
                                   + (1,) * (cur.ndim - axis - 1))
            return jnp.where(m, init, cur)

        return jax.tree_util.tree_map(sel, cache, fresh)

    # -- accounting ----------------------------------------------------------

    def metrics(self, cache: Any) -> Dict[str, Any]:
        """Budget accounting, policy-defined.  ``live_tokens``/``reads_tokens``
        are (B,) arrays (mean over kv heads); ``peak_bytes`` is a static int
        (physical arena size, valid under tracing as a constant)."""
        live = cache.retained_tokens().astype(jnp.float32).mean(axis=-1)
        return {"live_tokens": live, "reads_tokens": live,
                "peak_bytes": self.peak_bytes(cache)}

    def peak_bytes(self, cache: Any) -> int:
        return _nbytes(cache.k) + _nbytes(cache.v)


# ---------------------------------------------------------------------------
# built-in policies
# ---------------------------------------------------------------------------


def _attend_spec(cache, **kw) -> AttendSpec:
    """Uniform spec builder: attach the cache's live-block table when it
    maintains one (``block_spec`` is the cache-side half of the kernel's
    block-table contract — see docs/kernels.md)."""
    tbl, n, bp = cache.block_spec() if hasattr(cache, "block_spec") \
        else (None, None, 0)
    return AttendSpec(cache.k, cache.v, cache.valid_mask(), cache.positions(),
                      block_tbl=tbl, block_n=n, block_p=bp, **kw)


class _SlotRingMixin:
    """Shared decode path for slot-arena caches (dms / vanilla-local / window)."""

    @staticmethod
    def _slot_update(cache, k_new, v_new, aux):
        cfg = aux["attn_cfg"]
        b = k_new.shape[0]
        alpha = aux.get("alpha_bin")
        if alpha is None:
            alpha = jnp.zeros((b, cfg.num_kv_heads), bool)
        cache = cache.step(k_new, v_new, alpha)
        return cache, _attend_spec(cache)


@register_policy("vanilla")
class VanillaPolicy(_SlotRingMixin, KVPolicy):
    """Dense append-only cache; local-attention layers get a ring buffer
    (overflow recycling == sliding window) so memory stays O(window)."""

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        if layer_window is not None:
            eff_len = min(max_len, layer_window + 1)
            return SlotDMSCache.init(batch, a.num_kv_heads, eff_len, a.head_dim,
                                     max(arch.dms.window, 1), dtype,
                                     dms_active=False, block_p=cfg.block_p)
        return VanillaCache.init(batch, a.num_kv_heads, max_len, a.head_dim,
                                 dtype, block_p=cfg.block_p)

    def decode_update(self, cache, q, k_new, v_new, aux):
        if isinstance(cache, VanillaCache):
            cache = cache.append(k_new, v_new)
            return cache, _attend_spec(cache)
        return self._slot_update(cache, k_new, v_new, aux)

    def prefill_import(self, arch, cfg, k, v, positions, retained, alpha_bin,
                       *, max_len, layer_window=None, dtype=None):
        a = arch.attn
        dtype = dtype or jnp.dtype(arch.dtype)
        if layer_window is not None:
            raise NotImplementedError("vanilla: no local-window import path")
        b, h, t, d = k.shape
        cache = VanillaCache.init(b, a.num_kv_heads, max_len, a.head_dim,
                                  dtype, block_p=cfg.block_p)
        return cache.append(k, v)


@register_policy("window")
class WindowPolicy(_SlotRingMixin, KVPolicy):
    """StreamingLLM-style sliding window via ring-buffer overflow recycling."""

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        budget = _budget_tokens(cfg, max_len)
        return SlotDMSCache.init(batch, a.num_kv_heads, budget + 1, a.head_dim,
                                 max(arch.dms.window, 1), dtype,
                                 dms_active=False, block_p=cfg.block_p)

    def decode_update(self, cache, q, k_new, v_new, aux):
        return self._slot_update(cache, k_new, v_new, aux)


@register_policy("dms")
class DMSPolicy(_SlotRingMixin, KVPolicy):
    """The paper's policy: slot-compacted arena, delayed eviction (§3.3)."""

    alpha_mode = "dms"

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        eff_len = (min(max_len, layer_window + 1) if layer_window is not None
                   else max_len)
        slots = SlotDMSCache.provision_slots(eff_len, cfg.cr, arch.dms.window)
        return SlotDMSCache.init(batch, a.num_kv_heads, min(slots, eff_len + 1),
                                 a.head_dim, arch.dms.window, dtype,
                                 block_p=cfg.block_p)

    def decode_update(self, cache, q, k_new, v_new, aux):
        return self._slot_update(cache, k_new, v_new, aux)

    def prefill_import(self, arch, cfg, k, v, positions, retained, alpha_bin,
                       *, max_len, layer_window=None, dtype=None):
        eff_len = (min(max_len, layer_window + 1) if layer_window is not None
                   else max_len)
        slots = SlotDMSCache.provision_slots(eff_len, cfg.cr, arch.dms.window)
        return SlotDMSCache.from_prefill(
            k, v, positions, retained, arch.dms.window,
            min(slots, eff_len + 1), alpha_bin=alpha_bin,
            block_p=cfg.block_p)


@register_policy("dms_masked")
class MaskedDMSPolicy(_SlotRingMixin, KVPolicy):
    """Full-length cache with a retained bitmap — the correctness oracle."""

    alpha_mode = "dms"

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        return MaskedDMSCache.init(batch, a.num_kv_heads, max_len, a.head_dim,
                                   arch.dms.window, dtype,
                                   block_p=cfg.block_p)

    def decode_update(self, cache, q, k_new, v_new, aux):
        return self._slot_update(cache, k_new, v_new, aux)


class _WeightEvictPolicy(KVPolicy):
    """Shared insert→attend→evict shape for weight-driven policies."""

    def decode_update(self, cache, q, k_new, v_new, aux):
        cache = cache.insert(k_new, v_new)
        return cache, _attend_spec(cache, needs_weights=True)

    def post_attend(self, cache, weights):
        return cache.evict(weights)


@register_policy("tova")
class TOVAPolicy(_WeightEvictPolicy):
    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        budget = _budget_tokens(cfg, max_len)
        return TOVACache.init(batch, a.num_kv_heads, budget + 1, a.head_dim,
                              dtype, block_p=cfg.block_p)


@register_policy("h2o")
class H2OPolicy(_WeightEvictPolicy):
    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        budget = _budget_tokens(cfg, max_len)
        return H2OCache.init(batch, a.num_kv_heads, budget + 1, a.head_dim,
                             max(budget // 2, 1), dtype, block_p=cfg.block_p)


@register_policy("quest")
class QuestPolicy(KVPolicy):
    """Page-sparse reads over a full cache: the policy whose two budget axes
    diverge — ``reads_tokens`` shrinks, ``live_tokens`` does not."""

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        ps = cfg.quest_page_size
        ml = ((max_len + ps - 1) // ps) * ps
        top = cfg.quest_top_pages or max(int(ml / cfg.cr) // ps, 1)
        return QuestCache.init(batch, a.num_kv_heads, ml, a.head_dim, ps, top, dtype)

    def decode_update(self, cache, q, k_new, v_new, aux):
        cfg = aux["attn_cfg"]
        b = q.shape[0]
        cache = cache.append(k_new, v_new)
        g = cfg.q_per_kv
        q_pool = q[:, 0].reshape(b, cfg.num_kv_heads, g, cfg.head_dim).mean(axis=2)
        pages = cache.select_pages(q_pool)
        tok_mask = cache.token_mask_from_pages(pages)
        # the top-k page selection IS a block table: with use_kernel the
        # flash-decode kernel fetches exactly the selected pages, turning
        # Quest's reads-tokens metering into real HBM traffic
        tbl, n = cache.block_table_from_pages(pages)
        return cache, AttendSpec(cache.k, cache.v, tok_mask, cache.positions(),
                                 block_tbl=tbl, block_n=n,
                                 block_p=cache.page_size)

    def metrics(self, cache):
        live = cache.retained_tokens().astype(jnp.float32).mean(axis=-1)
        reads = jnp.broadcast_to(cache.reads_per_step().astype(jnp.float32),
                                 live.shape)
        return {"live_tokens": live, "reads_tokens": reads,
                "peak_bytes": self.peak_bytes(cache)}

    def peak_bytes(self, cache):
        return (_nbytes(cache.k) + _nbytes(cache.v)
                + _nbytes(cache.kmin) + _nbytes(cache.kmax))


@register_policy("dmc")
class DMCPolicy(KVPolicy):
    """Dynamic Memory Compression: α=1 merges into the newest entry."""

    alpha_mode = "always"

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        slots = int(max_len / cfg.cr) + 16
        return DMCCache.init(batch, a.num_kv_heads, slots, a.head_dim,
                             block_p=cfg.block_p)

    def decode_update(self, cache, q, k_new, v_new, aux):
        cfg = aux["attn_cfg"]
        b = k_new.shape[0]
        alpha = aux.get("alpha_bin")
        if alpha is None:
            alpha = jnp.zeros((b, cfg.num_kv_heads), bool)
        cache = cache.step(k_new, v_new, alpha)
        dtype = aux["dtype"]
        tbl, n, bp = cache.block_spec()
        # merged entries have no single logical position: skip window masking
        return cache, AttendSpec(cache.k.astype(dtype), cache.v.astype(dtype),
                                 cache.valid_mask(), None,
                                 block_tbl=tbl, block_n=n, block_p=bp)


# autoload policies that live in their own modules (each registers itself on
# import — the same mechanism downstream plugins use)
from repro.core import keyformer as _keyformer  # noqa: E402,F401
