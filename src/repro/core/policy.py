"""Unified, pluggable KV cache-policy API: the ``KVPolicy`` registry.

The paper's hyper-scaling results hinge on *which* compression policy runs
(DMS vs. training-free baselines vs. DMC), so the policy abstraction must be
a first-class, extensible contract rather than ``if policy.kind == ...``
chains smeared across the model and engine.  This module defines that
contract; every policy owns its full lifecycle:

* ``init_cache(arch, batch, max_len, cfg, layer_window, dtype)`` — provision
  the cache arena for one attention layer.
* ``decode_update(cache, q, k_new, v_new, aux) -> (cache, AttendSpec)`` —
  absorb one decoded token and describe what this step's attention should
  read (keys/values, visibility, positions, whether post-softmax weights are
  needed back).
* ``post_attend(cache, weights)`` — optional second phase for policies whose
  eviction depends on the current step's attention weights (TOVA, H2O,
  Keyformer).
* ``prefill_import(...)`` — build the cache from full-attention prefill
  outputs (e.g. :meth:`SlotDMSCache.from_prefill`), including un-executed
  delayed-eviction decisions.
* ``fork_cache(cache, width)`` / ``gather_cache(cache, src)`` — the
  shared-prefill fork: prefill a prompt once, clone the cache pytree into W
  hyper-scaling chains instead of re-prefilling W times (``fork_cache``
  widens the batch; ``gather_cache`` is the in-place lane shuffle the
  scheduler uses inside its fixed lane arena).
* ``reclaim_cache(cache, reset_mask, fresh)`` — per-lane arena reset: lanes
  where ``reset_mask`` is True return to the pristine ``fresh`` state (EOS
  early-exit frees a lane's slots for the next admitted request).
* ``export_prefix(cache, lane)`` / ``import_prefix(cache, snap, lane)`` — the
  cross-request prefix lifecycle: snapshot one lane's complete state at a
  token boundary (everything needed to continue decoding, including pending
  eviction rings and score accumulators) and restore it into a pristine lane
  later, so even compressed/evicting caches can reuse a shared prompt prefix
  across requests (see :mod:`repro.serving.prefix_cache`).
* ``metrics(cache)`` — the paper's two budget axes, policy-defined instead of
  engine-guessed: ``live_tokens`` (peak-memory axis), ``reads_tokens``
  (KV-reads axis; differs from live for Quest) and ``peak_bytes`` (physical
  arena bytes, static).

Policies register by name with :func:`register_policy`; the model/engine
dispatch purely through the registry via the :class:`PolicyCache` pytree
wrapper, whose ``policy`` name rides in static (hashable) aux data — so
``jax.jit`` re-traces per policy but the *code* is policy-agnostic.  Adding a
new policy (see :mod:`repro.core.keyformer`) requires zero edits to
``models/`` or ``serving/``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import block_pool
from repro.core.baselines import DMCCache, H2OCache, QuestCache, TOVACache
from repro.core.config import ArchConfig, KVPolicyConfig
from repro.core.kv_cache import (MaskedDMSCache, SlotDMSCache, VanillaCache,
                                 _tree_dataclass, pack_dense)


# ---------------------------------------------------------------------------
# wire types
# ---------------------------------------------------------------------------


@dataclass
class AttendSpec:
    """What one decode step's attention should read.

    ``k``/``v``: (B, Hkv, P, Dh); ``visible``: (B, Hkv, P) bool — canonical:
    construction broadcasts lazily-shaped masks (VanillaCache's (B, 1, P))
    up to the full per-head shape so the reference einsum, the kernel
    dispatch, and the weights-out scatter all see one mask layout.
    ``positions``: per-slot logical positions for local-window masking, or
    ``None`` when no positions are available.
    ``needs_weights`` requests the group-summed post-softmax weights back via
    :meth:`KVPolicy.post_attend`.

    ``block_tbl``/``block_n``/``block_p`` are the **block-table contract**
    with the flash-decode kernel (docs/kernels.md): ``block_tbl`` (B, Hkv,
    NB) int32 lists the arena's live ``block_p``-sized K/V blocks per (lane,
    kv head), compacted into the first ``block_n`` (B, Hkv) entries.  The
    kernel's scalar-prefetched index maps stream exactly those blocks, so
    decode HBM traffic scales with live tokens instead of arena capacity.
    Every *visible* slot must be covered by a listed block (a listed block
    may still contain dead slots — the kernel masks those via ``visible``);
    ``block_p == 0`` means "no table" and the kernel falls back to streaming
    the whole arena.  When ``block_p > 0`` the arena extent P must be a
    ``block_p`` multiple (caches allocate pre-padded; see
    ``KVPolicyConfig.block_p``).

    ``pool_k``/``pool_v``/``phys`` are set for paged caches (same dtype as
    ``k``): the flash kernel then streams pool pages directly — ``block_tbl``
    entries are *logical* block ids translated through ``phys`` at dispatch
    (see :func:`repro.kernels.ops.dms_decode_attention`) — while ``k``/``v``
    hold the gathered dense view for the reference path (dead code under the
    kernel).
    """

    k: jnp.ndarray
    v: jnp.ndarray
    visible: jnp.ndarray
    positions: Optional[jnp.ndarray] = None
    needs_weights: bool = False
    block_tbl: Optional[jnp.ndarray] = None
    block_n: Optional[jnp.ndarray] = None
    block_p: int = 0
    pool_k: Optional[jnp.ndarray] = None     # (NPOOL, block_p, Dh)
    pool_v: Optional[jnp.ndarray] = None
    phys: Optional[jnp.ndarray] = None       # (B, Hkv, NB) int32

    def __post_init__(self):
        # canonicalize lazy (B, 1, P) visibility masks to (B, Hkv, P) at the
        # single construction chokepoint — a broadcast is free under jit and
        # both attention paths (and the weights scatter) rely on the shape
        tgt = self.k.shape[:3]
        if self.visible.shape != tgt:
            self.visible = jnp.broadcast_to(self.visible, tgt)


@_tree_dataclass
class PolicyCache:
    """Pytree wrapper binding a cache state to its policy *by name*.

    The name lives in static aux data, so dispatch inside jitted code is a
    trace-time registry lookup — no isinstance chains, and the cache pytree
    stays an opaque, shardable container for the engine.
    """

    cache: Any
    policy: str = dataclasses.field(metadata={"static": True}, default="vanilla")

    @property
    def length(self) -> jnp.ndarray:
        return self.cache.length


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, "KVPolicy"] = {}


def register_policy(name: str) -> Callable[[type], type]:
    """Class decorator: instantiate and register a :class:`KVPolicy` by name."""

    def deco(cls: type) -> type:
        if name in _REGISTRY:
            raise ValueError(
                f"KV policy {name!r} already registered "
                f"(by {type(_REGISTRY[name]).__name__})")
        pol = cls()
        pol.name = name
        _REGISTRY[name] = pol
        return cls

    return deco


def get_policy(name: str) -> "KVPolicy":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown KV policy {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def init_policy_cache(arch: ArchConfig, batch: int, max_len: int,
                      cfg: KVPolicyConfig, *, layer_kind: str = "attn",
                      layer_window: Optional[int] = None,
                      dtype=None) -> PolicyCache:
    """Provision one attention layer's cache through the registry."""
    name = cfg.kind_for_layer(layer_kind)
    pol = get_policy(name)
    dtype = dtype or jnp.dtype(arch.dtype)
    inner = pol.init_cache(arch, batch, max_len, cfg,
                           layer_window=layer_window, dtype=dtype)
    return PolicyCache(cache=inner, policy=name)


def iter_policy_caches(tree: Any) -> Iterator[PolicyCache]:
    """Yield every :class:`PolicyCache` node in a decode-state pytree."""
    leaves = jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, PolicyCache))
    for leaf in leaves:
        if isinstance(leaf, PolicyCache):
            yield leaf


def map_pooled_caches(state: Any, fn: Callable[[int, Any], Any]) -> Any:
    """Rebuild a decode state with ``fn(pooled_idx, cache)`` applied to every
    *pooled* cache (non-pooled caches pass through untouched).

    ``pooled_idx`` counts pooled caches in :func:`iter_policy_caches` order —
    the same order the scheduler's ``_pool_descs`` and the fault injector's
    ghost-ref ledgers use, so per-pool host arrays line up by index."""
    counter = [0]

    def visit(node):
        if isinstance(node, PolicyCache) \
                and getattr(node.cache, "pool", None) is not None:
            idx = counter[0]
            counter[0] += 1
            return dataclasses.replace(node, cache=fn(idx, node.cache))
        return node

    return jax.tree_util.tree_map(
        visit, state, is_leaf=lambda x: isinstance(x, PolicyCache))


def state_peak_bytes(state: Any) -> int:
    """Physical KV arena bytes of a decode state (uniform metrics contract).

    Works on both per-layer caches and the stacked (superblock-leading)
    decode state — ``peak_bytes`` is purely shape-derived.
    """
    return sum(get_policy(pc.policy).peak_bytes(pc.cache)
               for pc in iter_policy_caches(state))


def state_pool_stats(state: Any) -> Optional[Dict[str, Any]]:
    """Aggregate paged-pool counters across every pooled cache in a decode
    state (host-side; call outside jit).  None when nothing is paged.

    ``live_tokens`` comes from each cache's incremental BlockTable ``count``
    (live slots per block — sums shape-safely whatever the leading stacking),
    so ``fragmentation`` is the global share of *mapped page capacity* not
    holding a live token: padded-vs-packed waste inside allocated pages."""
    out: Optional[Dict[str, Any]] = None
    mapped_cap = 0
    for pc in iter_policy_caches(state):
        pool = getattr(pc.cache, "pool", None)
        if pool is None:
            continue
        s = block_pool.stats(pool, pc.cache.phys,
                             live_tokens=pc.cache.blocks.count)
        mapped_cap += s["mapped_entries"] * pool.block_p
        if out is None:
            out = dict(s)
            out["pools"] = 1
        else:
            for key in ("pool_blocks", "allocated_blocks", "free_blocks",
                        "shared_blocks", "cow_copies", "alloc_events",
                        "high_water_blocks", "superblocks", "mapped_entries",
                        "live_tokens"):
                out[key] += s[key]
            out["exhausted"] = out["exhausted"] or s["exhausted"]
            out["pools"] += 1
    if out is not None:
        out["fragmentation"] = (1.0 - out["live_tokens"] / mapped_cap
                                if mapped_cap else 0.0)
    return out


def _nbytes(a) -> int:
    n = 1
    for s in a.shape:
        n *= int(s)
    return n * jnp.dtype(a.dtype).itemsize


def _budget_tokens(cfg: KVPolicyConfig, max_len: int) -> int:
    return cfg.budget or max(int(max_len / cfg.cr), 1)


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


class KVPolicy:
    """Base contract.  Subclass, implement the lifecycle, decorate with
    ``@register_policy("name")`` — the model/engine pick it up untouched."""

    name: str = ""
    #: "none" — policy never sees eviction decisions;
    #: "dms"  — extract binarised DMS α when ``arch.dms.enabled``;
    #: "always" — extract α from the borrowed neuron unconditionally (DMC).
    alpha_mode: str = "none"

    # -- lifecycle -----------------------------------------------------------

    def init_cache(self, arch: ArchConfig, batch: int, max_len: int,
                   cfg: KVPolicyConfig, *, layer_window: Optional[int],
                   dtype) -> Any:
        raise NotImplementedError

    def decode_update(self, cache: Any, q: jnp.ndarray, k_new: jnp.ndarray,
                      v_new: jnp.ndarray, aux: Dict[str, Any]
                      ) -> Tuple[Any, AttendSpec]:
        """q: (B, 1, Hq, Dh) post-RoPE; k_new/v_new: (B, Hkv, 1, Dh) post-RoPE.

        aux carries ``alpha_bin`` ((B, Hkv) bool or None), ``pos_t``,
        ``attn_cfg``, ``arch`` and ``dtype``.
        """
        raise NotImplementedError

    def post_attend(self, cache: Any, weights: jnp.ndarray,
                    active: Optional[jnp.ndarray] = None) -> Any:
        """Second phase when ``AttendSpec.needs_weights``; ``weights`` is the
        group-summed post-softmax distribution (B, Hkv, P).  ``active`` is
        the scheduler's per-lane live mask — paged caches gate pool mutation
        on it (shared pool state cannot be rolled back by lane_select)."""
        return cache

    def prefill_import(self, arch: ArchConfig, cfg: KVPolicyConfig,
                       k: jnp.ndarray, v: jnp.ndarray,
                       positions: jnp.ndarray, retained: Optional[jnp.ndarray],
                       alpha_bin: Optional[jnp.ndarray], *, max_len: int,
                       layer_window: Optional[int] = None, dtype=None) -> Any:
        """Build a cache from full-attention prefill outputs (k/v:
        (B, Hkv, T, Dh) post-RoPE, e.g. ``make_prefill_step``'s ``layer_kv``).

        ``Engine`` currently teacher-forces prompts through the decode path
        (exact eviction semantics for every policy); this hook is for callers
        that run a dense prefill and import the result — policies without an
        import path raise."""
        raise NotImplementedError(f"{self.name}: no prefill import path")

    # -- lane lifecycle (continuous batching / hyperscale fork) --------------

    def fork_cache(self, cache: Any, width: int, *, axis: int = 0) -> Any:
        """Clone every lane of ``cache`` into ``width`` adjacent lanes.

        The shared-prefill fork: prefill once at batch B, fork to B·W chains
        — forked chains see bitwise-identical cache contents, so their first
        decode step matches W independent prefills while the prefill-phase
        KV reads drop by W×.  The default tiles the lane axis of every array
        leaf (all caches are lane-leading pytrees); policies with non-lane
        state override.  ``axis`` selects the lane axis (1 for decode states
        stacked over superblocks).

        Paged caches fork **copy-on-write**: only the per-lane page map
        tiles and refcounts are recomputed — zero pool bytes move until a
        forked chain's first divergent write (token_write's CoW path)."""
        pool = getattr(cache, "pool", None)
        if pool is None:
            return jax.tree_util.tree_map(
                lambda a: jnp.repeat(a, width, axis=axis), cache)
        body = dataclasses.replace(cache, pool=None)
        body = jax.tree_util.tree_map(
            lambda a: jnp.repeat(a, width, axis=axis), body)
        return dataclasses.replace(
            body, pool=block_pool.set_refcounts(pool, body.phys))

    def gather_cache(self, cache: Any, src: jnp.ndarray, *,
                     axis: int = 0) -> Any:
        """Lane shuffle: new lane ``l`` takes old lane ``src[l]``'s state —
        how the scheduler forks a prefilled lane into free lanes of a
        fixed-size arena (``src`` is the identity except forked targets).
        Same override point as :meth:`fork_cache` for policies whose state
        is not purely lane-leading.

        Paged: the page map shuffles like any per-lane leaf, then refcounts
        are recomputed — duplicated lanes become CoW sharers, dropped lanes'
        pages fall back to the free list."""
        pool = getattr(cache, "pool", None)
        if pool is None:
            return jax.tree_util.tree_map(
                lambda a: jnp.take(a, src, axis=axis), cache)
        body = dataclasses.replace(cache, pool=None)
        body = jax.tree_util.tree_map(
            lambda a: jnp.take(a, src, axis=axis), body)
        return dataclasses.replace(
            body, pool=block_pool.set_refcounts(pool, body.phys))

    # -- prefix lifecycle (cross-request radix prefix cache) -----------------

    def export_prefix(self, cache: Any, lane, *, axis: int = 0) -> Any:
        """Snapshot one lane's complete cache state at a token boundary.

        Returns a width-1-lane pytree of the same structure as ``cache``
        (static fields ride along), suitable for host storage in the
        cross-request prefix cache and later re-import.  The contract: for a
        lane that has consumed exactly the L prefix tokens, the snapshot holds
        *everything* the policy needs to continue decoding — arena contents,
        free lists, pending eviction rings, score accumulators, page metadata
        — so ``import_prefix`` + suffix prefill is bitwise-equal to a cold
        prefill of the full prompt.  All built-in caches keep their per-lane
        state lane-leading (:class:`~repro.core.kv_cache.LaneSliceable`), so
        the default is a pure lane slice; policies with non-lane state must
        override both hooks together (same override point as
        :meth:`fork_cache`).  ``lane`` may be a traced int32 scalar.

        Paged caches **densify** on export: the lane's pool pages are
        gathered into a fixed-arena-shaped snapshot (``pool``/``phys`` =
        None) — byte-compatible with snapshots from a fixed-arena engine, so
        the prefix cache stores one format."""
        pool = getattr(cache, "pool", None)
        if pool is None:
            return cache.export_lane(lane, axis=axis)
        if axis:
            return jax.vmap(
                lambda c: self.export_prefix(c, lane, axis=0))(cache)
        phys_l = jax.lax.dynamic_slice_in_dim(cache.phys, lane, 1, axis=0)
        k, v = block_pool.dense_kv(pool, phys_l)             # (1, H, P, Dh)
        snap = dataclasses.replace(cache, pool=None, phys=None
                                   ).export_lane(lane, axis=0)
        return dataclasses.replace(snap, k=k, v=v)

    def import_prefix(self, cache: Any, snap: Any, lane, *, axis: int = 0
                      ) -> Any:
        """Restore an :meth:`export_prefix` snapshot into lane ``lane``.

        The target lane must be pristine (just reclaimed/initialised); the
        snapshot overwrites every leaf's lane slice, so the lane continues
        exactly where the exporting request's prefill stood.

        Paged caches re-page the dense snapshot: pages are allocated for
        every block with a live slot, snapshot block contents scatter into
        them, and the lane's page map + refcounts are rebuilt.  Pool
        exhaustion drops the affected blocks (reads as zeros, masked) and
        latches ``pool.exhausted``."""
        pool = getattr(cache, "pool", None)
        if pool is None:
            return cache.import_lane(snap, lane, axis=axis)
        if axis:
            return jax.vmap(
                lambda c, s: self.import_prefix(c, s, lane, axis=0)
            )(cache, snap)
        bp = pool.block_p
        _, hh, nbb = cache.phys.shape
        p, dh = snap.k.shape[2], snap.k.shape[3]
        valid = jnp.broadcast_to(snap.valid_mask(), (1, hh, p))
        need = jnp.any(valid.reshape(hh, nbb, bp), axis=-1).reshape(-1)
        pool, page, ok = block_pool.alloc(pool, need)
        dst = jnp.where(need & ok, page, pool.num_blocks)
        pool = dataclasses.replace(
            pool,
            k=pool.k.at[dst].set(
                snap.k.reshape(hh * nbb, bp, dh).astype(pool.k.dtype),
                mode="drop"),
            v=pool.v.at[dst].set(
                snap.v.reshape(hh * nbb, bp, dh).astype(pool.v.dtype),
                mode="drop"))
        phys_lane = jnp.where(need & ok, page, -1).reshape(1, hh, nbb)
        phys = jax.lax.dynamic_update_slice_in_dim(cache.phys, phys_lane,
                                                   lane, axis=0)
        pool = dataclasses.replace(
            pool, ref=block_pool.recount(phys, pool.num_blocks))
        body = dataclasses.replace(cache, pool=None, phys=None)
        snap_z = dataclasses.replace(
            snap, pool=None, phys=None,
            k=snap.k[..., :0].astype(cache.k.dtype),
            v=snap.v[..., :0].astype(cache.v.dtype))
        body = body.import_lane(snap_z, lane, axis=0)
        return dataclasses.replace(body, pool=pool, phys=phys)

    def import_slab(self, slab: Any, snap: Any, slot, *, axis: int = 0
                    ) -> Any:
        """Device-side variant of :meth:`import_prefix` for the hot-tier
        snapshot slab: write a width-1 snapshot into storage slot ``slot``.

        The slab is *storage*, not a decode cache — it is ``slots`` stacked
        copies of whatever pytree :meth:`export_prefix` returns (see
        :func:`repro.models.transformer.init_snapshot_slab`), so the default
        is a pure ``dynamic_update_slice`` on the snapshot's own leaves.
        Runs jitted with both operands device-resident: a deferred export
        costs zero host↔device bytes.  A policy whose ``export_prefix``
        snapshot is not a width-1-lane pytree must override this pair
        alongside the prefix pair."""
        return jax.tree_util.tree_map(
            lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, s.astype(d.dtype), slot, axis=axis), slab, snap)

    def export_slab(self, slab: Any, slot, *, axis: int = 0) -> Any:
        """Device-side variant of :meth:`export_prefix`: fetch the snapshot
        stored in slab slot ``slot`` (the zero-copy hot-hit path — the
        result feeds :meth:`import_prefix` device-to-device)."""
        return jax.tree_util.tree_map(
            lambda d: jax.lax.dynamic_slice_in_dim(d, slot, 1, axis=axis),
            slab)

    def reclaim_cache(self, cache: Any, reset_mask: jnp.ndarray,
                      fresh: Any, *, axis: int = 0) -> Any:
        """Reset lanes where ``reset_mask`` (B,) is True to the pristine
        ``fresh`` cache: the EOS-reclamation hook.  A reclaimed lane's arena
        reads as empty (``live_tokens`` ≈ 0) and its free list is full, so
        the scheduler can admit the next request into it.

        Paged: the reclaimed lane's page-map rows reset to -1 and refcounts
        are recomputed, so its pages return to the free list the moment no
        CoW sharer still maps them.  The pool itself (bytes + counters) is
        kept — counters are monotone observability state."""

        def sel(cur, init):
            m = reset_mask.reshape((1,) * axis + (-1,)
                                   + (1,) * (cur.ndim - axis - 1))
            return jnp.where(m, init, cur)

        pool = getattr(cache, "pool", None)
        if pool is None:
            return jax.tree_util.tree_map(sel, cache, fresh)
        body = jax.tree_util.tree_map(
            sel, dataclasses.replace(cache, pool=None),
            dataclasses.replace(fresh, pool=None))
        return dataclasses.replace(
            body, pool=block_pool.set_refcounts(pool, body.phys))

    # -- accounting ----------------------------------------------------------

    def metrics(self, cache: Any) -> Dict[str, Any]:
        """Budget accounting, policy-defined.  ``live_tokens``/``reads_tokens``
        are (B,) arrays (mean over kv heads); ``peak_bytes`` is a static int
        (physical arena size, valid under tracing as a constant)."""
        live = cache.retained_tokens().astype(jnp.float32).mean(axis=-1)
        return {"live_tokens": live, "reads_tokens": live,
                "peak_bytes": self.peak_bytes(cache)}

    def peak_bytes(self, cache: Any) -> int:
        pool = getattr(cache, "pool", None)
        if pool is not None:
            # paged: the device footprint IS the pool; per-lane arenas are
            # zero-width placeholders
            return _nbytes(pool.k) + _nbytes(pool.v)
        return _nbytes(cache.k) + _nbytes(cache.v)


# ---------------------------------------------------------------------------
# built-in policies
# ---------------------------------------------------------------------------


def _attend_spec(cache, **kw) -> AttendSpec:
    """Uniform spec builder: attach the cache's live-block table when it
    maintains one (``block_spec`` is the cache-side half of the kernel's
    block-table contract — see docs/kernels.md).

    Paged caches additionally pass the pool arena through for the kernel and
    gather a dense view for the reference path (DCE'd under the kernel)."""
    tbl, n, bp = cache.block_spec() if hasattr(cache, "block_spec") \
        else (None, None, 0)
    pool = getattr(cache, "pool", None)
    if pool is not None:
        k, v = block_pool.dense_kv(pool, cache.phys)
        return AttendSpec(k, v, cache.valid_mask(), cache.positions(),
                          block_tbl=tbl, block_n=n, block_p=bp,
                          pool_k=pool.k, pool_v=pool.v, phys=cache.phys, **kw)
    return AttendSpec(cache.k, cache.v, cache.valid_mask(), cache.positions(),
                      block_tbl=tbl, block_n=n, block_p=bp, **kw)


class _SlotRingMixin:
    """Shared decode path for slot-arena caches (dms / vanilla-local / window)."""

    @staticmethod
    def _slot_update(cache, k_new, v_new, aux):
        cfg = aux["attn_cfg"]
        b = k_new.shape[0]
        alpha = aux.get("alpha_bin")
        if alpha is None:
            alpha = jnp.zeros((b, cfg.num_kv_heads), bool)
        cache = cache.step(k_new, v_new, alpha, active=aux.get("active"))
        return cache, _attend_spec(cache)


@register_policy("vanilla")
class VanillaPolicy(_SlotRingMixin, KVPolicy):
    """Dense append-only cache; local-attention layers get a ring buffer
    (overflow recycling == sliding window) so memory stays O(window)."""

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        if layer_window is not None:
            eff_len = min(max_len, layer_window + 1)
            return SlotDMSCache.init(batch, a.num_kv_heads, eff_len, a.head_dim,
                                     max(arch.dms.window, 1), dtype,
                                     dms_active=False, block_p=cfg.block_p,
                                     paged=cfg.paged,
                                     pool_blocks=cfg.pool_blocks)
        return VanillaCache.init(batch, a.num_kv_heads, max_len, a.head_dim,
                                 dtype, block_p=cfg.block_p, paged=cfg.paged,
                                 pool_blocks=cfg.pool_blocks)

    def decode_update(self, cache, q, k_new, v_new, aux):
        if isinstance(cache, VanillaCache):
            cache = cache.append(k_new, v_new, active=aux.get("active"))
            return cache, _attend_spec(cache)
        return self._slot_update(cache, k_new, v_new, aux)

    def prefill_import(self, arch, cfg, k, v, positions, retained, alpha_bin,
                       *, max_len, layer_window=None, dtype=None):
        a = arch.attn
        dtype = dtype or jnp.dtype(arch.dtype)
        if layer_window is not None:
            raise NotImplementedError("vanilla: no local-window import path")
        b, h, t, d = k.shape
        cache = VanillaCache.init(b, a.num_kv_heads, max_len, a.head_dim,
                                  dtype, block_p=cfg.block_p, paged=cfg.paged,
                                  pool_blocks=cfg.pool_blocks)
        return cache.append(k, v)


@register_policy("window")
class WindowPolicy(_SlotRingMixin, KVPolicy):
    """StreamingLLM-style sliding window via ring-buffer overflow recycling."""

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        budget = _budget_tokens(cfg, max_len)
        return SlotDMSCache.init(batch, a.num_kv_heads, budget + 1, a.head_dim,
                                 max(arch.dms.window, 1), dtype,
                                 dms_active=False, block_p=cfg.block_p,
                                 paged=cfg.paged, pool_blocks=cfg.pool_blocks)

    def decode_update(self, cache, q, k_new, v_new, aux):
        return self._slot_update(cache, k_new, v_new, aux)


@register_policy("dms")
class DMSPolicy(_SlotRingMixin, KVPolicy):
    """The paper's policy: slot-compacted arena, delayed eviction (§3.3)."""

    alpha_mode = "dms"

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        eff_len = (min(max_len, layer_window + 1) if layer_window is not None
                   else max_len)
        slots = SlotDMSCache.provision_slots(eff_len, cfg.cr, arch.dms.window)
        return SlotDMSCache.init(batch, a.num_kv_heads, min(slots, eff_len + 1),
                                 a.head_dim, arch.dms.window, dtype,
                                 block_p=cfg.block_p, paged=cfg.paged,
                                 pool_blocks=cfg.pool_blocks)

    def decode_update(self, cache, q, k_new, v_new, aux):
        return self._slot_update(cache, k_new, v_new, aux)

    def prefill_import(self, arch, cfg, k, v, positions, retained, alpha_bin,
                       *, max_len, layer_window=None, dtype=None):
        eff_len = (min(max_len, layer_window + 1) if layer_window is not None
                   else max_len)
        slots = SlotDMSCache.provision_slots(eff_len, cfg.cr, arch.dms.window)
        cache = SlotDMSCache.from_prefill(
            k, v, positions, retained, arch.dms.window,
            min(slots, eff_len + 1), alpha_bin=alpha_bin,
            block_p=cfg.block_p)
        if cfg.paged:
            cache = pack_dense(cache, cfg.pool_blocks)
        return cache


@register_policy("dms_masked")
class MaskedDMSPolicy(_SlotRingMixin, KVPolicy):
    """Full-length cache with a retained bitmap — the correctness oracle."""

    alpha_mode = "dms"

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        return MaskedDMSCache.init(batch, a.num_kv_heads, max_len, a.head_dim,
                                   arch.dms.window, dtype,
                                   block_p=cfg.block_p, paged=cfg.paged,
                                   pool_blocks=cfg.pool_blocks)

    def decode_update(self, cache, q, k_new, v_new, aux):
        return self._slot_update(cache, k_new, v_new, aux)


class _WeightEvictPolicy(KVPolicy):
    """Shared insert→attend→evict shape for weight-driven policies."""

    def decode_update(self, cache, q, k_new, v_new, aux):
        cache = cache.insert(k_new, v_new, active=aux.get("active"))
        return cache, _attend_spec(cache, needs_weights=True)

    def post_attend(self, cache, weights, active=None):
        return cache.evict(weights, active=active)


@register_policy("tova")
class TOVAPolicy(_WeightEvictPolicy):
    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        budget = _budget_tokens(cfg, max_len)
        return TOVACache.init(batch, a.num_kv_heads, budget + 1, a.head_dim,
                              dtype, block_p=cfg.block_p, paged=cfg.paged,
                              pool_blocks=cfg.pool_blocks)


@register_policy("h2o")
class H2OPolicy(_WeightEvictPolicy):
    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        budget = _budget_tokens(cfg, max_len)
        return H2OCache.init(batch, a.num_kv_heads, budget + 1, a.head_dim,
                             max(budget // 2, 1), dtype, block_p=cfg.block_p,
                             paged=cfg.paged, pool_blocks=cfg.pool_blocks)


@register_policy("quest")
class QuestPolicy(KVPolicy):
    """Page-sparse reads over a full cache: the policy whose two budget axes
    diverge — ``reads_tokens`` shrinks, ``live_tokens`` does not."""

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        ps = cfg.quest_page_size
        ml = ((max_len + ps - 1) // ps) * ps
        top = cfg.quest_top_pages or max(int(ml / cfg.cr) // ps, 1)
        return QuestCache.init(batch, a.num_kv_heads, ml, a.head_dim, ps, top,
                               dtype, paged=cfg.paged,
                               pool_blocks=cfg.pool_blocks)

    def decode_update(self, cache, q, k_new, v_new, aux):
        cfg = aux["attn_cfg"]
        b = q.shape[0]
        cache = cache.append(k_new, v_new, active=aux.get("active"))
        g = cfg.q_per_kv
        q_pool = q[:, 0].reshape(b, cfg.num_kv_heads, g, cfg.head_dim).mean(axis=2)
        pages = cache.select_pages(q_pool)
        tok_mask = cache.token_mask_from_pages(pages)
        # the top-k page selection IS a block table: with use_kernel the
        # flash-decode kernel fetches exactly the selected pages, turning
        # Quest's reads-tokens metering into real HBM traffic
        tbl, n = cache.block_table_from_pages(pages)
        if cache.pool is not None:
            kd, vd = block_pool.dense_kv(cache.pool, cache.phys)
            return cache, AttendSpec(kd, vd, tok_mask, cache.positions(),
                                     block_tbl=tbl, block_n=n,
                                     block_p=cache.page_size,
                                     pool_k=cache.pool.k, pool_v=cache.pool.v,
                                     phys=cache.phys)
        return cache, AttendSpec(cache.k, cache.v, tok_mask, cache.positions(),
                                 block_tbl=tbl, block_n=n,
                                 block_p=cache.page_size)

    def metrics(self, cache):
        live = cache.retained_tokens().astype(jnp.float32).mean(axis=-1)
        reads = jnp.broadcast_to(cache.reads_per_step().astype(jnp.float32),
                                 live.shape)
        return {"live_tokens": live, "reads_tokens": reads,
                "peak_bytes": self.peak_bytes(cache)}

    def peak_bytes(self, cache):
        if cache.pool is not None:
            return (_nbytes(cache.pool.k) + _nbytes(cache.pool.v)
                    + _nbytes(cache.kmin) + _nbytes(cache.kmax))
        return (_nbytes(cache.k) + _nbytes(cache.v)
                + _nbytes(cache.kmin) + _nbytes(cache.kmax))


@register_policy("dmc")
class DMCPolicy(KVPolicy):
    """Dynamic Memory Compression: α=1 merges into the newest entry."""

    alpha_mode = "always"

    def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
        a = arch.attn
        slots = int(max_len / cfg.cr) + 16
        return DMCCache.init(batch, a.num_kv_heads, slots, a.head_dim,
                             block_p=cfg.block_p, paged=cfg.paged,
                             pool_blocks=cfg.pool_blocks)

    def decode_update(self, cache, q, k_new, v_new, aux):
        cfg = aux["attn_cfg"]
        b = k_new.shape[0]
        alpha = aux.get("alpha_bin")
        if alpha is None:
            alpha = jnp.zeros((b, cfg.num_kv_heads), bool)
        cache = cache.step(k_new, v_new, alpha, active=aux.get("active"))
        dtype = aux["dtype"]
        tbl, n, bp = cache.block_spec()
        if cache.pool is not None:
            # the pool holds fp32 accumulators while the spec is model-dtype,
            # so (unlike other paged caches) the kernel cannot stream pool
            # pages directly: gather the dense view and cast, exactly the
            # fixed-arena path — the cast output feeds the same kernel
            kd, vd = block_pool.dense_kv(cache.pool, cache.phys)
        else:
            kd, vd = cache.k, cache.v
        # merged entries carry their newest contribution's position, so
        # layer_map window layers mask DMC slots like every other policy
        # (a merged entry is "as recent as" its last absorbed token)
        return cache, AttendSpec(kd.astype(dtype), vd.astype(dtype),
                                 cache.valid_mask(), cache.positions(),
                                 block_tbl=tbl, block_n=n, block_p=bp)


# autoload policies that live in their own modules (each registers itself on
# import — the same mechanism downstream plugins use)
from repro.core import keyformer as _keyformer  # noqa: E402,F401
