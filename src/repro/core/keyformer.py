"""Keyformer (Adnan et al., 2024): score-based KV eviction with
Gumbel-softmax regularization.

Intuition: post-softmax attention weights are a biased importance signal —
once tokens are dropped, the softmax renormalises over survivors and
over-weights recency.  Keyformer regularises the per-step score with Gumbel
noise and a temperature ``tau`` before accumulating, which both smooths the
distribution and injects the stochastic tie-breaking the paper shows matters
for long-tail retention.  A recency window is always protected (like H2O);
outside it, the token with the lowest accumulated regularised score is
evicted when over budget.

This module is the registry's worked extension example: it defines its own
cache pytree and plugs in purely through ``@register_policy`` + the
``KVPolicy`` lifecycle — zero edits to ``models/`` or ``serving/`` (see
docs/policies.md for the walkthrough).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import block_pool
from repro.core.config import ArchConfig, KVPolicyConfig
from repro.core.kv_cache import (INVALID_POS, BlockTable, HasBlockTable,
                                 LaneSliceable, _round_up, _tree_dataclass,
                                 event_mask, init_paged)
from repro.core.policy import KVPolicy, _attend_spec, register_policy

_SCORE_EPS = 1e-9
_NOISE_SEED = 0x5EED  # fixed: decode must be reproducible per (seed, step)


@_tree_dataclass
class KeyformerCache(LaneSliceable, HasBlockTable):
    k: jnp.ndarray       # (B, H, P, D) — P padded to a block_p multiple
    v: jnp.ndarray
    pos: jnp.ndarray     # (B, H, P) int32
    valid: jnp.ndarray   # (B, H, P) bool
    score: jnp.ndarray   # (B, H, P) f32 — accumulated regularised scores
    length: jnp.ndarray  # (B,) — per lane
    salt: jnp.ndarray    # (B,) uint32 — per-layer noise salt (see insert)
    blocks: BlockTable   # incremental live-block table (flash-decode)
    recent_window: int = dataclasses.field(metadata={"static": True})
    slots: int = dataclasses.field(metadata={"static": True})  # logical arena
    tau: float = dataclasses.field(metadata={"static": True}, default=1.0)
    pool: Optional[block_pool.BlockPool] = None
    phys: Optional[jnp.ndarray] = None       # (B, H, NB) int32, -1 = unmapped

    @staticmethod
    def init(batch, kv_heads, budget, head_dim, recent_window, tau,
             dtype=jnp.bfloat16, block_p: int = 0, paged: bool = False,
             pool_blocks=None):
        p = _round_up(budget, block_p)
        pool = phys = None
        if paged:
            pool, phys, z = init_paged(batch, kv_heads, p, head_dim, block_p,
                                       dtype, pool_blocks)
        else:
            z = jnp.zeros((batch, kv_heads, p, head_dim), dtype)
        return KeyformerCache(
            z, z,
            jnp.full((batch, kv_heads, p), INVALID_POS, jnp.int32),
            jnp.zeros((batch, kv_heads, p), bool),
            jnp.zeros((batch, kv_heads, p), jnp.float32),
            jnp.zeros((batch,), jnp.int32),
            jnp.zeros((batch,), jnp.uint32),
            BlockTable.init(batch, kv_heads, p, block_p),
            recent_window, budget, tau, pool=pool, phys=phys)

    @property
    def budget(self) -> int:
        return self.slots - 1   # arena is budget + 1 (insert-then-evict)

    def insert(self, k_new, v_new, active=None,
               salt=None) -> "KeyformerCache":
        p = self.k.shape[2]
        free = ~self.valid & (jnp.arange(p)[None, None] < self.slots)
        slot = jnp.argmax(free, axis=2).astype(jnp.int32)         # first free
        hit = (jnp.arange(p)[None, None] == slot[..., None])
        newly = jnp.take_along_axis(free, slot[..., None], axis=2)[..., 0]
        pool, phys = self.pool, self.phys
        if pool is not None:
            pool, phys = block_pool.token_write(
                pool, phys, slot[..., None], k_new, v_new,
                event_mask(active, slot.shape)[..., None])
            k, v = self.k, self.v       # zero-width; bytes go to the pool
        else:
            k = jnp.where(hit[..., None], k_new.astype(self.k.dtype), self.k)
            v = jnp.where(hit[..., None], v_new.astype(self.v.dtype), self.v)
        # Stash the layer salt for this step's Gumbel draw.  It must be
        # derived from something bit-identical between the kernel and
        # reference attention paths — activations (k_new, attention weights)
        # differ by float ulps at layers > 0 and a bitcast salt would fork
        # the whole noise stream — so the policy passes a per-layer PARAM
        # scalar (decorrelating layers) and the draw folds it with the
        # per-lane logical step (decorrelating steps; see
        # ``accumulate_and_evict``).
        if salt is None:
            salt = jnp.zeros((), jnp.uint32)
        salt = jnp.broadcast_to(jnp.asarray(salt, jnp.uint32),
                                self.length.shape)
        return dataclasses.replace(
            self,
            k=k, v=v,
            pos=jnp.where(hit, self.length[:, None, None], self.pos),
            valid=self.valid | hit,
            score=jnp.where(hit, 0.0, self.score),
            length=self.length + 1,
            salt=salt,
            blocks=self.blocks.insert(slot, newly),
            pool=pool, phys=phys)

    def accumulate_and_evict(self, attn_weights, active=None) -> "KeyformerCache":
        """attn_weights: (B, H, P) group-summed post-softmax weights.

        Score update (Keyformer §4): softmax((log w + Gumbel noise) / tau)
        over live slots, accumulated; evict argmin outside the recency window
        when over budget.  Noise is derived from a fixed key folded with the
        logical step, so jitted decode stays deterministic and scan-safe.
        """
        p = self.k.shape[2]
        w = attn_weights.astype(jnp.float32)
        # Noise is derived PER LANE from (lane step, layer salt): lanes are
        # independent streams under continuous batching, so the draw must not
        # see other lanes (batch invariance — a forked chain replays exactly
        # the same noise as an independently-prefilled one).  The layer salt
        # (stored by ``insert`` from a per-layer param scalar) decorrelates
        # layers while staying attention-implementation-independent, which
        # is what keeps ``use_kernel`` decode token-equal to the reference.
        base = jax.random.PRNGKey(_NOISE_SEED)

        def draw(len_b, salt_b):
            k = jax.random.fold_in(base, len_b)
            k = jax.random.fold_in(k, salt_b)
            return jax.random.bits(k, w.shape[1:], jnp.uint32)

        bits = jax.vmap(draw)(self.length, self.salt)
        # bits -> uniform via exact steps only: mantissa-fill to [1, 2),
        # the exact -1.0, and a clip.  ``jax.random.uniform``'s affine
        # minval/maxval rescale FMA-fuses differently at different batch
        # shapes, breaking the bitwise fork == tiled-prefill contract.
        u01 = jax.lax.bitcast_convert_type(
            (bits >> 9) | jnp.uint32(0x3F800000), jnp.float32) - 1.0
        u = jnp.clip(u01, _SCORE_EPS, 1.0 - _SCORE_EPS)
        gumbel = -jnp.log(-jnp.log(u))
        logits = jnp.where(self.valid, jnp.log(w + _SCORE_EPS) + gumbel, -jnp.inf)
        reg = jax.nn.softmax(logits / self.tau, axis=-1)
        score = self.score + jnp.where(self.valid, reg, 0.0)

        over = jnp.sum(self.valid, axis=2) > self.budget
        recent = self.pos >= (self.length - self.recent_window)[:, None, None]
        cand = jnp.where(self.valid & ~recent, score, jnp.inf)
        any_evictable = jnp.any(jnp.isfinite(cand), axis=2)
        oldest = jnp.argmin(jnp.where(self.valid, self.pos, INVALID_POS), axis=2)
        victim = jnp.where(any_evictable, jnp.argmin(cand, axis=2),
                           oldest).astype(jnp.int32)
        hit = (jnp.arange(p)[None, None] == victim[..., None]) & over[..., None]
        blocks, dead = self.blocks.evict_ex(victim, over)
        pool, phys = self.pool, self.phys
        if pool is not None:
            pool, phys = block_pool.free_block(
                pool, phys, victim, dead & event_mask(active, victim.shape))
        return dataclasses.replace(
            self,
            pos=jnp.where(hit, INVALID_POS, self.pos),
            valid=self.valid & ~hit,
            score=jnp.where(hit, 0.0, score),
            blocks=blocks, pool=pool, phys=phys)

    def valid_mask(self):
        return self.valid

    def positions(self):
        return self.pos

    def retained_tokens(self):
        return jnp.sum(self.valid, axis=-1)


@register_policy("keyformer")
class KeyformerPolicy(KVPolicy):
    def init_cache(self, arch: ArchConfig, batch: int, max_len: int,
                   cfg: KVPolicyConfig, *, layer_window, dtype):
        a = arch.attn
        budget = cfg.budget or max(int(max_len / cfg.cr), 1)
        return KeyformerCache.init(batch, a.num_kv_heads, budget + 1,
                                   a.head_dim, max(budget // 2, 1),
                                   cfg.keyformer_tau, dtype,
                                   block_p=cfg.block_p, paged=cfg.paged,
                                   pool_blocks=cfg.pool_blocks)

    def decode_update(self, cache, q, k_new, v_new, aux):
        cache = cache.insert(k_new, v_new, active=aux.get("active"),
                             salt=aux.get("layer_salt"))
        return cache, _attend_spec(cache, needs_weights=True)

    def post_attend(self, cache, weights, active=None):
        return cache.accumulate_and_evict(weights, active=active)
