"""Inference-time hyper-scaling controller (paper §2.1, §5.1).

A scaling configuration is an ``L-W-CR`` tuple: max sequence length L, number
of parallel reasoning chains W, compression ratio CR.  The two budget metrics
the paper Pareto-plots against accuracy:

* **KV cache token reads** — Σ over decode steps of the number of live cache
  items attended to (per layer, per kv head, averaged over heads then summed).
  Proxy for runtime: decode is memory-bound (Appendix G).
* **Peak tokens in memory** — max over time of the total live cache size.

The accounting here is *exact* (driven by the real cache states produced
during generation), so the Pareto benchmark measures the same thing the paper
does, just on our models/tasks.  Answer aggregation: majority voting
(Wang et al., 2023) for exact-match tasks, pass@all for code-style tasks.
"""
from __future__ import annotations

import collections
import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class ScalingConfig:
    """One L-W-CR point of the scaling grid.

    ``eos_id`` enables EOS-driven early exit during serving: a chain that
    emits it stops contributing KV reads and its lane is reclaimed (None =
    decode the full budget, the paper's fixed-L accounting)."""

    max_len: int
    width: int
    cr: float = 1.0
    eos_id: Optional[int] = None

    @property
    def label(self) -> str:
        return f"{self.max_len // 1024}-{self.width}-{self.cr:g}"


@dataclass
class BudgetMeter:
    """Accumulates the paper's two budget metrics during generation.

    The two axes are metered separately because they diverge for reads-sparse
    policies (Quest reduces *reads*, not cache size): ``kv_reads`` integrates
    ``reads_tokens`` over steps, ``peak_tokens`` tracks the max of
    ``live_tokens``.  Both come from the policies' uniform ``metrics()``
    contract (:mod:`repro.core.policy`), not engine guesses.
    """

    kv_reads: float = 0.0
    kv_reads_saved: float = 0.0   # prefill reads avoided via prefix-cache hits
    peak_tokens: float = 0.0
    peak_bytes: float = 0.0       # physical arena bytes (static per state)
    steps: int = 0
    generated_tokens: int = 0

    def observe_step(self, live_tokens_per_layer: Sequence[float],
                     new_tokens: int = 1,
                     reads_tokens_per_layer: Optional[Sequence[float]] = None):
        """live_tokens_per_layer: Σ over (batch, kv-heads)/H of live cache items
        for each layer at this decode step.  ``reads_tokens_per_layer`` defaults
        to live (the dense-read case)."""
        live = float(np.sum(live_tokens_per_layer))
        reads = (live if reads_tokens_per_layer is None
                 else float(np.sum(reads_tokens_per_layer)))
        self.kv_reads += reads
        self.peak_tokens = max(self.peak_tokens, live)
        self.steps += 1
        self.generated_tokens += new_tokens

    def observe_peak_bytes(self, nbytes: float):
        self.peak_bytes = max(self.peak_bytes, float(nbytes))

    def observe_saved_reads(self, reads: float):
        """Record prefill reads a prefix-cache hit avoided.  Kept on a
        separate axis: ``kv_reads`` stays the honest paid-reads integral, and
        ``kv_reads + kv_reads_saved`` is what a cold serve would have read."""
        self.kv_reads_saved += float(reads)

    def merge(self, other: "BudgetMeter") -> "BudgetMeter":
        """Concurrent merge: the two meters ran on co-resident lanes (parallel
        chains / simultaneous requests), so peak memory adds."""
        return BudgetMeter(
            kv_reads=self.kv_reads + other.kv_reads,
            kv_reads_saved=self.kv_reads_saved + other.kv_reads_saved,
            peak_tokens=self.peak_tokens + other.peak_tokens,  # parallel chains co-resident
            peak_bytes=self.peak_bytes + other.peak_bytes,
            steps=max(self.steps, other.steps),
            generated_tokens=self.generated_tokens + other.generated_tokens,
        )

    def merge_sequential(self, other: "BudgetMeter") -> "BudgetMeter":
        """Sequential merge: ``other`` ran *after* self on the same lanes
        (e.g. a request's prefill phase then decode phase), so peak memory is
        the max over time, not the sum — reads still integrate."""
        return BudgetMeter(
            kv_reads=self.kv_reads + other.kv_reads,
            kv_reads_saved=self.kv_reads_saved + other.kv_reads_saved,
            peak_tokens=max(self.peak_tokens, other.peak_tokens),
            peak_bytes=max(self.peak_bytes, other.peak_bytes),
            steps=self.steps + other.steps,
            generated_tokens=self.generated_tokens + other.generated_tokens,
        )

    def as_dict(self):
        return dataclasses.asdict(self)


def analytic_budget(
    seq_len: int, width: int, cr: float, num_layers: int, window: int = 0,
) -> Tuple[float, float]:
    """Closed-form budget for a model that hits its target CR exactly.

    Live tokens after t generated ≈ window + (t - window)/CR.  Returns
    (kv_reads, peak_tokens) summed over W chains and L layers.  Used to
    cross-check the measured meter and for large-scale projection.
    """
    t = np.arange(1, seq_len + 1, dtype=np.float64)
    live = np.where(t <= window, t, window + (t - window) / cr)
    reads = float(live.sum()) * num_layers * width
    peak = float(live[-1]) * num_layers * width
    return reads, peak


# ---------------------------------------------------------------------------
# answer aggregation
# ---------------------------------------------------------------------------


def majority_vote(answers: Sequence[Optional[str]]) -> Optional[str]:
    votes = [a for a in answers if a is not None]
    if not votes:
        return None
    return collections.Counter(votes).most_common(1)[0][0]


def pass_at_all(per_chain_pass: Sequence[bool]) -> bool:
    return any(per_chain_pass)


def exact_match_accuracy(predictions: Sequence[Optional[str]], targets: Sequence[str]) -> float:
    hits = sum(1 for p, t in zip(predictions, targets) if p is not None and p == t)
    return hits / max(len(targets), 1)


# ---------------------------------------------------------------------------
# scaling grid / Pareto utilities
# ---------------------------------------------------------------------------


def default_grid(base_len: int = 1024, crs: Sequence[float] = (1.0,)) -> List[ScalingConfig]:
    grid = []
    for cr in crs:
        for l_mult in (1, 2, 4):
            for w in (1, 2, 4, 8):
                grid.append(ScalingConfig(base_len * l_mult, w, cr))
    return grid


def pareto_frontier(points: Sequence[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """(budget, accuracy) points -> frontier sorted by budget (maximise acc)."""
    pts = sorted(points)
    frontier: List[Tuple[float, float]] = []
    best = -np.inf
    for b, a in pts:
        if a > best:
            frontier.append((b, a))
            best = a
    return frontier


def frontier_margin(a: Sequence[Tuple[float, float]], b: Sequence[Tuple[float, float]]) -> float:
    """Average accuracy gap of frontier *a* over *b* on the shared budget
    interval (paper Appendix E), linear interpolation, log-budget axis."""
    if not a or not b:
        return float("nan")
    lo = max(a[0][0], b[0][0])
    hi = min(a[-1][0], b[-1][0])
    if hi <= lo:
        # disjoint budget projections: if a's whole frontier sits at smaller
        # budgets with >= accuracy, it strictly dominates (paper Table 5 "NA"
        # case) — report the accuracy edge at a's best vs b's cheapest point
        if a[-1][0] <= b[0][0]:
            return a[-1][1] - b[0][1]
        return float("nan")
    xs = np.exp(np.linspace(np.log(lo), np.log(hi), 128))

    def interp(front, x):
        bx = np.array([p[0] for p in front])
        ax = np.array([p[1] for p in front])
        return np.interp(x, bx, ax)

    return float(np.mean(interp(a, xs) - interp(b, xs)))
