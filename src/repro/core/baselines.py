"""Training-free KV-cache baselines (paper §2.2) + DMC (§2.3).

Implemented with the same functional-cache conventions as :mod:`kv_cache` so
they slot into the identical decode loop and budget accounting:

* **TOVA** (Oren et al., 2024): keep a budget of tokens; at each step evict the
  token with the lowest *current* attention weight, summed over query heads.
* **H2O** (Zhang et al., 2023a): budget split between a recency window and
  "heavy hitters" (highest cumulative attention); evict the lowest-cumulative
  non-recent token.
* **Quest** (Tang et al., 2024): keeps the full cache; per page (fixed-size
  block) stores elementwise min/max key metadata; at each step selects the
  top-k pages by an upper-bound score and attends only to them — reducing
  memory *reads*, not memory *size*.
* **DMC** (Nawrot et al., 2024): append-or-merge. When α=1 the new (k, v) is
  accumulated into the last cache entry by a running weighted average.
* **Window** (StreamingLLM-ish): sliding window + attention sinks.

These are decode-time policies; the paper evaluates them with a standard dense
prefill up to the budget (§F.1), which we mirror in the serving engine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import block_pool
from repro.core.kv_cache import (BlockTable, HasBlockTable,
                                 LaneSliceable, _round_up,
                                 _tree_dataclass, event_mask, init_paged,
                                 prefix_block_spec, INVALID_POS)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# TOVA
# ---------------------------------------------------------------------------


@_tree_dataclass
class TOVACache(LaneSliceable, HasBlockTable):
    k: jnp.ndarray       # (B, H, P, D) — P padded to a block_p multiple
    v: jnp.ndarray
    pos: jnp.ndarray     # (B, H, P)
    valid: jnp.ndarray   # (B, H, P)
    length: jnp.ndarray  # (B,) — per lane
    blocks: BlockTable   # incremental live-block table (flash-decode)
    slots: int = dataclasses.field(metadata={"static": True})  # logical arena
    pool: Optional[block_pool.BlockPool] = None
    phys: Optional[jnp.ndarray] = None       # (B, H, NB) int32, -1 = unmapped

    @staticmethod
    def init(batch, kv_heads, budget, head_dim, dtype=jnp.bfloat16,
             block_p: int = 0, paged: bool = False,
             pool_blocks: Optional[int] = None):
        p = _round_up(budget, block_p)
        pool = phys = None
        if paged:
            pool, phys, z = init_paged(batch, kv_heads, p, head_dim, block_p,
                                       dtype, pool_blocks)
        else:
            z = jnp.zeros((batch, kv_heads, p, head_dim), dtype)
        return TOVACache(z, z,
                         jnp.full((batch, kv_heads, p), INVALID_POS, jnp.int32),
                         jnp.zeros((batch, kv_heads, p), bool),
                         jnp.zeros((batch,), jnp.int32),
                         BlockTable.init(batch, kv_heads, p, block_p),
                         budget, pool=pool, phys=phys)

    @property
    def budget(self) -> int:
        return self.slots - 1   # arena is budget + 1 (room to insert-then-evict)

    def insert(self, k_new, v_new, active=None) -> "TOVACache":
        """Insert the new token into a free *logical* slot (the arena always
        has one; physical padding slots are never allocated)."""
        p = self.k.shape[2]
        free = ~self.valid & (jnp.arange(p)[None, None] < self.slots)
        slot = jnp.argmax(free, axis=2).astype(jnp.int32)         # first free
        hit = (jnp.arange(p)[None, None] == slot[..., None])
        newly = jnp.take_along_axis(free, slot[..., None], axis=2)[..., 0]
        pool, phys = self.pool, self.phys
        if pool is not None:
            pool, phys = block_pool.token_write(
                pool, phys, slot[..., None], k_new, v_new,
                event_mask(active, slot.shape)[..., None])
            k, v = self.k, self.v       # zero-width; bytes go to the pool
        else:
            k = jnp.where(hit[..., None], k_new.astype(self.k.dtype), self.k)
            v = jnp.where(hit[..., None], v_new.astype(self.v.dtype), self.v)
        return dataclasses.replace(
            self,
            k=k, v=v,
            pos=jnp.where(hit, self.length[:, None, None], self.pos),
            valid=self.valid | hit,
            length=self.length + 1,
            blocks=self.blocks.insert(slot, newly),
            pool=pool, phys=phys,
        )

    def evict(self, attn_weights, active=None) -> "TOVACache":
        """attn_weights: (B, H, P) current-step post-softmax weights summed
        over the query heads of each group (§2.2: TOVA victim = argmin)."""
        p = self.k.shape[2]
        n_valid = jnp.sum(self.valid, axis=2)
        over = n_valid > self.budget
        scores = jnp.where(self.valid, attn_weights.astype(jnp.float32), jnp.inf)
        victim = jnp.argmin(scores, axis=2).astype(jnp.int32)
        hit = (jnp.arange(p)[None, None] == victim[..., None]) & over[..., None]
        blocks, dead = self.blocks.evict_ex(victim, over)
        pool, phys = self.pool, self.phys
        if pool is not None:
            pool, phys = block_pool.free_block(
                pool, phys, victim, dead & event_mask(active, victim.shape))
        return dataclasses.replace(
            self,
            pos=jnp.where(hit, INVALID_POS, self.pos),
            valid=self.valid & ~hit,
            blocks=blocks, pool=pool, phys=phys,
        )

    def valid_mask(self):
        return self.valid

    def positions(self):
        return self.pos

    def retained_tokens(self):
        return jnp.sum(self.valid, axis=-1)


# ---------------------------------------------------------------------------
# H2O
# ---------------------------------------------------------------------------


@_tree_dataclass
class H2OCache(LaneSliceable, HasBlockTable):
    k: jnp.ndarray       # (B, H, P, D) — P padded to a block_p multiple
    v: jnp.ndarray
    pos: jnp.ndarray
    valid: jnp.ndarray
    acc: jnp.ndarray       # (B, H, P) cumulative attention mass
    length: jnp.ndarray    # (B,) — per lane
    blocks: BlockTable     # incremental live-block table (flash-decode)
    recent_window: int = dataclasses.field(metadata={"static": True})
    slots: int = dataclasses.field(metadata={"static": True})  # logical arena
    pool: Optional[block_pool.BlockPool] = None
    phys: Optional[jnp.ndarray] = None       # (B, H, NB) int32, -1 = unmapped

    @staticmethod
    def init(batch, kv_heads, budget, head_dim, recent_window=None,
             dtype=jnp.bfloat16, block_p: int = 0, paged: bool = False,
             pool_blocks: Optional[int] = None):
        p = _round_up(budget, block_p)
        pool = phys = None
        if paged:
            pool, phys, z = init_paged(batch, kv_heads, p, head_dim, block_p,
                                       dtype, pool_blocks)
        else:
            z = jnp.zeros((batch, kv_heads, p, head_dim), dtype)
        rw = recent_window if recent_window is not None else budget // 2
        return H2OCache(z, z,
                        jnp.full((batch, kv_heads, p), INVALID_POS, jnp.int32),
                        jnp.zeros((batch, kv_heads, p), bool),
                        jnp.zeros((batch, kv_heads, p), jnp.float32),
                        jnp.zeros((batch,), jnp.int32),
                        BlockTable.init(batch, kv_heads, p, block_p),
                        rw, budget, pool=pool, phys=phys)

    @property
    def budget(self) -> int:
        return self.slots - 1

    def insert(self, k_new, v_new, active=None) -> "H2OCache":
        p = self.k.shape[2]
        free = ~self.valid & (jnp.arange(p)[None, None] < self.slots)
        slot = jnp.argmax(free, axis=2).astype(jnp.int32)
        hit = (jnp.arange(p)[None, None] == slot[..., None])
        newly = jnp.take_along_axis(free, slot[..., None], axis=2)[..., 0]
        pool, phys = self.pool, self.phys
        if pool is not None:
            pool, phys = block_pool.token_write(
                pool, phys, slot[..., None], k_new, v_new,
                event_mask(active, slot.shape)[..., None])
            k, v = self.k, self.v       # zero-width; bytes go to the pool
        else:
            k = jnp.where(hit[..., None], k_new.astype(self.k.dtype), self.k)
            v = jnp.where(hit[..., None], v_new.astype(self.v.dtype), self.v)
        return dataclasses.replace(
            self,
            k=k, v=v,
            pos=jnp.where(hit, self.length[:, None, None], self.pos),
            valid=self.valid | hit,
            acc=jnp.where(hit, 0.0, self.acc),
            length=self.length + 1,
            blocks=self.blocks.insert(slot, newly),
            pool=pool, phys=phys,
        )

    def evict(self, attn_weights, active=None) -> "H2OCache":
        """Accumulate attention mass; evict the lowest-cumulative token outside
        the recency window when over budget (§2.2)."""
        p = self.k.shape[2]
        acc = self.acc + jnp.where(self.valid, attn_weights.astype(jnp.float32), 0.0)
        over = jnp.sum(self.valid, axis=2) > self.budget
        recent = self.pos >= (self.length - self.recent_window)[:, None, None]
        scores = jnp.where(self.valid & ~recent, acc, jnp.inf)
        any_evictable = jnp.any(jnp.isfinite(scores), axis=2)
        oldest = jnp.argmin(jnp.where(self.valid, self.pos, INVALID_POS), axis=2)
        victim = jnp.where(any_evictable, jnp.argmin(scores, axis=2), oldest).astype(jnp.int32)
        hit = (jnp.arange(p)[None, None] == victim[..., None]) & over[..., None]
        blocks, dead = self.blocks.evict_ex(victim, over)
        pool, phys = self.pool, self.phys
        if pool is not None:
            pool, phys = block_pool.free_block(
                pool, phys, victim, dead & event_mask(active, victim.shape))
        return dataclasses.replace(
            self,
            pos=jnp.where(hit, INVALID_POS, self.pos),
            valid=self.valid & ~hit,
            acc=jnp.where(hit, 0.0, acc),
            blocks=blocks, pool=pool, phys=phys,
        )

    def valid_mask(self):
        return self.valid

    def positions(self):
        return self.pos

    def retained_tokens(self):
        return jnp.sum(self.valid, axis=-1)


# ---------------------------------------------------------------------------
# Quest
# ---------------------------------------------------------------------------


@_tree_dataclass
class QuestCache(LaneSliceable):
    """Full cache + per-page min/max key metadata.  Pages are contiguous.

    ``page_size`` and ``top_pages`` are static; the *reads* accounting (what
    Quest actually saves) is ``top_pages * page_size`` per step per head.
    """

    k: jnp.ndarray        # (B, H, S, D)
    v: jnp.ndarray
    kmin: jnp.ndarray     # (B, H, S/page, D)
    kmax: jnp.ndarray
    length: jnp.ndarray   # (B,) — per lane
    page_size: int = dataclasses.field(metadata={"static": True})
    top_pages: int = dataclasses.field(metadata={"static": True})
    pool: Optional[block_pool.BlockPool] = None
    phys: Optional[jnp.ndarray] = None       # (B, H, NP) int32, -1 = unmapped

    @staticmethod
    def init(batch, kv_heads, max_len, head_dim, page_size, top_pages,
             dtype=jnp.bfloat16, paged: bool = False,
             pool_blocks: Optional[int] = None):
        assert max_len % page_size == 0
        n_pages = max_len // page_size
        pool = phys = None
        if paged:
            # pool page granularity == Quest's page_size, so the selected-page
            # block table indexes pool pages directly
            pool, phys, z = init_paged(batch, kv_heads, max_len, head_dim,
                                       page_size, dtype, pool_blocks)
        else:
            z = jnp.zeros((batch, kv_heads, max_len, head_dim), dtype)
        return QuestCache(
            z, z,
            jnp.full((batch, kv_heads, n_pages, head_dim), jnp.inf, jnp.float32),
            jnp.full((batch, kv_heads, n_pages, head_dim), -jnp.inf, jnp.float32),
            jnp.zeros((batch,), jnp.int32), page_size, top_pages,
            pool=pool, phys=phys)

    def append(self, k_new, v_new, active=None) -> "QuestCache":
        """k_new/v_new: (B, H, 1, D), written at each lane's own length."""
        t = self.length                                     # (B,)
        pool, phys = self.pool, self.phys
        if pool is not None:
            b, h = self.k.shape[:2]
            slot = jnp.broadcast_to(t[:, None, None], (b, h, 1))
            pool, phys = block_pool.token_write(
                pool, phys, slot, k_new, v_new,
                event_mask(active, (b, h, 1)))
            k, v = self.k, self.v       # zero-width; bytes go to the pool
        else:
            def upd(buf, new, off):
                return jax.lax.dynamic_update_slice_in_dim(buf, new, off, axis=1)

            k = jax.vmap(upd)(self.k, k_new.astype(self.k.dtype), t)
            v = jax.vmap(upd)(self.v, v_new.astype(self.v.dtype), t)
        page = t // self.page_size                          # (B,)
        kf = k_new[..., 0, :].astype(jnp.float32)
        n_pages = self.kmin.shape[2]
        hit = (jnp.arange(n_pages)[None, :] == page[:, None])[:, None, :, None]
        kmin = jnp.where(hit, jnp.minimum(self.kmin, kf[..., None, :]), self.kmin)
        kmax = jnp.where(hit, jnp.maximum(self.kmax, kf[..., None, :]), self.kmax)
        return dataclasses.replace(self, k=k, v=v, kmin=kmin, kmax=kmax,
                                   length=t + 1, pool=pool, phys=phys)

    def select_pages(self, q: jnp.ndarray) -> jnp.ndarray:
        """Upper-bound page scores (§2.2): sum_d max(q_d*kmin_d, q_d*kmax_d).

        q: (B, H, D) — per-KV-head (group-pooled) query.  Returns a bool page
        mask (B, H, n_pages) marking the top-k live pages.
        """
        qf = q.astype(jnp.float32)[..., None, :]
        ub = jnp.sum(jnp.maximum(qf * self.kmin, qf * self.kmax), axis=-1)  # (B,H,P)
        n_pages = self.kmin.shape[2]
        live = (jnp.arange(n_pages)[None, :] * self.page_size) \
            < self.length[:, None]                          # (B, n_pages)
        ub = jnp.where(live[:, None], ub, -jnp.inf)
        k = min(self.top_pages, n_pages)
        thresh = jax.lax.top_k(ub, k)[0][..., -1:]
        sel = (ub >= thresh) & live[:, None]
        return sel

    def token_mask_from_pages(self, page_mask: jnp.ndarray) -> jnp.ndarray:
        s = self.k.shape[2]
        token_pages = jnp.arange(s) // self.page_size
        tok = jnp.take(page_mask, token_pages, axis=2)
        written = jnp.arange(s)[None, None, :] < self.length[:, None, None]
        return tok & written

    def block_table_from_pages(self, page_mask: jnp.ndarray):
        """Compact the selected-page bool mask into a flash-decode block
        table ``(tbl (B,H,NP) int32, n (B,H) int32)``: selected page ids
        first (ascending), so the kernel fetches exactly the top-k pages —
        Quest's reads-sparsity realized as HBM traffic, not just metering.
        Kept full-width (NP, not top_pages) because threshold ties can
        select more than ``top_pages`` pages; the kernel's per-(b,h) ``n``
        early-exits the unselected tail either way."""
        tbl = jnp.argsort(~page_mask, axis=-1, stable=True).astype(jnp.int32)
        n = jnp.sum(page_mask, axis=-1).astype(jnp.int32)
        return tbl, n

    def valid_mask(self):
        # length-prefix occupancy; mapped pool pages == blocks with any live
        # slot, the invariant the generic pooled prefix-import relies on
        s = self.k.shape[2]
        return jnp.arange(s)[None, None, :] < self.length[:, None, None]

    def positions(self):
        s = self.k.shape[2]
        return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, None],
                                self.k.shape[:2] + (s,))

    def retained_tokens(self):
        # memory footprint is FULL — that is Quest's trade-off
        s = self.k.shape[2]
        written = jnp.minimum(self.length, s)               # (B,)
        return jnp.broadcast_to(written[:, None], self.k.shape[:2])

    def reads_per_step(self):
        n_live_pages = jnp.minimum((self.length + self.page_size - 1) // self.page_size,
                                   self.top_pages)
        return n_live_pages * self.page_size                # (B,)


# ---------------------------------------------------------------------------
# DMC (append-or-merge)
# ---------------------------------------------------------------------------


@_tree_dataclass
class DMCCache(LaneSliceable):
    """Dynamic Memory Compression inference cache (Nawrot et al., 2024).

    α=1 ⇒ accumulate (k, v) into the most recent entry by weighted average
    with running weight z;  α=0 ⇒ append a fresh entry.
    """

    k: jnp.ndarray        # (B, H, P, D) fp32 accumulators — P padded to a
    #                       block_p multiple; occupancy is a count-prefix so
    #                       the live-block table is derived, not stored
    v: jnp.ndarray
    z: jnp.ndarray        # (B, H, P) accumulation weights
    count: jnp.ndarray    # (B, H) number of live entries
    length: jnp.ndarray   # (B,) — per lane
    pos: jnp.ndarray      # (B, H, P) newest-contribution position per entry
    block_p: int = dataclasses.field(metadata={"static": True}, default=0)
    pool: Optional[block_pool.BlockPool] = None   # fp32 pages (accumulators)
    phys: Optional[jnp.ndarray] = None       # (B, H, NB) int32, -1 = unmapped

    @staticmethod
    def init(batch, kv_heads, num_slots, head_dim, block_p: int = 0,
             paged: bool = False, pool_blocks: Optional[int] = None):
        p = _round_up(num_slots, block_p)
        pool = phys = None
        if paged:
            pool, phys, z4 = init_paged(batch, kv_heads, p, head_dim, block_p,
                                        jnp.float32, pool_blocks)
        else:
            z4 = jnp.zeros((batch, kv_heads, p, head_dim), jnp.float32)
        return DMCCache(z4, z4,
                        jnp.zeros((batch, kv_heads, p), jnp.float32),
                        jnp.zeros((batch, kv_heads), jnp.int32),
                        jnp.zeros((batch,), jnp.int32),
                        jnp.zeros((batch, kv_heads, p), jnp.int32), block_p,
                        pool=pool, phys=phys)

    def block_spec(self):
        tbl, n = prefix_block_spec(self.count, self.k.shape[2], self.block_p,
                                   self.k.shape[1])
        return tbl, n, self.block_p

    def step(self, k_new, v_new, alpha, omega=None, active=None) -> "DMCCache":
        """alpha: (B, H) bool merge decision; omega: optional (B, H) importance
        weight for the weighted average (defaults to 1)."""
        b, h, p = self.k.shape[:3]
        if omega is None:
            omega = jnp.ones((b, h), jnp.float32)
        kf = k_new[..., 0, :].astype(jnp.float32)
        vf = v_new[..., 0, :].astype(jnp.float32)
        merge = alpha & (self.count > 0)
        tgt = jnp.where(merge, jnp.maximum(self.count - 1, 0), self.count)  # slot index
        p_idx = jnp.arange(p)
        hit = p_idx[None, None] == tgt[..., None]
        pool, phys = self.pool, self.phys
        if pool is not None:
            # row-level twin of the dense formula below: gather the merge
            # target's accumulator row, blend, write back through the page
            # map (same op order, so bitwise-equal at slot ``tgt``)
            z_tgt = jnp.take_along_axis(self.z, tgt[..., None], axis=2)[..., 0]
            z_old_r = jnp.where(merge, z_tgt, 0.0)
            z_new_r = z_old_r + omega
            k_old = block_pool.gather_rows(pool.k, phys, tgt, self.block_p)
            v_old = block_pool.gather_rows(pool.v, phys, tgt, self.block_p)
            k_row = (jnp.where(merge[..., None], k_old, 0.0) * z_old_r[..., None]
                     + kf * omega[..., None]) / z_new_r[..., None]
            v_row = (jnp.where(merge[..., None], v_old, 0.0) * z_old_r[..., None]
                     + vf * omega[..., None]) / z_new_r[..., None]
            # tgt == P (arena full) is a silent drop in the dense path; mask
            # it here too so the clamp in token_write can't hit a live page
            wm = event_mask(active, (b, h)) & (tgt < p)
            pool, phys = block_pool.token_write(
                pool, phys, tgt[..., None], k_row[..., None, :],
                v_row[..., None, :], wm[..., None])
            k, v = self.k, self.v       # zero-width; bytes go to the pool
        else:
            z_old = jnp.where(merge[..., None], self.z, 0.0)
            z_new = z_old + omega[..., None]
            k_upd = (jnp.where(merge[..., None, None], self.k, 0.0) * z_old[..., None]
                     + kf[..., None, :] * omega[..., None, None]) / z_new[..., None]
            v_upd = (jnp.where(merge[..., None, None], self.v, 0.0) * z_old[..., None]
                     + vf[..., None, :] * omega[..., None, None]) / z_new[..., None]
            k = jnp.where(hit[..., None], k_upd, self.k)
            v = jnp.where(hit[..., None], v_upd, self.v)
        z = jnp.where(hit, jnp.where(merge[..., None], self.z, 0.0) + omega[..., None],
                      self.z)
        count = jnp.where(merge, self.count, self.count + 1)
        # a merged entry is "as recent as" its newest contribution: stamp the
        # touched slot with the current position so layer_map window layers
        # can mask DMC entries (no active masking — lane_select rolls back)
        pos = jnp.where(hit, self.length[:, None, None], self.pos)
        return dataclasses.replace(self, k=k, v=v, z=z, count=count,
                                   length=self.length + 1, pos=pos,
                                   pool=pool, phys=phys)

    def valid_mask(self):
        p = self.k.shape[2]
        return jnp.arange(p)[None, None] < self.count[..., None]

    def positions(self):
        return self.pos

    def retained_tokens(self):
        return self.count
