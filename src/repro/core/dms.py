"""Dynamic Memory Sparsification (DMS) — the paper's core technique (§3).

Everything that defines DMS lives here:

* α-logit extraction ("borrowed neuron", Appendix B) and Gumbel-sigmoid
  relaxation (Eq. 1),
* the delayed-eviction additive attention mask ``M_alpha`` (Fig. 2b) — built
  lazily from the per-token α vector, never materialised inside kernels,
* the one-sided L1 auxiliary compression loss and the linear CR schedule,
* binarised inference decisions.

Shapes convention: ``alpha`` is per KV head: ``(batch, kv_heads, seq)``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import DMSConfig

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free on bf16
_EPS = 1e-6


# ---------------------------------------------------------------------------
# alpha prediction
# ---------------------------------------------------------------------------


def alpha_logits_from_q(q_raw: jnp.ndarray, num_kv_heads: int, bias: float) -> jnp.ndarray:
    """Extract eviction logits from the raw (pre-RoPE) query projection.

    Appendix B: "borrow the first neuron from the first query head in each
    query group".  ``q_raw``: (B, T, Hq, Dh).  Returns (B, Hkv, T).
    """
    b, t, hq, _ = q_raw.shape
    g = hq // num_kv_heads
    first = q_raw[:, :, ::g, 0]                       # (B, T, Hkv)
    return first.astype(jnp.float32).transpose(0, 2, 1) + bias


def zero_borrowed_neuron(q: jnp.ndarray, num_kv_heads: int, scale: float = 0.0) -> jnp.ndarray:
    """Zero (or phase-1 scale) the borrowed neuron so it no longer affects attention.

    Phase-1 retrofit (App. B) passes ``scale = 1 - t/n_t``; the main phase
    passes 0.  ``q``: (B, T, Hq, Dh).
    """
    hq = q.shape[2]
    g = hq // num_kv_heads
    mask = jnp.ones((hq, q.shape[3]), dtype=q.dtype)
    mask = mask.at[::g, 0].set(jnp.asarray(scale, dtype=q.dtype))
    return q * mask


def gumbel_sigmoid(
    logits: jnp.ndarray, tau: float, rng: Optional[jax.Array], hard: bool = False
) -> jnp.ndarray:
    """Binary-concrete / Gumbel-sigmoid sample in [0, 1] (Eq. 1).

    With ``rng=None`` returns the deterministic relaxation sigmoid(logits/tau).
    ``hard=True`` uses a straight-through estimator.
    """
    logits = logits.astype(jnp.float32)
    if rng is not None:
        u = jax.random.uniform(rng, logits.shape, minval=_EPS, maxval=1.0 - _EPS)
        noise = jnp.log(u) - jnp.log1p(-u)            # logistic noise
        logits = logits + noise
    y = jax.nn.sigmoid(logits / tau)
    if hard:
        y_hard = (y > 0.5).astype(y.dtype)
        y = y + jax.lax.stop_gradient(y_hard - y)
    return y


def binary_alpha(logits: jnp.ndarray) -> jnp.ndarray:
    """Inference-time decision  α^bin = round(sigmoid(logit))  (§3.3)."""
    return (jax.nn.sigmoid(logits.astype(jnp.float32)) > 0.5)


# ---------------------------------------------------------------------------
# delayed-eviction mask
# ---------------------------------------------------------------------------


def eviction_log_survival(alpha: jnp.ndarray) -> jnp.ndarray:
    """log(1 - α_j), clamped — the additive mask contribution of key j."""
    return jnp.log1p(-jnp.clip(alpha.astype(jnp.float32), 0.0, 1.0 - _EPS))


def build_dms_mask(
    alpha: jnp.ndarray,
    q_positions: jnp.ndarray,
    k_positions: jnp.ndarray,
    cfg: DMSConfig,
    causal: bool = True,
    local_window: Optional[int] = None,
) -> jnp.ndarray:
    """Materialise the additive attention mask ``M_alpha`` (training, Fig. 2b).

    Reference path only — kernels consume ``alpha`` directly.

    alpha:        (B, Hkv, Tk)   relaxed eviction decisions for each key.
    q_positions:  (Tq,) absolute positions of queries.
    k_positions:  (Tk,) absolute positions of keys.
    Returns mask: (B, Hkv, Tq, Tk), entries in (-inf, 0].

    Delayed eviction: key j's mask applies to queries i with  i - j >= w .
    Immediate eviction (ablation): applies to all i > j.
    """
    i = q_positions[:, None].astype(jnp.int32)
    j = k_positions[None, :].astype(jnp.int32)
    delay = 1 if cfg.immediate_eviction else cfg.window
    in_evict_zone = (i - j) >= delay                            # (Tq, Tk)
    log_surv = eviction_log_survival(alpha)                     # (B, Hkv, Tk)
    mask = jnp.where(in_evict_zone[None, None], log_surv[:, :, None, :], 0.0)
    if causal:
        mask = jnp.where((j <= i)[None, None], mask, NEG_INF)
    if local_window is not None:
        mask = jnp.where(((i - j) < local_window)[None, None], mask, NEG_INF)
    return mask


def retained_after_prefill(
    alpha_bin: jnp.ndarray, seq_len: int, cfg: DMSConfig
) -> jnp.ndarray:
    """Which tokens remain in the cache after prefilling ``seq_len`` tokens.

    A token j is physically evicted once position j + w has been generated,
    i.e. after prefill token j is gone iff  α_j = 1  and  j <= seq_len - 1 - w.
    Returns bool (B, Hkv, T): True = retained.
    """
    t = jnp.arange(seq_len)
    delay = 1 if cfg.immediate_eviction else cfg.window
    executed = t <= (seq_len - 1 - delay)
    return ~(alpha_bin & executed[None, None, :])


# ---------------------------------------------------------------------------
# auxiliary loss + schedule
# ---------------------------------------------------------------------------


def cr_schedule(step: jnp.ndarray | int, cfg: DMSConfig) -> jnp.ndarray:
    """CR(t) = min(1 + t / steps_per_cr_unit, target)  (§4)."""
    cr = 1.0 + jnp.asarray(step, jnp.float32) / cfg.steps_per_cr_unit
    return jnp.minimum(cr, cfg.target_cr)


def target_alpha(step: jnp.ndarray | int, cfg: DMSConfig) -> jnp.ndarray:
    """α*(t) = 1 - 1/CR(t): the annealed mean-eviction target."""
    return 1.0 - 1.0 / cr_schedule(step, cfg)


def aux_compression_loss(alpha_sum: jnp.ndarray, alpha_count: jnp.ndarray,
                         step: jnp.ndarray | int, cfg: DMSConfig) -> jnp.ndarray:
    """One-sided L1 loss (§3.2), normalised by the α count for scale stability.

    L_aux = max(α* · N − Σ α, 0) / N  where N = L·H·T aggregated over layers.
    """
    a_star = target_alpha(step, cfg)
    return jnp.maximum(a_star * alpha_count - alpha_sum, 0.0) / jnp.maximum(alpha_count, 1.0)


# ---------------------------------------------------------------------------
# convenience: full training-mode alpha pipeline for one attention layer
# ---------------------------------------------------------------------------


def train_alphas(
    q_raw: jnp.ndarray,
    num_kv_heads: int,
    cfg: DMSConfig,
    rng: Optional[jax.Array],
    deterministic: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(relaxed alpha, zeroed q) for the training path."""
    logits = alpha_logits_from_q(q_raw, num_kv_heads, cfg.logit_bias)
    alpha = gumbel_sigmoid(logits, cfg.tau, None if deterministic else rng)
    q = zero_borrowed_neuron(q_raw, num_kv_heads)
    return alpha, q


def infer_alphas(q_raw: jnp.ndarray, num_kv_heads: int,
                 cfg: DMSConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(binary alpha, zeroed q) for the inference path."""
    logits = alpha_logits_from_q(q_raw, num_kv_heads, cfg.logit_bias)
    return binary_alpha(logits), zero_borrowed_neuron(q_raw, num_kv_heads)
