"""Global paged KV block pool: on-demand lane arenas with copy-on-write fork.

Fixed per-lane arenas make device memory scale with *provisioned* capacity:
at CR8 roughly 7/8 of every arena is reservation that compression can never
give back (the capacity twin of the dead-block-DMA pitfall — see
docs/kernels.md).  This module replaces per-lane K/V storage with ONE shared
arena of ``block_p``-sized pages per cache instance:

* ``BlockPool`` holds the page arena (``k``/``v``: (NPOOL, block_p, Dh)), a
  refcount vector (``ref == 0`` means free) and observability counters.
* Each cache keeps a per-(lane, head) *page map* ``phys``: (B, H, NB) int32,
  ``-1`` = unmapped.  Logical slot ``s`` of block ``b = s // block_p`` lives
  at pool page ``phys[lane, head, b]``.
* Pages are allocated **on first write** to an unmapped block
  (:func:`token_write`), freed when the cache's incremental
  :class:`~repro.core.kv_cache.BlockTable` reports a block's live-slot count
  hit zero (:func:`free_block`), and reclaimed wholesale at EOS
  (:func:`recount` after the metadata reset).
* Fork is **copy-on-write**: :func:`recount` after a lane gather bumps
  refcounts without touching page bytes; the first divergent write to a page
  with ``ref > 1`` copies that one page (:func:`token_write`'s CoW path) —
  a W-way fork moves zero arena bytes at fork time.

Everything is functional pytree code: the pool rides inside the cache pytree
through ``jit`` / ``scan`` / ``vmap`` unchanged.  All mutation helpers accept
a boolean event mask so inactive scheduler lanes produce no pool events
(their per-lane metadata is rolled back by ``lane_select``; the pool itself
is shared and must therefore never be speculatively mutated).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _tree_dataclass(cls):
    """Same pytree registration idiom as kv_cache._tree_dataclass (duplicated
    here so kv_cache can import this module without a cycle)."""
    cls = dataclass(cls)
    child_names = [f.name for f in dataclasses.fields(cls)
                   if not f.metadata.get("static")]
    static_names = [f.name for f in dataclasses.fields(cls)
                    if f.metadata.get("static")]

    def flatten_with_keys(o):
        return (
            [(jax.tree_util.GetAttrKey(n), getattr(o, n)) for n in child_names],
            tuple(getattr(o, n) for n in static_names),
        )

    def flatten(o):
        return (
            tuple(getattr(o, n) for n in child_names),
            tuple(getattr(o, n) for n in static_names),
        )

    def unflatten(aux, children):
        kw = dict(zip(child_names, children))
        kw.update(zip(static_names, aux))
        return cls(**kw)

    jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys, unflatten,
                                            flatten_func=flatten)
    return cls


@_tree_dataclass
class BlockPool:
    """Shared page arena + free list (``ref == 0``) + counters.

    One pool instance backs ALL lanes and kv-heads of one cache instance
    (i.e. one per pattern-position per layer stack); distinct caches never
    share a pool.  ``ref[p]`` is the number of (lane, head, block) map
    entries pointing at page ``p`` — CoW sharing after fork is ``ref > 1``.
    """

    k: jnp.ndarray            # (NPOOL, block_p, Dh)
    v: jnp.ndarray            # (NPOOL, block_p, Dh)
    ref: jnp.ndarray          # (NPOOL,) int32 — 0 = free page
    cow_copies: jnp.ndarray   # () int32 — pages copied by divergent writes
    alloc_events: jnp.ndarray  # () int32 — successful page allocations
    high_water: jnp.ndarray   # () int32 — max pages simultaneously allocated
    exhausted: jnp.ndarray    # () bool — an allocation ever failed

    block_p: int = dataclasses.field(metadata={"static": True}, default=0)

    @staticmethod
    def init(num_blocks: int, block_p: int, head_dim: int,
             dtype=jnp.bfloat16) -> "BlockPool":
        z = jnp.zeros((num_blocks, block_p, head_dim), dtype)
        return BlockPool(
            k=z, v=z,
            ref=jnp.zeros((num_blocks,), jnp.int32),
            cow_copies=jnp.zeros((), jnp.int32),
            alloc_events=jnp.zeros((), jnp.int32),
            high_water=jnp.zeros((), jnp.int32),
            exhausted=jnp.zeros((), bool),
            block_p=block_p,
        )

    @property
    def num_blocks(self) -> int:
        return self.ref.shape[-1]


# ---------------------------------------------------------------------------
# Allocation
# ---------------------------------------------------------------------------


def alloc(pool: BlockPool, need: jnp.ndarray
          ) -> Tuple[BlockPool, jnp.ndarray, jnp.ndarray]:
    """Grab one free page per True entry of ``need`` (M,).

    Deterministic lowest-free-id-first order.  Returns ``(pool, page, ok)``;
    where ``ok`` is False the pool was exhausted — the caller must drop the
    write (``exhausted`` is latched for observability, other lanes' pages are
    never touched).  A dropped write silently corrupts the victim lane's
    decode, so the serving scheduler must read the latch at its tick boundary
    and fail/preempt rather than keep decoding (see
    ``serving/scheduler.py`` and :func:`clear_flags`)."""
    npool = pool.num_blocks
    free = pool.ref == 0
    n_free = jnp.sum(free.astype(jnp.int32))
    order = jnp.argsort(~free).astype(jnp.int32)        # stable: free ids first
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1       # per-event free-list rank
    ok = need & (rank < n_free)
    page = order[jnp.clip(rank, 0, npool - 1)]
    ref = pool.ref.at[jnp.where(ok, page, npool)].add(1, mode="drop")
    used = npool - jnp.sum((ref == 0).astype(jnp.int32))
    pool = dataclasses.replace(
        pool, ref=ref,
        alloc_events=pool.alloc_events + jnp.sum(ok.astype(jnp.int32)),
        high_water=jnp.maximum(pool.high_water, used),
        exhausted=pool.exhausted | jnp.any(need & ~ok))
    return pool, page, ok


def clear_flags(pool: BlockPool) -> BlockPool:
    """Un-latch ``exhausted`` after the failure has been handled host-side.

    The latch is sticky device state by design (a dropped write anywhere in a
    chunk must survive to the tick boundary); once the scheduler has failed
    the affected requests and reclaimed their pages, leaving it set would
    condemn every *later* request on the same pool.  See the scheduler's
    exhausted backstop and docs/serving.md "Failure semantics & preemption"
    for the dropped-write pitfall this closes."""
    return dataclasses.replace(pool, exhausted=jnp.zeros_like(pool.exhausted))


def recount(phys: jnp.ndarray, num_blocks: int) -> jnp.ndarray:
    """Recompute ``ref`` as the multiplicity of each page in ``phys``.

    ``phys``: (..., B, H, NB) with arbitrary leading axes (stacked
    superblocks); returns (..., NPOOL) int32.  Used by the whole-lane
    lifecycle ops (fork gather, reclaim, prefix import) where incremental
    updates would be error-prone — CoW refcounts reach zero exactly when the
    last mapping disappears, by construction."""
    lead = phys.shape[:-3]
    flat = phys.reshape(lead + (-1,))
    ids = jnp.arange(num_blocks, dtype=jnp.int32)
    return jnp.sum((flat[..., None] == ids).astype(jnp.int32), axis=-2)


def set_refcounts(pool: BlockPool, phys: jnp.ndarray) -> BlockPool:
    return dataclasses.replace(pool, ref=recount(phys, pool.num_blocks))


# ---------------------------------------------------------------------------
# Write path (alloc-on-first-write + copy-on-write)
# ---------------------------------------------------------------------------


def token_write(pool: BlockPool, phys: jnp.ndarray, slot: jnp.ndarray,
                k_rows: jnp.ndarray, v_rows: jnp.ndarray, mask: jnp.ndarray
                ) -> Tuple[BlockPool, jnp.ndarray]:
    """Write token rows at logical ``slot`` through the page map.

    ``slot``/``mask``: (B, H, T); ``k_rows``/``v_rows``: (B, H, T, Dh).
    Per masked event: the target block is mapped on demand (first write to an
    unmapped block allocates a page; a write to a CoW-shared page copies it
    first).  Exhaustion drops the affected writes and latches
    ``pool.exhausted`` — shared pages are never corrupted.
    """
    b, h, t = slot.shape
    nb = phys.shape[-1]
    bp = pool.block_p
    npool = pool.num_blocks
    blk = jnp.clip(slot // bp, 0, nb - 1)                 # (B,H,T)
    off = jnp.clip(slot - blk * bp, 0, bp - 1)
    cur = jnp.take_along_axis(phys, blk, axis=2)          # (B,H,T) mapped page

    # first masked occurrence of each block within a (lane, head) this call:
    # only that event decides alloc/CoW; later same-block events follow the
    # updated map (multi-token prefill chunks land in one page)
    same = blk[..., :, None] == blk[..., None, :]          # (B,H,T,T)
    earlier = jnp.tril(jnp.ones((t, t), bool), -1)
    dup = jnp.any(same & earlier & mask[..., None, :], axis=-1)
    first = mask & ~dup

    ref_cur = pool.ref[jnp.clip(cur, 0, npool - 1)]
    need_alloc = first & (cur < 0)
    need_cow = first & (cur >= 0) & (ref_cur > 1)
    need = need_alloc | need_cow

    flat = lambda a: a.reshape(-1)
    needf, curf = flat(need), flat(cur)
    pool, page, ok = alloc(pool, needf)

    # CoW: copy the shared page's bytes into the fresh page, drop one ref
    cowf = flat(need_cow) & ok
    src = jnp.clip(curf, 0, npool - 1)
    dst = jnp.where(cowf, page, npool)
    pool = dataclasses.replace(
        pool,
        k=pool.k.at[dst].set(pool.k[src], mode="drop"),
        v=pool.v.at[dst].set(pool.v[src], mode="drop"),
        ref=pool.ref.at[jnp.where(cowf, src, npool)].add(-1, mode="drop"),
        cow_copies=pool.cow_copies + jnp.sum(cowf.astype(jnp.int32)))

    # remap: first events with a fresh page point their block at it
    bi = jnp.repeat(jnp.arange(b), h * t)
    hi = jnp.tile(jnp.repeat(jnp.arange(h), t), b)
    apply = needf & ok
    phys = phys.at[bi, hi, jnp.where(apply, flat(blk), nb)].set(
        page, mode="drop")

    # failed allocations poison their block for this call: every event on a
    # failed block (not just the first) drops its write
    bad = jnp.zeros((b, h, nb), bool).at[
        bi, hi, jnp.where(needf & ~ok, flat(blk), nb)].set(True, mode="drop")

    # the actual row writes, through the post-remap map
    tgt = jnp.take_along_axis(phys, blk, axis=2)          # (B,H,T)
    badf = flat(jnp.take_along_axis(bad, blk, axis=2))
    wmask = flat(mask) & (flat(tgt) >= 0) & ~badf
    wt = jnp.where(wmask, flat(tgt), npool)
    offf = flat(off)
    pool = dataclasses.replace(
        pool,
        k=pool.k.at[wt, offf].set(
            k_rows.reshape(-1, k_rows.shape[-1]).astype(pool.k.dtype),
            mode="drop"),
        v=pool.v.at[wt, offf].set(
            v_rows.reshape(-1, v_rows.shape[-1]).astype(pool.v.dtype),
            mode="drop"))
    return pool, phys


def free_block(pool: BlockPool, phys: jnp.ndarray, slot: jnp.ndarray,
               mask: jnp.ndarray) -> Tuple[BlockPool, jnp.ndarray]:
    """Unmap the block containing ``slot`` (B, H) where ``mask`` is True.

    Fired when the cache's BlockTable reports the block's live-slot count hit
    zero (``evict_ex``'s dead mask): the page's refcount drops and the page
    returns to the free list once its last sharer lets go."""
    nb = phys.shape[-1]
    npool = pool.num_blocks
    bp = pool.block_p
    blk = jnp.clip(slot // bp, 0, nb - 1)                 # (B,H)
    cur = jnp.take_along_axis(phys, blk[..., None], axis=2)[..., 0]
    apply = mask & (cur >= 0)
    ref = pool.ref.at[jnp.where(apply, cur, npool)].add(-1, mode="drop")
    b, h = blk.shape
    bi = jnp.repeat(jnp.arange(b), h)
    hi = jnp.tile(jnp.arange(h), b)
    phys = phys.at[bi, hi,
                   jnp.where(apply.reshape(-1), blk.reshape(-1), nb)].set(
        -1, mode="drop")
    return dataclasses.replace(pool, ref=ref), phys


# ---------------------------------------------------------------------------
# Read path
# ---------------------------------------------------------------------------


def dense_kv(pool: BlockPool, phys: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gather a lane-major dense (B, H, P, Dh) view (unmapped blocks read as
    zero).  This is the reference attention path; under the flash kernel the
    gather is dead code — the kernel streams pool pages directly."""
    b, h, nb = phys.shape
    bp, dh = pool.k.shape[-2:]
    idx = jnp.clip(phys, 0, pool.num_blocks - 1)
    mapped = (phys >= 0)[..., None, None]
    k = jnp.where(mapped, pool.k[idx], 0).reshape(b, h, nb * bp, dh)
    v = jnp.where(mapped, pool.v[idx], 0).reshape(b, h, nb * bp, dh)
    return k, v


def gather_rows(arr: jnp.ndarray, phys: jnp.ndarray, slot: jnp.ndarray,
                block_p: int) -> jnp.ndarray:
    """Read one token row per (lane, head): ``slot`` (B, H) -> (B, H, Dh).
    Unmapped slots read as zero (DMC's merge target before first write)."""
    nb = phys.shape[-1]
    npool = arr.shape[0]
    blk = jnp.clip(slot // block_p, 0, nb - 1)
    off = jnp.clip(slot - blk * block_p, 0, block_p - 1)
    page = jnp.take_along_axis(phys, blk[..., None], axis=2)[..., 0]
    rows = arr[jnp.clip(page, 0, npool - 1), off]
    return jnp.where((page >= 0)[..., None], rows, 0)


def translate_table(phys: jnp.ndarray, tbl: jnp.ndarray) -> jnp.ndarray:
    """Map a logical BlockTable (B, H, NB_tbl) of block ids into pool page
    ids through ``phys`` — the table the paged flash kernel prefetches.
    Stale entries past each row's ``n`` may translate to -1; they are
    clamped (the kernel's live-count guard never dereferences them)."""
    nb = phys.shape[-1]
    return jnp.take_along_axis(phys, jnp.clip(tbl, 0, nb - 1), axis=2)


# ---------------------------------------------------------------------------
# Observability
# ---------------------------------------------------------------------------


def stats(pool: BlockPool, phys: jnp.ndarray,
          live_tokens: Optional[jnp.ndarray] = None) -> dict:
    """Host-side pool counters (handles stacked superblock leading axes).

    ``fragmentation``: share of mapped slot capacity not holding a live
    token (padded-vs-packed waste *inside* allocated pages)."""
    import numpy as np
    ref = np.asarray(pool.ref)
    physv = np.asarray(phys)
    bp = pool.block_p
    nsb = int(np.prod(ref.shape[:-1])) if ref.ndim > 1 else 1
    allocated = int((ref > 0).sum())
    total = int(ref.size)
    mapped_entries = int((physv >= 0).sum())      # per-sharer mapped blocks
    out = {
        "pool_blocks": total,
        "allocated_blocks": allocated,
        "free_blocks": total - allocated,
        "shared_blocks": int((ref > 1).sum()),
        "mapped_entries": mapped_entries,
        "cow_copies": int(np.asarray(pool.cow_copies).sum()),
        "alloc_events": int(np.asarray(pool.alloc_events).sum()),
        "high_water_blocks": int(np.asarray(pool.high_water).sum()),
        "exhausted": bool(np.asarray(pool.exhausted).any()),
        "superblocks": nsb,
    }
    if live_tokens is not None:
        live = float(np.asarray(live_tokens).sum())
        cap = float(mapped_entries * bp)
        out["live_tokens"] = int(live)
        out["fragmentation"] = 1.0 - live / cap if cap else 0.0
    return out
