"""Retrofitting losses: logit distillation + DMS auxiliary loss (paper §3.2, §4).

The paper retrofits via logit distillation (Hinton et al., 2015): the vanilla
LLM is the teacher, the DMS model the student;  L = L_D + L_aux.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import DMSConfig
from repro.core import dms as dms_lib


def kl_logit_distillation(
    student_logits: jnp.ndarray,
    teacher_logits: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
    temperature: float = 1.0,
) -> jnp.ndarray:
    """KL(teacher || student) averaged over unmasked positions.

    logits: (B, T, V); mask: (B, T) with 1 = count this position.
    """
    t = temperature
    sp = jax.nn.log_softmax(student_logits.astype(jnp.float32) / t, axis=-1)
    tp = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / t, axis=-1)
    kl = jnp.sum(jnp.exp(tp) * (tp - sp), axis=-1) * (t * t)     # (B, T)
    if mask is None:
        return jnp.mean(kl)
    mask = mask.astype(jnp.float32)
    return jnp.sum(kl * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None
) -> jnp.ndarray:
    """Next-token CE in Megatron vocab-parallel form.

    With vocab-sharded logits, ``take_along_axis`` would force GSPMD to
    all-gather the (B, T, V) tensor; the logsumexp − one-hot-contraction form
    keeps every reduction shard-local + psum.  logits: (B, T, V) fp32.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                    # sharded reduce
    onehot = labels[..., None] == jnp.arange(logits.shape[-1])[None, None, :]
    label_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - label_logit
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def retrofit_loss(
    student_logits: jnp.ndarray,
    teacher_logits: Optional[jnp.ndarray],
    labels: jnp.ndarray,
    alpha_sum: jnp.ndarray,
    alpha_count: jnp.ndarray,
    step: jnp.ndarray,
    dms_cfg: DMSConfig,
    mask: Optional[jnp.ndarray] = None,
    distill_weight: float = 1.0,
):
    """Full retrofit objective  L = L_D + L_aux  (+ CE fallback without teacher).

    Returns (loss, metrics dict).
    """
    if teacher_logits is not None:
        l_main = kl_logit_distillation(student_logits, teacher_logits, mask) * distill_weight
    else:
        l_main = lm_cross_entropy(student_logits, labels, mask)
    l_aux = dms_lib.aux_compression_loss(alpha_sum, alpha_count, step, dms_cfg)
    loss = l_main + l_aux
    metrics = {
        "loss": loss,
        "loss_main": l_main,
        "loss_aux": l_aux,
        "alpha_mean": alpha_sum / jnp.maximum(alpha_count, 1.0),
        "target_alpha": dms_lib.target_alpha(step, dms_cfg),
        "cr_schedule": dms_lib.cr_schedule(step, dms_cfg),
    }
    return loss, metrics
