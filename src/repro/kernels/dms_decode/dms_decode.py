"""Pallas TPU kernel: block-table flash-decode over compacted KV arenas.

The production win of KV compression at decode time is **HBM read traffic**:
at CR× compression the kernel must move CR× fewer K/V bytes, not merely skip
CR× of the compute.  This kernel makes that structural via *block-table
indirection*: the grid runs over a per-(lane, kv-head) **compacted table of
live block ids** (scalar-prefetched, maintained incrementally by the caches
— see ``repro.core.kv_cache.BlockTable`` and docs/kernels.md), and the K/V
``BlockSpec`` index maps read the table, so a block with zero live slots is
**never DMA'd into VMEM**.  Iterations past a head's live count ``n`` clamp
the index map to the last live block — Pallas's pipeline skips the copy when
the block index does not change — and ``@pl.when`` skips their compute, so
the tail costs neither bandwidth nor FLOPs.  Slot-level holes *inside* a
live block are masked via the ``valid`` bitmap (kept in its stored dtype;
any integer/bool dtype works — the kernel only tests ``!= 0``).

Grid: ``(B·Hkv, NB_tbl)`` — one pass over (at most) the table width per kv
head; the G query heads of the group ride along as rows of the (G, Dh) q
tile so GQA reuses each streamed KV block across the whole group (the main
arithmetic-intensity lever at decode time).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


class DecodeConfig(NamedTuple):
    orig_dh: int
    g: int                      # query heads per kv head
    block_p: int
    logit_cap: Optional[float]
    interpret: bool
    shared_kv: bool = False     # paged mode: k/v are ONE shared page arena
                                # (1, NPOOL*block_p, Dh); table entries are
                                # pool page ids and `valid` rides pre-gathered
                                # in table order (bh, NB_tbl*block_p)
    weights_out: bool = False   # also emit per-block unnormalized post-softmax
                                # weights (table order) + per-block running max
                                # + final per-head (m, l) — the wrapper
                                # renormalizes host-side (see ops.py)


def _decode_kernel(tbl_ref, n_ref, q_ref, k_ref, v_ref, valid_ref,
                   o_ref, *rest, cfg: DecodeConfig):
    if cfg.weights_out:
        w_ref, mb_ref, mo_ref, lo_ref, acc_ref, m_ref, l_ref = rest
    else:
        acc_ref, m_ref, l_ref = rest
    h, i = pl.program_id(0), pl.program_id(1)
    ni = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(i < n_ref[h])
    def _body():
        q = q_ref[0].astype(jnp.float32)                  # (G, Dh)
        k = k_ref[0].astype(jnp.float32)                  # (BP, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (cfg.orig_dh ** -0.5)
        if cfg.logit_cap is not None:
            s = cfg.logit_cap * jnp.tanh(s / cfg.logit_cap)
        live = valid_ref[0][None, :] != 0                 # (1, BP)
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(live, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        if cfg.weights_out:
            # unnormalized weights relative to the running max at THIS block;
            # the wrapper rescales by exp(m_blk - m_final) / l_final
            w_ref[0, 0] = p                               # (G, BP)
            mb_ref[0, 0] = m_new[:, 0]                    # (G,)

    @pl.when(i == ni - 1)
    def _finish():
        l = l_ref[...]
        l_safe = jnp.where(l <= 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        if cfg.weights_out:
            mo_ref[0] = m_ref[...][:, 0]                  # (G,)
            lo_ref[0] = l_ref[...][:, 0]                  # (G,)


def _live_i(h, i, n_ref):
    """Grid step ``i`` clamped to the last live table entry — a repeated
    index means the pipeline issues NO new DMA for the dead tail (and
    ``@pl.when`` skips its compute)."""
    return jnp.minimum(i, jnp.maximum(n_ref[h] - 1, 0))


def _live_block(h, i, tbl_ref, n_ref):
    """The arena block this grid step streams: table entry ``i`` (clamped —
    see :func:`_live_i`).  In fixed-arena mode the entry indexes the head's
    own arena; in ``shared_kv`` (paged) mode it is a pool page id into the
    one shared arena."""
    return tbl_ref[h, _live_i(h, i, n_ref)]


def decode_fwd(q, k, v, valid, block_tbl, block_n, cfg: DecodeConfig):
    """q: (BHkv, G, Dh); block_n: (BHkv,) int32 live counts.
    Returns (BHkv, G, Dh) — or, with ``cfg.weights_out``, the tuple
    ``(out, w_blk, m_blk, m_out, l_out)`` where ``w_blk`` is
    (BHkv, NB_tbl, G, block_p) per-block unnormalized post-softmax weights
    (``exp(s - m_blk)``, table order), ``m_blk`` (BHkv, NB_tbl, G) the
    running max when each block was processed, and ``m_out``/``l_out``
    (BHkv, G) the final flash statistics.  The normalized weight of a slot
    in table row ``i`` is ``w_blk[i] * exp(m_blk[i] - m_out) / l_out`` —
    a per-(row, g) scalar rescale the wrapper applies host-side, writing
    weight bytes ∝ table width (never arena capacity).

    Fixed-arena mode: k/v (BHkv, P, Dh) with P a block_p multiple; valid
    (BHkv, P) in its stored dtype (bool/int — only ``!= 0`` is used);
    block_tbl (BHkv, NB_tbl) int32 compacted live block ids into the head's
    own arena.

    ``cfg.shared_kv`` (paged) mode: k/v are the ONE global page pool
    (1, NPOOL*block_p, Dh) shared by every (lane, kv head); block_tbl
    entries are *pool page ids* (the cache's logical table translated
    through its page map) and ``valid`` arrives pre-gathered into table
    order (BHkv, NB_tbl*block_p) so its index map needs no indirection.

    Either way only blocks listed in the table are fetched: HBM traffic per
    head is ``n * block_p * Dh * (itemsize_k + itemsize_v)`` regardless of
    arena/pool capacity."""
    bh, g, dh = q.shape
    nb_tbl = block_tbl.shape[1]

    if cfg.shared_kv:
        # one shared arena: the leading axis is a singleton, the table entry
        # IS the page id; `valid` is table-ordered so it indexes by (h, i)
        kv_map = lambda h, i, tbl, n: (0, _live_block(h, i, tbl, n), 0)
        val_map = lambda h, i, tbl, n: (h, _live_i(h, i, n))
    else:
        kv_map = lambda h, i, tbl, n: (h, _live_block(h, i, tbl, n), 0)
        val_map = lambda h, i, tbl, n: (h, _live_block(h, i, tbl, n))

    out_specs = pl.BlockSpec((1, g, dh), lambda h, i, tbl, n: (h, 0, 0))
    out_shape = jax.ShapeDtypeStruct((bh, g, dh), q.dtype)
    if cfg.weights_out:
        # per-block outputs revisit the same (clamped) table row on the dead
        # tail — like the K/V inputs, a repeated index means no new copy; the
        # wrapper masks rows ≥ n so tail garbage never escapes.
        wmap = lambda h, i, tbl, n: (h, _live_i(h, i, n), 0, 0)
        mbmap = lambda h, i, tbl, n: (h, _live_i(h, i, n), 0)
        stat = lambda h, i, tbl, n: (h, 0)
        out_specs = [
            out_specs,
            pl.BlockSpec((1, 1, g, cfg.block_p), wmap),
            pl.BlockSpec((1, 1, g), mbmap),
            pl.BlockSpec((1, g), stat),
            pl.BlockSpec((1, g), stat),
        ]
        out_shape = [
            out_shape,
            jax.ShapeDtypeStruct((bh, nb_tbl, g, cfg.block_p), jnp.float32),
            jax.ShapeDtypeStruct((bh, nb_tbl, g), jnp.float32),
            jax.ShapeDtypeStruct((bh, g), jnp.float32),
            jax.ShapeDtypeStruct((bh, g), jnp.float32),
        ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bh, nb_tbl),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda h, i, tbl, n: (h, 0, 0)),
            pl.BlockSpec((1, cfg.block_p, dh), kv_map),
            pl.BlockSpec((1, cfg.block_p, dh), kv_map),
            pl.BlockSpec((1, cfg.block_p), val_map),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, cfg=cfg),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=cfg.interpret,
        name="dms_decode",
    )(block_tbl, block_n, q, k, v, valid)
