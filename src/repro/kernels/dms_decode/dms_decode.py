"""Pallas TPU kernel: flash-decode over the DMS slot-compacted KV arena.

The production win of DMS at decode time is that the *physical* arena has
``P ≈ S/CR + w`` slots instead of S — this kernel streams exactly those P
slots (the CR× HBM-traffic reduction is structural, not simulated).  Dead
slots (free-list holes) are masked via the ``valid`` bitmap; blocks that are
entirely dead are skipped with ``@pl.when`` using a scalar-prefetched
per-block liveness table.

Grid: ``(B·Hkv, nP)`` — one pass over the arena per kv head; the G query
heads of the group ride along as rows of the (G, Dh) q tile so GQA reuses
each streamed KV block across the whole group (the main arithmetic-intensity
lever at decode time).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


class DecodeConfig(NamedTuple):
    orig_dh: int
    g: int                      # query heads per kv head
    block_p: int
    logit_cap: Optional[float]
    interpret: bool


def _decode_kernel(blk_live_ref, q_ref, k_ref, v_ref, valid_ref,
                   o_ref, acc_ref, m_ref, l_ref, *, cfg: DecodeConfig):
    h, pi = pl.program_id(0), pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(blk_live_ref[h, pi] > 0)
    def _body():
        q = q_ref[0].astype(jnp.float32)                  # (G, Dh)
        k = k_ref[0].astype(jnp.float32)                  # (BP, Dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (cfg.orig_dh ** -0.5)
        if cfg.logit_cap is not None:
            s = cfg.logit_cap * jnp.tanh(s / cfg.logit_cap)
        live = valid_ref[0][None, :] > 0                  # (1, BP)
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(live, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(pi == np_ - 1)
    def _finish():
        l = l_ref[...]
        l_safe = jnp.where(l <= 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def decode_fwd(q, k, v, valid, blk_live, cfg: DecodeConfig):
    """q: (BHkv, G, Dh); k/v: (BHkv, Pp, Dh); valid: (BHkv, Pp) int32;
    blk_live: (BHkv, nP) int32.  Returns (BHkv, G, Dh)."""
    bh, g, dh = q.shape
    pp = k.shape[1]
    np_ = pp // cfg.block_p

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bh, np_),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda h, pi, bl: (h, 0, 0)),
            pl.BlockSpec((1, cfg.block_p, dh), lambda h, pi, bl: (h, pi, 0)),
            pl.BlockSpec((1, cfg.block_p, dh), lambda h, pi, bl: (h, pi, 0)),
            pl.BlockSpec((1, cfg.block_p), lambda h, pi, bl: (h, pi)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda h, pi, bl: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, dh), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_decode_kernel, cfg=cfg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, g, dh), q.dtype),
        interpret=cfg.interpret,
        name="dms_decode",
    )(blk_live, q, k, v, valid)
