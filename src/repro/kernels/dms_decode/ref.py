"""Pure-jnp oracle for the DMS decode-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def dms_decode_ref(
    q: jnp.ndarray,        # (B, 1, Hq, Dh) — one new token
    k: jnp.ndarray,        # (B, Hkv, P, Dh) — slot arena (post-RoPE keys)
    v: jnp.ndarray,        # (B, Hkv, P, Dh)
    valid: jnp.ndarray,    # (B, Hkv, P) bool — live slots
    *,
    logit_cap: Optional[float] = None,
) -> jnp.ndarray:
    b, _, hq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q[:, 0].reshape(b, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhpd->bhgp", qg, k.astype(jnp.float32)) * (dh ** -0.5)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    s = jnp.where(valid[:, :, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgp,bhpd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)
