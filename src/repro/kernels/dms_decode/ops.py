"""jit'd wrapper for the block-table flash-decode kernel (inference only).

Two call modes (docs/kernels.md):

* **Block-table mode** (``block_tbl``/``block_n``/``block_p`` given — what
  every registry policy's :class:`~repro.core.policy.AttendSpec` supplies):
  the arena is already allocated pre-padded to a ``block_p`` multiple in the
  kernel-native per-(lane, kv-head) layout, so this wrapper is **copy-free**
  — the (B, Hkv, …) → (B·Hkv, …) merges are metadata-only reshapes, there is
  no ``jnp.pad``, no ``valid`` dtype cast, and no full-arena liveness
  reduction on the step path.  HBM traffic ∝ live blocks.

  With ``pool_k``/``pool_v``/``phys`` also given (paged caches — see
  ``repro.core.block_pool``) the kernel streams the shared page arena
  directly: the logical table is translated through the page map (one
  (B, Hkv, NB_tbl) gather of int32 ids), ``valid`` is gathered into table
  order (bool rows — bytes, not Dh-wide), and the dense per-lane k/v views
  a paged AttendSpec carries for the reference path are never touched
  (dead code under jit).  Zero page bytes move on dispatch.
* **Legacy/dense mode** (no table — encoder-memory cross-attention, direct
  kernel tests on arbitrary shapes): a table covering every written block is
  derived from ``valid`` (one O(P) reduction) and the arena is padded to a
  block multiple.  Traffic ∝ arena capacity; fine for dense encoder memory,
  a pitfall for compacted caches (see docs/kernels.md — don't reintroduce).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dms_decode.dms_decode import DecodeConfig, decode_fwd

DEFAULT_BLOCK_P = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.lru_cache(maxsize=None)
def _default_interpret() -> bool:
    """Resolve the backend once per process (trace-time constant), not per
    decode call — ``jax.default_backend()`` walks the platform registry."""
    return jax.default_backend() == "cpu"


def modeled_hbm_bytes(block_n, block_p: int, head_dim: int,
                      k_dtype, v_dtype) -> int:
    """K/V bytes the kernel fetches for one decode step: ``sum(n)`` live
    blocks × block bytes.  Exact by construction — the index maps fetch
    precisely the first ``n`` table entries per (lane, kv head), and the
    clamped tail re-uses the last block's buffer (no DMA).  The benchmark's
    traffic model (``benchmarks/decode_path.py``) asserts this scales with
    live tokens, not arena capacity."""
    per_slot = head_dim * (jnp.dtype(k_dtype).itemsize
                           + jnp.dtype(v_dtype).itemsize)
    return int(jnp.sum(block_n)) * block_p * per_slot


def dms_decode_attention(
    q: jnp.ndarray,       # (B, 1, Hq, Dh)
    k: jnp.ndarray,       # (B, Hkv, P, Dh)
    v: jnp.ndarray,
    valid: jnp.ndarray,   # (B, Hkv, P) bool (stored dtype — never cast here)
    *,
    block_tbl: Optional[jnp.ndarray] = None,   # (B, Hkv, NB) int32
    block_n: Optional[jnp.ndarray] = None,     # (B, Hkv) int32
    block_p: Optional[int] = None,
    logit_cap: Optional[float] = None,
    interpret: Optional[bool] = None,
    pool_k: Optional[jnp.ndarray] = None,      # (NPOOL, block_p, Dh) page arena
    pool_v: Optional[jnp.ndarray] = None,
    phys: Optional[jnp.ndarray] = None,        # (B, Hkv, NB) page map, -1 free
    need_weights: bool = False,
) -> jnp.ndarray:
    b, _, hq, dh = q.shape
    hkv, p = k.shape[1], k.shape[2]
    g = hq // hkv
    if interpret is None:
        interpret = _default_interpret()
    shared_kv = False

    if block_tbl is not None:
        # block-table fast path: zero full-arena copies — reshapes only
        if p % block_p:
            raise ValueError(
                f"arena extent {p} not a multiple of block_p {block_p}; "
                "caches must allocate pre-padded (KVPolicyConfig.block_p)")
        bp = block_p
        tblf = block_tbl.reshape(b * hkv, -1)
        nf = block_n.reshape(b * hkv)
        ltbl = tblf             # LOGICAL arena rows — weights scatter target
        p_arena = p
        if pool_k is not None:
            # paged: stream the shared page arena.  Translate logical block
            # ids -> pool page ids through the page map (the one-liner twin
            # of block_pool.translate_table, inlined so kernels don't import
            # core); stale tail entries may map to -1 — clamp, the kernel's
            # live-count guard never dereferences them.
            shared_kv = True
            npool, pool_bp = pool_k.shape[0], pool_k.shape[1]
            if pool_bp != bp:
                raise ValueError(
                    f"pool page size {pool_bp} != block_p {bp}")
            nb = phys.shape[-1]
            ptbl = jnp.take_along_axis(
                phys, jnp.clip(block_tbl, 0, nb - 1), axis=2)
            tblf = jnp.clip(ptbl, 0, npool - 1).reshape(b * hkv, -1)
            kf = pool_k.reshape(1, npool * bp, dh)
            vf = pool_v.reshape(1, npool * bp, dh)
            # valid rides pre-gathered into table order so its index map
            # needs no indirection inside the kernel (bool rows — cheap)
            valf = jnp.take_along_axis(
                valid.reshape(b, hkv, p // bp, bp),
                jnp.clip(block_tbl, 0, p // bp - 1)[..., None], axis=2,
            ).reshape(b * hkv, -1)
        else:
            kf, vf = k.reshape(b * hkv, p, dh), v.reshape(b * hkv, p, dh)
            valf = valid.reshape(b * hkv, p)
    else:
        # legacy/dense path: derive a written-prefix-of-blocks table from
        # `valid` (O(P) reduction + pad — NOT the policy step path)
        bp = min(block_p or DEFAULT_BLOCK_P, _round_up(p, 8))
        pp = _round_up(p, bp)
        kf = jnp.pad(k.reshape(b * hkv, p, dh), ((0, 0), (0, pp - p), (0, 0)))
        vf = jnp.pad(v.reshape(b * hkv, p, dh), ((0, 0), (0, pp - p), (0, 0)))
        valf = jnp.pad(valid.reshape(b * hkv, p), ((0, 0), (0, pp - p)))
        nb = pp // bp
        blk_live = jnp.any(valf.reshape(b * hkv, nb, bp) != 0, axis=-1)
        tblf = jnp.argsort(~blk_live, axis=-1, stable=True).astype(jnp.int32)
        nf = jnp.sum(blk_live, axis=-1).astype(jnp.int32)
        ltbl = tblf
        p_arena = pp

    qf = q[:, 0].reshape(b, hkv, g, dh).reshape(b * hkv, g, dh)
    cfg = DecodeConfig(orig_dh=dh, g=g, block_p=bp, logit_cap=logit_cap,
                       interpret=bool(interpret), shared_kv=shared_kv,
                       weights_out=need_weights)
    if not need_weights:
        out = decode_fwd(qf, kf, vf, valf, tblf, nf, cfg)
        return out.reshape(b, hkv, g, dh).reshape(b, 1, hq, dh)

    out, w_blk, m_blk, m_out, l_out = decode_fwd(qf, kf, vf, valf, tblf, nf,
                                                 cfg)
    # Renormalize per table row: each block's weights were emitted as
    # exp(s - m_blk) with m_blk the running max at that block; the true
    # softmax weight is exp(s - m_out) / l_out.  For live rows
    # m_blk <= m_out always, so the clamp is the identity there — it only
    # silences dead-tail/empty-head garbage (masked to zero below anyway)
    # from overflowing the exp.  Per-g rescale BEFORE the group sum: the
    # query heads of a group have distinct (m, l).
    nb_tbl = tblf.shape[1]
    l_safe = jnp.where(l_out <= 0.0, 1.0, l_out)                  # (BH, G)
    corr = jnp.exp(jnp.minimum(m_blk - m_out[:, None, :], 0.0)) \
        / l_safe[:, None, :]                                      # (BH, NB, G)
    w_tbl = jnp.sum(w_blk * corr[..., None], axis=2)              # (BH, NB, BP)
    row_live = jnp.arange(nb_tbl)[None, :] < nf[:, None]
    w_tbl = jnp.where(row_live[..., None], w_tbl, 0.0)
    # Scatter table rows back to LOGICAL arena rows.  Weight bytes written
    # ∝ table width; the zeros init is (B·Hkv, P) f32 — group-summed, not
    # Dh-wide, so it stays under the arena-traffic lint threshold.  Dead
    # rows route to the out-of-range dump index and are dropped, so a stale
    # duplicate table id can never clobber a live row.
    nb_arena = p_arena // bp
    safe_rows = jnp.where(row_live, jnp.clip(ltbl, 0, nb_arena - 1), nb_arena)
    w_arena = jnp.zeros((b * hkv, nb_arena, bp), jnp.float32)
    w_arena = w_arena.at[jnp.arange(b * hkv)[:, None], safe_rows].set(
        w_tbl, mode="drop")
    weights = w_arena.reshape(b, hkv, nb_arena * bp)[:, :, :p]
    return out.reshape(b, hkv, g, dh).reshape(b, 1, hq, dh), weights
