"""jit'd wrapper for the DMS decode kernel (inference only — no VJP needed)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dms_decode.dms_decode import DecodeConfig, decode_fwd

DEFAULT_BLOCK_P = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def dms_decode_attention(
    q: jnp.ndarray,       # (B, 1, Hq, Dh)
    k: jnp.ndarray,       # (B, Hkv, P, Dh)
    v: jnp.ndarray,
    valid: jnp.ndarray,   # (B, Hkv, P) bool
    *,
    logit_cap: Optional[float] = None,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    b, _, hq, dh = q.shape
    hkv, p = k.shape[1], k.shape[2]
    g = hq // hkv
    interpret = (jax.default_backend() == "cpu") if interpret is None else interpret

    bp = min(block_p, _round_up(p, 8))
    pp = _round_up(p, bp)

    qf = q[:, 0].reshape(b, hkv, g, dh).reshape(b * hkv, g, dh)
    kf = jnp.pad(k.reshape(b * hkv, p, dh), ((0, 0), (0, pp - p), (0, 0)))
    vf = jnp.pad(v.reshape(b * hkv, p, dh), ((0, 0), (0, pp - p), (0, 0)))
    valf = jnp.pad(valid.reshape(b * hkv, p).astype(jnp.int32),
                   ((0, 0), (0, pp - p)))
    blk_live = jnp.max(valf.reshape(b * hkv, pp // bp, bp), axis=-1)

    cfg = DecodeConfig(orig_dh=dh, g=g, block_p=bp, logit_cap=logit_cap,
                       interpret=bool(interpret))
    out = decode_fwd(qf, kf, vf, valf, blk_live, cfg)
    return out.reshape(b, hkv, g, dh).reshape(b, 1, hq, dh)
