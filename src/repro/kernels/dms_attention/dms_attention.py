"""Pallas TPU kernels: flash attention with the DMS delayed-eviction mask.

Design (TPU adaptation of the paper's FlashMask/PagedAttention GPU story):

* The T×T additive mask is never materialised.  Each kv head carries a length-T
  fp32 vector ``log_surv = log1p(-alpha)``; inside the kernel the mask value
  for (i, j) is ``log_surv[j]`` iff ``i - j >= w`` (the delayed-eviction zone),
  else 0.  Causal and local-window masks are position arithmetic.
* **Block skipping**: with binarised decisions (prefill), a k-block that is
  (a) entirely inside the eviction zone for the whole q-block and (b) has no
  retained token, contributes nothing.  Such blocks are skipped two ways:
    - compute: ``@pl.when(live)`` guards the whole MXU body;
    - DMA: the k/v ``index_map`` consults a scalar-prefetched remap table and
      re-requests the previous live block, so Pallas's pipeline emits no new
      copy (revisited blocks are not re-fetched).
  This converts DMS sparsity into real prefill FLOP *and* bandwidth savings —
  the TPU-native equivalent of FlashMask tile skipping.
* Grid layouts: fwd/dq ``(B·Hq, nQ, nK)`` (k innermost, online softmax in VMEM
  scratch); dk/dv ``(B·Hkv, nK, G, nQ)`` accumulating over the query heads of
  each group, which also yields the mask gradient d(log_surv) per kv head.

Block shapes default to 128×128 (MXU-aligned); head_dim is padded to a lane
multiple by the wrapper when needed.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


class FlashConfig(NamedTuple):
    t: int                      # true sequence length (pre-padding)
    orig_dh: int                # true head dim (pre-padding) -> softmax scale
    hq: int
    hkv: int
    window: Optional[int]       # local-attention window, or None
    dms_delay: int              # eviction delay w (0 = no DMS mask)
    causal: bool
    logit_cap: Optional[float]
    block_q: int
    block_k: int
    skip_blocks: bool           # binarised alpha -> dead-block skipping
    interpret: bool


def _kv_row(h, cfg: FlashConfig):
    b = h // cfg.hq
    g = cfg.hq // cfg.hkv
    return b * cfg.hkv + (h % cfg.hq) // g


def _block_live(qi, ki, cfg: FlashConfig, hr):
    """Scalar liveness of block (qi, ki); ``hr`` = has-retained flag (int32)."""
    q_start = qi * cfg.block_q
    q_end = q_start + cfg.block_q - 1
    k_start = ki * cfg.block_k
    k_end = k_start + cfg.block_k - 1
    live = jnp.asarray(True)
    if cfg.causal:
        live &= k_start <= q_end
    if cfg.window is not None:
        live &= k_end >= q_start - cfg.window + 1
    if cfg.skip_blocks and cfg.dms_delay > 0:
        fully_in_zone = (q_start - k_end) >= cfg.dms_delay
        live &= (hr > 0) | ~fully_in_zone
    return live


def _mask_scores(s, qi, ki, ls_blk, cfg: FlashConfig):
    """Apply causal/window/padding masks + the DMS additive mask to (BQ,BK)."""
    ids_q = qi * cfg.block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    ids_k = ki * cfg.block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if cfg.logit_cap is not None:
        s = cfg.logit_cap * jnp.tanh(s / cfg.logit_cap)
    s_capped = s
    if cfg.dms_delay > 0 and ls_blk is not None:
        zone = (ids_q - ids_k) >= cfg.dms_delay
        s = s + jnp.where(zone, ls_blk, 0.0)
    else:
        zone = None
    neg = jnp.full_like(s, NEG_INF)
    if cfg.causal:
        s = jnp.where(ids_k <= ids_q, s, neg)
    if cfg.window is not None:
        s = jnp.where(ids_q - ids_k < cfg.window, s, neg)
    s = jnp.where(ids_k < cfg.t, s, neg)        # key padding
    return s, s_capped, zone, ids_q


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(hr_ref, remap_ref, q_ref, k_ref, v_ref, ls_ref,
                o_ref, lse_ref, acc_ref, m_ref, l_ref, *, cfg: FlashConfig):
    h, qi, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    hr = hr_ref[_kv_row(h, cfg), ki] if cfg.skip_blocks else jnp.int32(1)

    @pl.when(_block_live(qi, ki, cfg, hr))
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (cfg.orig_dh ** -0.5)
        ls_blk = ls_ref[0][None, :] if cfg.dms_delay > 0 else None
        s, _, _, _ = _mask_scores(s, qi, ki, ls_blk, cfg)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...]
        l_safe = jnp.where(l <= 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l_safe))[:, 0]


def flash_fwd(q, k, v, ls, hr, remap, cfg: FlashConfig):
    """q: (BHq, Tp, Dh); k/v: (BHkv, Tp, Dh); ls: (BHkv, Tp);
    hr/remap: (BHkv, nK) int32.  Returns (out (BHq,Tp,Dh), lse (BHq,Tp))."""
    bhq, tp, dh = q.shape
    nq, nk = tp // cfg.block_q, tp // cfg.block_k
    g = cfg.hq // cfg.hkv

    def qmap(h, qi, ki, hr_s, rm_s):
        return (h, qi, 0)

    def kmap(h, qi, ki, hr_s, rm_s):
        row = _kv_row(h, cfg)
        if cfg.skip_blocks and cfg.dms_delay > 0:
            fully_in_zone = (qi * cfg.block_q - (ki * cfg.block_k + cfg.block_k - 1)
                             ) >= cfg.dms_delay
            dead = (hr_s[row, ki] == 0) & fully_in_zone
            blk = jnp.where(dead, rm_s[row, ki], ki)
        else:
            blk = ki
        return (row, blk, 0)

    def lsmap(h, qi, ki, hr_s, rm_s):
        row, blk, _ = kmap(h, qi, ki, hr_s, rm_s)
        return (row, blk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, cfg.block_q, dh), qmap),
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k), lsmap),
        ],
        out_specs=[
            pl.BlockSpec((1, cfg.block_q, dh), qmap),
            pl.BlockSpec((1, cfg.block_q), lambda h, qi, ki, *_: (h, qi)),
        ],
        scratch_shapes=[
            pltpu.VMEM((cfg.block_q, dh), jnp.float32),
            pltpu.VMEM((cfg.block_q, 1), jnp.float32),
            pltpu.VMEM((cfg.block_q, 1), jnp.float32),
        ],
    )
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, cfg=cfg),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bhq, tp, dh), q.dtype),
            jax.ShapeDtypeStruct((bhq, tp), jnp.float32),
        ],
        interpret=cfg.interpret,
        name="dms_flash_fwd",
    )(hr, remap, q, k, v, ls)
    return out, lse


# ---------------------------------------------------------------------------
# backward: dq
# ---------------------------------------------------------------------------


def _dq_kernel(hr_ref, remap_ref, q_ref, k_ref, v_ref, ls_ref, do_ref,
               lse_ref, delta_ref, dq_ref, dq_acc, *, cfg: FlashConfig):
    h, qi, ki = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    hr = hr_ref[_kv_row(h, cfg), ki] if cfg.skip_blocks else jnp.int32(1)

    @pl.when(_block_live(qi, ki, cfg, hr))
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        scale = cfg.orig_dh ** -0.5
        s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
        ls_blk = ls_ref[0][None, :] if cfg.dms_delay > 0 else None
        s, s_capped, _, ids_q = _mask_scores(s_raw, qi, ki, ls_blk, cfg)
        p = jnp.exp(s - lse_ref[0][:, None])
        p = jnp.where(ids_q < cfg.t, p, 0.0)
        do = do_ref[0].astype(jnp.float32)
        dp = jax.lax.dot_general(do, v_ref[0].astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None])
        if cfg.logit_cap is not None:
            ds = ds * (1.0 - (s_capped / cfg.logit_cap) ** 2)
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def flash_dq(q, k, v, ls, do, lse, delta, hr, remap, cfg: FlashConfig):
    bhq, tp, dh = q.shape
    nq, nk = tp // cfg.block_q, tp // cfg.block_k

    def qmap(h, qi, ki, *_):
        return (h, qi, 0)

    def kmap(h, qi, ki, hr_s, rm_s):
        row = _kv_row(h, cfg)
        if cfg.skip_blocks and cfg.dms_delay > 0:
            fully_in_zone = (qi * cfg.block_q - (ki * cfg.block_k + cfg.block_k - 1)
                             ) >= cfg.dms_delay
            dead = (hr_s[row, ki] == 0) & fully_in_zone
            blk = jnp.where(dead, rm_s[row, ki], ki)
        else:
            blk = ki
        return (row, blk, 0)

    def lsmap(h, qi, ki, hr_s, rm_s):
        row, blk, _ = kmap(h, qi, ki, hr_s, rm_s)
        return (row, blk)

    def rowmap(h, qi, ki, *_):
        return (h, qi)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, cfg.block_q, dh), qmap),
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k), lsmap),
            pl.BlockSpec((1, cfg.block_q, dh), qmap),
            pl.BlockSpec((1, cfg.block_q), rowmap),
            pl.BlockSpec((1, cfg.block_q), rowmap),
        ],
        out_specs=pl.BlockSpec((1, cfg.block_q, dh), qmap),
        scratch_shapes=[pltpu.VMEM((cfg.block_q, dh), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_dq_kernel, cfg=cfg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bhq, tp, dh), q.dtype),
        interpret=cfg.interpret,
        name="dms_flash_dq",
    )(hr, remap, q, k, v, ls, do, lse, delta)


# ---------------------------------------------------------------------------
# backward: dk, dv, d(log_surv)
# ---------------------------------------------------------------------------


def _dkv_kernel(hr_ref, remap_ref, q_ref, k_ref, v_ref, ls_ref, do_ref,
                lse_ref, delta_ref, dk_ref, dv_ref, dls_ref,
                dk_acc, dv_acc, dls_acc, *, cfg: FlashConfig):
    bh, kj, g, qi = (pl.program_id(0), pl.program_id(1),
                     pl.program_id(2), pl.program_id(3))
    ng, nq = pl.num_programs(2), pl.num_programs(3)

    @pl.when((g == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)
        dls_acc[...] = jnp.zeros_like(dls_acc)

    hr = hr_ref[bh, kj] if cfg.skip_blocks else jnp.int32(1)

    @pl.when(_block_live(qi, kj, cfg, hr))
    def _body():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        scale = cfg.orig_dh ** -0.5
        s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
        ls_blk = ls_ref[0][None, :] if cfg.dms_delay > 0 else None
        s, s_capped, zone, ids_q = _mask_scores(s_raw, qi, kj, ls_blk, cfg)
        p = jnp.exp(s - lse_ref[0][:, None])
        p = jnp.where(ids_q < cfg.t, p, 0.0)
        do = do_ref[0].astype(jnp.float32)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None])
        if cfg.dms_delay > 0 and zone is not None:
            dls_acc[...] += jnp.sum(jnp.where(zone, ds, 0.0), axis=0, keepdims=True)
        if cfg.logit_cap is not None:
            ds = ds * (1.0 - (s_capped / cfg.logit_cap) ** 2)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32) * scale

    @pl.when((g == ng - 1) & (qi == nq - 1))
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)
        dls_ref[0] = dls_acc[0]


def flash_dkv(q, k, v, ls, do, lse, delta, hr, remap, cfg: FlashConfig):
    bhkv, tp, dh = k.shape
    nq, nk = tp // cfg.block_q, tp // cfg.block_k
    g_sz = cfg.hq // cfg.hkv

    def qrow(bh, g):
        b = bh // cfg.hkv
        return b * cfg.hq + (bh % cfg.hkv) * g_sz + g

    def qmap(bh, kj, g, qi, *_):
        return (qrow(bh, g), qi, 0)

    def rowmap(bh, kj, g, qi, *_):
        return (qrow(bh, g), qi)

    def kmap(bh, kj, g, qi, *_):
        return (bh, kj, 0)

    def lsmap(bh, kj, g, qi, *_):
        return (bh, kj)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bhkv, nk, g_sz, nq),
        in_specs=[
            pl.BlockSpec((1, cfg.block_q, dh), qmap),
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k), lsmap),
            pl.BlockSpec((1, cfg.block_q, dh), qmap),
            pl.BlockSpec((1, cfg.block_q), rowmap),
            pl.BlockSpec((1, cfg.block_q), rowmap),
        ],
        out_specs=[
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k, dh), kmap),
            pl.BlockSpec((1, cfg.block_k), lsmap),
        ],
        scratch_shapes=[
            pltpu.VMEM((cfg.block_k, dh), jnp.float32),
            pltpu.VMEM((cfg.block_k, dh), jnp.float32),
            pltpu.VMEM((1, cfg.block_k), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_dkv_kernel, cfg=cfg),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bhkv, tp, dh), k.dtype),
            jax.ShapeDtypeStruct((bhkv, tp, dh), v.dtype),
            jax.ShapeDtypeStruct((bhkv, tp), jnp.float32),
        ],
        interpret=cfg.interpret,
        name="dms_flash_dkv",
    )(hr, remap, q, k, v, ls, do, lse, delta)
