"""Pure-jnp oracle for the DMS flash-attention kernel.

Mirrors the kernel semantics exactly: causal + local-window masks, the DMS
delayed-eviction additive mask built from ``log_surv = log1p(-alpha)``, and
the gemma-style logit softcap (applied to raw scores, before mask addition).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def dms_attention_ref(
    q: jnp.ndarray,               # (B, T, Hq, Dh)
    k: jnp.ndarray,               # (B, T, Hkv, Dh)
    v: jnp.ndarray,               # (B, T, Hkv, Dh)
    log_surv: Optional[jnp.ndarray],   # (B, Hkv, T) = log1p(-alpha), or None
    *,
    window: Optional[int] = None,      # local attention window (i - j < window)
    dms_window: int = 0,               # eviction delay w (mask applies i - j >= w)
    causal: bool = True,
    logit_cap: Optional[float] = None,
    immediate: bool = False,
) -> jnp.ndarray:
    b, t, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, t, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bihgd,bjhd->bhgij", qg, k.astype(jnp.float32)) * (dh ** -0.5)
    if logit_cap is not None:
        s = logit_cap * jnp.tanh(s / logit_cap)
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    if causal:
        s = jnp.where((j <= i)[None, None, None], s, NEG_INF)
    if window is not None:
        s = jnp.where(((i - j) < window)[None, None, None], s, NEG_INF)
    if log_surv is not None:
        delay = 1 if immediate else dms_window
        zone = (i - j) >= delay
        add = jnp.where(zone[None, None], log_surv[:, :, None, :], 0.0)   # (B,H,Tq,Tk)
        s = s + add[:, :, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgij,bjhd->bihgd", p, v.astype(jnp.float32))
    return out.reshape(b, t, hq, dh).astype(q.dtype)
