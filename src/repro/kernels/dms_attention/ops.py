"""jit'd wrapper for the DMS flash-attention kernels (+ custom VJP).

Public entry point: :func:`dms_flash_attention` — takes the relaxed (or
binarised) eviction decisions ``alpha`` and differentiates through the mask:
``log_surv = log1p(-alpha)`` is computed *outside* the custom_vjp, so the
α-chain rule is handled by JAX autodiff while the O(T²) attention body uses
the hand-written Pallas forward/backward kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.dms_attention.dms_attention import (FlashConfig, NEG_INF,
                                                       flash_dkv, flash_dq,
                                                       flash_fwd)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


# -- inner custom-vjp function (log_surv in, static config hashable) ----------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _flash(q, k, v, ls, cfg: FlashConfig):
    out, _ = _flash_fwd_impl(q, k, v, ls, cfg)
    return out


def _prep_tables(ls, cfg: FlashConfig):
    """has_retained + remap tables per (BHkv, k-block) from log-survival."""
    bhkv, tp = ls.shape
    nk = tp // cfg.block_k
    if not cfg.skip_blocks:
        hr = jnp.ones((bhkv, nk), jnp.int32)
        remap = jnp.broadcast_to(jnp.arange(nk, dtype=jnp.int32), (bhkv, nk))
        return hr, remap
    retained = (ls > NEG_INF / 2).reshape(bhkv, nk, cfg.block_k)
    # key-padding counts as evicted
    ids = jnp.arange(tp).reshape(nk, cfg.block_k)
    retained = retained & (ids < cfg.t)[None]
    hr = jnp.any(retained, axis=-1).astype(jnp.int32)                # (BHkv, nK)
    idx = jnp.arange(nk, dtype=jnp.int32)
    last_live = jax.lax.associative_scan(
        jnp.maximum, jnp.where(hr > 0, idx[None, :], -1), axis=1)
    remap = jnp.where(last_live >= 0, last_live, idx[None, :]).astype(jnp.int32)
    return hr, remap


def _flash_fwd_impl(q, k, v, ls, cfg: FlashConfig):
    hr, remap = _prep_tables(ls, cfg)
    out, lse = flash_fwd(q, k, v, ls, hr, remap, cfg)
    return out, lse


def _flash_vjp_fwd(q, k, v, ls, cfg: FlashConfig):
    out, lse = _flash_fwd_impl(q, k, v, ls, cfg)
    return out, (q, k, v, ls, out, lse)


def _flash_vjp_bwd(cfg: FlashConfig, res, dout):
    q, k, v, ls, out, lse = res
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    hr, remap = _prep_tables(ls, cfg)
    dq = flash_dq(q, k, v, ls, dout, lse, delta, hr, remap, cfg)
    dk, dv, dls = flash_dkv(q, k, v, ls, dout, lse, delta, hr, remap, cfg)
    return dq, dk, dv, dls


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# -- public wrapper -----------------------------------------------------------


def dms_flash_attention(
    q: jnp.ndarray,                      # (B, T, Hq, Dh)
    k: jnp.ndarray,                      # (B, T, Hkv, Dh)
    v: jnp.ndarray,                      # (B, T, Hkv, Dh)
    alpha: Optional[jnp.ndarray] = None,  # (B, Hkv, T) in [0,1]; None = vanilla
    *,
    window: Optional[int] = None,
    dms_window: int = 0,
    causal: bool = True,
    logit_cap: Optional[float] = None,
    immediate: bool = False,
    skip_blocks: Optional[bool] = None,   # default: True iff alpha is binary-ish
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Flash attention with the DMS delayed-eviction mask.  Returns (B,T,Hq,Dh)."""
    b, t, hq, dh = q.shape
    hkv = k.shape[2]
    interpret = _is_cpu() if interpret is None else interpret

    bq = min(block_q, _round_up(t, 8))
    bk = min(block_k, _round_up(t, 8))
    tp = _round_up(t, max(bq, bk))
    bq = min(bq, tp)
    bk = min(bk, tp)

    use_alpha = alpha is not None and dms_window >= 0 and alpha is not None
    delay = (1 if immediate else dms_window) if alpha is not None else 0

    # head-fold + pad
    def fold(x, heads):
        x = x.transpose(0, 2, 1, 3).reshape(b * heads, t, dh)
        return jnp.pad(x, ((0, 0), (0, tp - t), (0, 0)))

    qf, kf, vf = fold(q, hq), fold(k, hkv), fold(v, hkv)

    if alpha is not None:
        ls = jnp.maximum(jnp.log1p(-jnp.clip(alpha.astype(jnp.float32), 0.0, 1.0)),
                         NEG_INF)
        ls = ls.reshape(b * hkv, t)
        ls = jnp.pad(ls, ((0, 0), (0, tp - t)), constant_values=NEG_INF)
        if skip_blocks is None:
            skip_blocks = False
    else:
        ls = jnp.zeros((b * hkv, tp), jnp.float32)
        delay = 0
        skip_blocks = False

    cfg = FlashConfig(
        t=t, orig_dh=dh, hq=hq, hkv=hkv, window=window, dms_delay=delay,
        causal=causal, logit_cap=logit_cap, block_q=bq, block_k=bk,
        skip_blocks=bool(skip_blocks), interpret=bool(interpret),
    )
    out = _flash(qf, kf, vf, ls, cfg)
    out = out[:, :t].reshape(b, hq, t, dh).transpose(0, 2, 1, 3)
    return out


def dms_flash_attention_prefill(
    q, k, v, alpha_bin, *, dms_window: int, window=None, causal=True,
    logit_cap=None, block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K,
    interpret=None,
):
    """Prefill entry: binarised α enables dead-block skipping (compute + DMA)."""
    return dms_flash_attention(
        q, k, v, alpha_bin.astype(jnp.float32), window=window,
        dms_window=dms_window, causal=causal, logit_cap=logit_cap,
        skip_blocks=True, block_q=block_q, block_k=block_k, interpret=interpret)
