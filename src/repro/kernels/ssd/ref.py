"""Pure-jnp oracle for the SSD chunk kernel = the chunked reference in
repro.models.ssd (re-exported for the kernels/ layout convention)."""
from repro.models.ssd import ssd_chunked_ref  # noqa: F401
