"""SSD chunk computation wrapper.

A dedicated Pallas SSD kernel (intra-chunk dual-form matmul with in-VMEM
decay masks) is the natural next hot-spot after the attention kernels; the
current wrapper routes to the chunked jnp formulation, which XLA already maps
onto the MXU as batched matmuls — on TPU the win from a hand kernel is the
fusion of the decay-mask construction, estimated <10% of SSD block time
(see EXPERIMENTS.md §Perf notes).  Kept as the integration point.
"""
from __future__ import annotations

from repro.models.ssd import ssd_chunked_ref


def ssd_chunked(x, dt, a, bmat, cmat, *, chunk: int, init_state=None):
    return ssd_chunked_ref(x, dt, a, bmat, cmat, chunk, init_state=init_state)
