"""Step functions (train / retrofit / prefill / serve) + input_specs.

These are the units the multi-pod dry-run lowers and the launchers execute.
``input_specs`` returns weak-type-correct ShapeDtypeStructs for every model
input — shardable, no device allocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import distill as distill_lib
from repro.core.config import ArchConfig, KVPolicyConfig, ShapeConfig
from repro.models import transformer as tfm
from repro.optim import adamw

# enc-dec shape convention: encoder momentum is capped at 4K frames;
# the decoder carries the cell's full sequence length (see DESIGN.md).
ENC_LEN_CAP = 4096


def _frontend_split(arch: ArchConfig, seq_len: int) -> Tuple[int, int]:
    """(frontend_tokens, text_tokens) summing to seq_len."""
    if arch.frontend == "vision_patches" and arch.frontend_tokens:
        f = min(arch.frontend_tokens, seq_len // 2)
        return f, seq_len - f
    return 0, seq_len


def enc_len_for(arch: ArchConfig, seq_len: int) -> int:
    return min(ENC_LEN_CAP, seq_len) if arch.encoder_layers else 0


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def train_input_specs(arch: ArchConfig, shape: ShapeConfig,
                      accum_steps: int = 1) -> Dict[str, Any]:
    """With ``accum_steps > 1`` the pipeline emits microbatched tensors
    (K, B/K, ...) and the train step scans over K, accumulating grads."""
    b, s = shape.global_batch, shape.seq_len
    assert b % accum_steps == 0, (b, accum_steps)
    f, t_text = _frontend_split(arch, s)
    lead = (accum_steps, b // accum_steps) if accum_steps > 1 else (b,)
    specs = {
        "tokens": jax.ShapeDtypeStruct(lead + (t_text,), jnp.int32),
        "labels": jax.ShapeDtypeStruct(lead + (s,), jnp.int32),
    }
    if f:
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            lead + (f, arch.d_model), jnp.dtype(arch.dtype))
    e = enc_len_for(arch, s)
    if e:
        specs["enc_embeds"] = jax.ShapeDtypeStruct(
            lead + (e, arch.d_model), jnp.dtype(arch.dtype))
    return specs


def prefill_input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    specs = train_input_specs(arch, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(arch: ArchConfig, shape: ShapeConfig,
                       policy: KVPolicyConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: tfm.init_decode_state(arch, b, s, policy))
    specs: Dict[str, Any] = {
        "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        # per-lane positions: production decode is continuous-batched, so the
        # lowered step must accept lanes at different sequence positions
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": cache,
    }
    e = enc_len_for(arch, s)
    if e:
        specs["enc_out"] = jax.ShapeDtypeStruct((b, e, arch.d_model),
                                                jnp.dtype(arch.dtype))
    return specs


def params_spec(arch: ArchConfig, dtype: Optional[str] = None) -> Any:
    shapes = jax.eval_shape(lambda: tfm.init_model(jax.random.PRNGKey(0), arch))
    if dtype is not None:
        shapes = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(dtype)), shapes)
    return shapes


def opt_state_spec(params_shapes: Any) -> Any:
    return jax.eval_shape(lambda: adamw.init(
        jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), params_shapes)))


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(arch: ArchConfig, opt_cfg: adamw.AdamWConfig, *,
                    dms_train: bool = False, remat: bool = True,
                    use_kernel: bool = False, distill_weight: float = 1.0,
                    scan_layers: bool = True, attn_impl=None,
                    accum_steps: int = 1, grad_shardings=None):
    """Standard LM training step: CE (+ DMS aux + MoE aux), grads, AdamW.

    ``accum_steps > 1`` expects microbatched inputs (K, B/K, ...) and
    accumulates fp32 grads over a ``lax.scan`` — the production memory/
    overlap schedule (per-microbatch reduce-scatter hides DP comms behind
    the next microbatch's compute under XLA's latency-hiding scheduler).
    """
    mode = "dms_train" if (dms_train and arch.dms.enabled) else "vanilla"

    def loss_fn(p, batch, rng, step):
        logits, aux = tfm.model_forward(
            p, batch["tokens"], arch, mode=mode, rng=rng, remat=remat,
            use_kernel=use_kernel, scan_layers=scan_layers, attn_impl=attn_impl,
            frontend_embeds=batch.get("frontend_embeds"),
            enc_embeds=batch.get("enc_embeds"))
        ce = distill_lib.lm_cross_entropy(logits, batch["labels"])
        loss = ce + aux.get("moe_aux_loss", 0.0)
        if mode == "dms_train":
            loss = loss + distill_lib.retrofit_loss(
                logits, None, batch["labels"], aux["alpha_sum"],
                aux["alpha_count"], step, arch.dms)[1]["loss_aux"]
        return loss, (ce, aux)

    def train_step(params, opt_state, batch, step):
        rng = jax.random.fold_in(jax.random.PRNGKey(17), step)
        if accum_steps == 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, rng, step)
        else:
            def mb_body(acc, mb):
                g_acc, l_acc, c_acc, a_sum, a_cnt = acc
                (l, (c, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb, rng, step)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                if grad_shardings is not None:
                    # ZeRO: reduce-scatter each microbatch's grads onto the
                    # optimizer sharding; overlaps with the next microbatch
                    g_acc = jax.lax.with_sharding_constraint(g_acc, grad_shardings)
                return (g_acc, l_acc + l, c_acc + c,
                        a_sum + aux.get("alpha_sum", 0.0),
                        a_cnt + aux.get("alpha_count", 0.0)), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            z = jnp.zeros(())
            (grads, loss, ce, a_sum, a_cnt), _ = jax.lax.scan(
                mb_body, (g0, z, z, z, z), batch)
            k = jnp.asarray(accum_steps, jnp.float32)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss, ce = loss / k, ce / k
            aux = {"alpha_sum": a_sum, "alpha_count": a_cnt}
        params2, opt_state2, om = adamw.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, "ce": ce, **om}
        if mode == "dms_train":
            metrics["alpha_mean"] = aux["alpha_sum"] / jnp.maximum(aux["alpha_count"], 1.0)
        return params2, opt_state2, metrics

    return train_step


def make_retrofit_step(arch: ArchConfig, opt_cfg: adamw.AdamWConfig, *,
                       remat: bool = True, use_kernel: bool = False,
                       phase1: bool = False, scan_layers: bool = True, attn_impl=None):
    """Paper-faithful DMS retrofit: logit distillation from the frozen vanilla
    teacher + one-sided L1 compression loss (§3.2, §4).  ``phase1`` runs the
    borrowed-neuron zeroing schedule (App. B) instead of the DMS mask."""

    def retrofit_step(params, teacher_params, opt_state, batch, step):
        rng = jax.random.fold_in(jax.random.PRNGKey(23), step)
        teacher_logits, _ = tfm.model_forward(
            teacher_params, batch["tokens"], arch, mode="vanilla",
            remat=remat, use_kernel=use_kernel, scan_layers=scan_layers,
            attn_impl=attn_impl,
            frontend_embeds=batch.get("frontend_embeds"),
            enc_embeds=batch.get("enc_embeds"))
        teacher_logits = jax.lax.stop_gradient(teacher_logits)

        def loss_fn(p):
            if phase1:
                scale = jnp.clip(1.0 - step / arch.dms.neuron_zeroing_steps, 0.0, 1.0)
                logits, aux = tfm.model_forward(
                    p, batch["tokens"], arch, mode="dms_phase1", rng=rng,
                    neuron_scale=scale, remat=remat, use_kernel=use_kernel,
                    scan_layers=scan_layers, attn_impl=attn_impl,
                    frontend_embeds=batch.get("frontend_embeds"),
                    enc_embeds=batch.get("enc_embeds"))
                aux = dict(aux, alpha_sum=jnp.zeros(()), alpha_count=jnp.ones(()))
            else:
                logits, aux = tfm.model_forward(
                    p, batch["tokens"], arch, mode="dms_train", rng=rng,
                    remat=remat, use_kernel=use_kernel,
                    scan_layers=scan_layers, attn_impl=attn_impl,
                    frontend_embeds=batch.get("frontend_embeds"),
                    enc_embeds=batch.get("enc_embeds"))
            loss, metrics = distill_lib.retrofit_loss(
                logits, teacher_logits, batch["labels"],
                aux["alpha_sum"], aux["alpha_count"], step, arch.dms)
            loss = loss + aux.get("moe_aux_loss", 0.0)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params2, opt_state2, om = adamw.apply_updates(params, grads, opt_state, opt_cfg)
        return params2, opt_state2, {**metrics, **om}

    return retrofit_step


def make_prefill_step(arch: ArchConfig, *, dms: bool = False,
                      use_kernel: bool = False, scan_layers: bool = True,
                      attn_impl=None):
    """Prefill: full forward, emit last-position logits + per-layer KV
    (+ retained map when DMS sparsifies the prefill)."""
    mode = "dms_eval" if (dms and arch.dms.enabled) else "vanilla"

    def prefill_step(params, batch):
        logits, aux = tfm.model_forward(
            params, batch["tokens"], arch, mode=mode, collect_kv=True,
            use_kernel=use_kernel, scan_layers=scan_layers, attn_impl=attn_impl,
            frontend_embeds=batch.get("frontend_embeds"),
            enc_embeds=batch.get("enc_embeds"))
        return logits[:, -1], aux["layer_kv"]

    return prefill_step


def make_serve_step(arch: ArchConfig, *, use_kernel: bool = False,
                    scan_layers: bool = True):
    """One decode step: new token in, logits + updated cache out.

    Emits both axes of the policies' uniform ``metrics()`` contract so the
    serving layer can meter KV reads and peak memory without knowing which
    policy runs (see :mod:`repro.core.policy`)."""

    def serve_step(params, cache, batch):
        logits, cache2, aux = tfm.decode_step(
            params, batch["token"], cache, arch, batch["pos"],
            use_kernel=use_kernel, scan_layers=scan_layers,
            enc_out=batch.get("enc_out"))
        return logits, cache2, {"live_tokens": aux["live_tokens"],
                                "reads_tokens": aux["reads_tokens"]}

    return serve_step
