"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Runs the fault-tolerant loop of :mod:`repro.train.loop`.  ``--smoke`` trains
the reduced same-family config on CPU (a few hundred steps of a ~100M-class
model is the examples/ path); the full config is intended for the production
mesh where the same step functions are lowered via pjit (see dryrun.py for
the sharding rules applied at scale).
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_arch, get_smoke
from repro.data.pipeline import DataConfig
from repro.train.loop import TrainConfig, train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--retrofit", action="store_true",
                    help="DMS retrofit (logit distillation) instead of pretrain")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    data_cfg = DataConfig(vocab_size=arch.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch, seed=args.seed)
    cfg = TrainConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      retrofit=args.retrofit, use_kernel=args.use_kernel,
                      seed=args.seed)
    out = train(arch, data_cfg, cfg, log_fn=lambda m: print(json.dumps(m)))
    print(json.dumps({"final": out["history"][-1] if out["history"] else {},
                      "resumed_from": out["resumed_from"]}))


if __name__ == "__main__":
    main()
