"""Roofline-term extraction from compiled XLA artifacts (deliverable g).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs_per_device        / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device        / HBM_bw_per_chip
    collective = wire_bytes_per_device       / link_bw

``cost_analysis()`` reports the per-device partitioned module, so dividing by
per-chip peaks is equivalent to the spec's global/(chips × peak) form.
Collective wire bytes are parsed from the HLO text with ring-algorithm
effective-traffic factors:

    all-reduce      2(n-1)/n · bytes       all-gather      (n-1)/n · out_bytes
    reduce-scatter  (n-1) · out_bytes      all-to-all      (n-1)/n · bytes
    collective-permute  1 · bytes

Collectives whose replica-group size exceeds one pod (256 chips) cross DCI
and are tallied separately (`dci_bytes`).
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
POD_CHIPS = 256

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|f8e4m3fn|f8e5m2|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"all-reduce-start|all-gather-start|collective-permute-start|ragged-all-to-all)"
    r"[\s(]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^=]*?\}|\[[\d,]+\]<=\[[\d,]+\])")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in an HLO result snippet."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    # iota form [g0,g1,...]<=[N]: groups of size = product(dims[1:])
    dims = [int(x) for x in g[1:g.index("]")].split(",")]
    if len(dims) == 1:
        return dims[0]
    n = 1
    for d in dims[1:]:
        n *= d
    return n


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    dci_bytes: float = 0.0
    op_bytes: Dict[str, float] = field(default_factory=dict)
    op_counts: Dict[str, int] = field(default_factory=dict)

    def add(self, op: str, bytes_: float, crosses_pod: bool):
        self.wire_bytes += bytes_
        if crosses_pod:
            self.dci_bytes += bytes_
        self.op_bytes[op] = self.op_bytes.get(op, 0.0) + bytes_
        self.op_counts[op] = self.op_counts.get(op, 0) + 1


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1).replace("-start", "")
        # result shapes sit between '=' and the op name; the instruction's own
        # name ('%all-reduce.133 = ...') must not be parsed as a shape source
        lhs = line[:m.start(1)]
        eq = lhs.find("=")
        lhs = lhs[eq + 1:] if eq >= 0 else lhs
        out_bytes = _shape_bytes(lhs)
        n = _group_size(line, num_devices)
        if n <= 1:
            continue
        if op == "all-reduce":
            wire = 2.0 * (n - 1) / n * out_bytes
        elif op == "all-gather":
            wire = (n - 1) / n * out_bytes
        elif op == "reduce-scatter":
            wire = (n - 1) * out_bytes
        elif op in ("all-to-all", "ragged-all-to-all"):
            wire = (n - 1) / n * out_bytes
        else:  # collective-permute
            wire = float(out_bytes)
        stats.add(op, wire, crosses_pod=n > POD_CHIPS)
    return stats


def modeled_bytes_per_device(arch, shape, kind: str, *, num_devices: int,
                             tp: int, dp: int, policy: str = "vanilla",
                             cr: float = 1.0, accum: int = 8,
                             remat: bool = True) -> Dict[str, float]:
    """Analytic per-device HBM traffic for one step, assuming TPU-native
    execution (bf16 matmul operands, flash-attention kernels keeping tiles in
    VMEM, fused elementwise chains).  The HLO 'bytes accessed' number from the
    CPU backend systematically over-counts — its float-normalization pass
    rewrites bf16 ops as convert→f32→convert and its cost model charges every
    fusion-internal flow — so this model is the memory term used for
    bottleneck calls; the HLO number is reported alongside as an upper bound.
    """
    p_total = arch.param_count(active_only=False)
    p_active = arch.param_count(active_only=True)
    p_dev = p_total / tp * 2.0                     # bf16 shard
    d = arch.d_model
    l = arch.num_layers + arch.encoder_layers
    b_loc = max(shape.global_batch / dp, 1.0)
    t = shape.seq_len

    if kind == "train":
        mb_tokens = b_loc * t / accum
        act_coeff = 30.0 if remat else 22.0        # r+w per token-dim, fwd+bwd(+remat)
        act = l * mb_tokens * d * 2.0 * act_coeff * accum
        grads = p_total / tp * 4.0 * 2.0 * accum   # fp32 accumulate r+w
        opt = p_total / (tp * dp) * 4.0 * 3.0 * 2.0  # m, v, master r+w
        logits = mb_tokens * arch.vocab_size / tp * 4.0 * 4.0 * accum
        total = 3.0 * p_dev + grads + opt + act + logits
        return {"params": 3.0 * p_dev, "grads": grads, "opt": opt,
                "activations": act, "logits": logits, "total": total}
    if kind == "prefill":
        a = arch.attn
        act = l * b_loc * t * d * 2.0 * 8.0
        cache_w = (0 if a is None else
                   2.0 * l * b_loc * t * a.num_kv_heads * a.head_dim * 2.0
                   / max(tp // max(a.num_kv_heads, 1), 1) / cr)
        # flash kernel streams K/V once per q block (q tiles resident in VMEM)
        blk = 2048.0
        attn_stream = (0 if a is None else
                       b_loc * max(a.num_kv_heads / tp, 1.0 / tp) * tp / tp *
                       (t * t / 2.0 / blk) * a.head_dim * 2.0 * 2.0 * l / cr)
        total = p_dev + act + cache_w + attn_stream
        return {"params": p_dev, "activations": act, "cache_write": cache_w,
                "attn_stream": attn_stream, "total": total}
    # decode
    a = arch.attn
    cache = 0.0
    if a is not None:
        n_attn = sum(1 for i in range(arch.num_layers)
                     if arch.layer_pattern[i % len(arch.layer_pattern)]
                     in ("attn", "attn_local"))
        n_local = sum(1 for i in range(arch.num_layers)
                      if arch.layer_pattern[i % len(arch.layer_pattern)] == "attn_local")
        h_shard = max(a.num_kv_heads / tp, 1.0) if shape.global_batch >= dp else a.num_kv_heads
        seq_fact = 1.0 if shape.global_batch >= dp else 1.0 / dp
        eff_len_g = min(t, a.window or t)
        full_len = t / cr
        cache = 2.0 * 2.0 * h_shard * a.head_dim * b_loc * seq_fact * (
            (n_attn - n_local) * full_len + n_local * min(eff_len_g, full_len))
    ssm_state = 0.0
    if arch.ssm is not None:
        nh = arch.ssm.num_heads(d) / tp
        ssm_state = (arch.num_layers * b_loc * nh * arch.ssm.head_dim
                     * arch.ssm.d_state * 4.0 * 2.0)
    if arch.rglru is not None:
        n_rg = sum(1 for k in arch.layer_pattern if k == "rglru")
        ssm_state += (arch.num_layers * n_rg / len(arch.layer_pattern)
                      * b_loc * (arch.rglru.lru_width or d) / tp * 4.0 * 2.0)
    act = l * b_loc * d * 2.0 * 8.0
    total = 2.0 * p_active / tp + cache + ssm_state + act
    return {"params": 2.0 * p_active / tp, "kv_cache": cache,
            "state": ssm_state, "activations": act, "total": total}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    flops_per_device: float
    bytes_per_device: float           # HLO 'bytes accessed' (upper bound)
    modeled_bytes_per_dev: float      # analytic TPU-native traffic model
    wire_bytes_per_device: float
    dci_bytes_per_device: float
    compute_s: float
    memory_s: float                   # from HLO bytes (upper bound)
    memory_model_s: float             # from the analytic model (used for calls)
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    step_time_s: float            # max(compute, memory_model, collective)
    hw_util: float                # model_flops / (chips * peak * step_time)
    memory_analysis: Dict[str, float] = field(default_factory=dict)
    memory_breakdown: Dict[str, float] = field(default_factory=dict)
    collective_ops: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, arch: str, shape: str, mesh_desc: str, num_devices: int,
            model_flops: float, hlo_text: Optional[str] = None,
            modeled: Optional[Dict[str, float]] = None) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text, num_devices)
    modeled = modeled or {"total": byts}

    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    memory_model_s = modeled["total"] / HBM_BW
    collective_s = coll.wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_model_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    total_flops = flops * num_devices
    ratio = model_flops / total_flops if total_flops else 0.0
    util = (model_flops / (num_devices * PEAK_FLOPS * step_time)
            if step_time > 0 else 0.0)

    ma = {}
    try:
        m = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            ma[k] = float(getattr(m, k, 0.0))
    except Exception:
        pass

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, num_devices=num_devices,
        flops_per_device=flops, bytes_per_device=byts,
        modeled_bytes_per_dev=float(modeled["total"]),
        wire_bytes_per_device=coll.wire_bytes,
        dci_bytes_per_device=coll.dci_bytes,
        compute_s=compute_s, memory_s=memory_s,
        memory_model_s=memory_model_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_flops_ratio=ratio, step_time_s=step_time, hw_util=util,
        memory_analysis=ma,
        memory_breakdown={k: float(v) for k, v in modeled.items()},
        collective_ops=coll.op_bytes,
        collective_counts=coll.op_counts)


def model_flops_for(arch, shape, kind: str) -> float:
    """MODEL_FLOPS: 6·N·D (train) / 2·N·D (forward-only), N = active params."""
    n_active = arch.param_count(active_only=True)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens
