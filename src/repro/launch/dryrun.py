import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

AOT-lowers and compiles every (architecture × input shape) cell on the
production meshes — 16×16 = 256 chips single-pod and 2×16×16 = 512 chips
multi-pod — and extracts memory / cost / collective analyses for the
roofline study.  No device allocation: all inputs are ShapeDtypeStructs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
        --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all          # 40 cells x 2 meshes
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_arch
from repro.core.config import KVPolicyConfig, SHAPES
from repro.launch import roofline, steps
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.parallel import sharding

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def cell_is_skipped(arch, shape) -> str | None:
    """Shape-grid skip rules (see DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return "long_500k skipped: pure full-attention arch (sub-quadratic required)"
    return None


def lower_cell(arch_name: str, shape_name: str, mesh, *, policy_kind: str = "vanilla",
               cr: float = 1.0, dms_train: bool = False, use_kernel: bool = False,
               remat: bool = True, scan_layers: bool = False, attn_impl="chunked",
               accum_steps: int = 1, tp: int = None):
    """Build, lower and compile one cell.  Returns (compiled, lowered, meta)."""
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(arch, shape)
    if skip and policy_kind == "vanilla":
        raise SkipCell(skip)

    pspec = steps.params_spec(arch, dtype=arch.dtype)
    p_sh = sharding.param_shardings(pspec, arch, mesh, tp=tp)
    dp_only = tp == 1

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        ospec = steps.opt_state_spec(pspec)
        o_sh = sharding.opt_shardings(pspec, arch, mesh, tp=tp)
        step_fn = steps.make_train_step(arch, opt_cfg, dms_train=dms_train,
                                        remat=remat, use_kernel=use_kernel,
                                        scan_layers=scan_layers,
                                        attn_impl=attn_impl,
                                        accum_steps=accum_steps,
                                        grad_shardings=o_sh.mu if accum_steps > 1
                                        else None)
        batch = steps.train_input_specs(arch, shape, accum_steps=accum_steps)
        b_sh = sharding.batch_shardings(mesh, batch, microbatched=accum_steps > 1,
                                        batch_over_model=dp_only)
        step_spec = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(step_fn,
                         in_shardings=(p_sh, o_sh, b_sh, None),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(pspec, ospec, batch, step_spec)
    elif shape.kind == "prefill":
        step_fn = steps.make_prefill_step(arch, dms=policy_kind == "dms",
                                          use_kernel=use_kernel,
                                          scan_layers=scan_layers,
                                          attn_impl=attn_impl)
        batch = steps.prefill_input_specs(arch, shape)
        b_sh = sharding.batch_shardings(mesh, batch, batch_over_model=dp_only)
        out_shape = jax.eval_shape(step_fn, pspec, batch)
        o_sh = sharding.prefill_out_shardings(out_shape, mesh, arch)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh), out_shardings=o_sh)
        lowered = jitted.lower(pspec, batch)
    else:  # decode
        policy = KVPolicyConfig(kind=policy_kind, cr=cr)
        step_fn = steps.make_serve_step(arch, use_kernel=use_kernel,
                                        scan_layers=scan_layers)
        batch = steps.decode_input_specs(arch, shape, policy)
        cache_spec = batch.pop("cache")
        c_sh = sharding.cache_shardings(cache_spec, mesh, shape.global_batch, arch)
        b_sh = sharding.batch_shardings(mesh, batch)
        jitted = jax.jit(step_fn, in_shardings=(p_sh, c_sh, b_sh),
                         out_shardings=(None, c_sh, None),
                         donate_argnums=(1,))
        lowered = jitted.lower(pspec, cache_spec, batch)

    compiled = lowered.compile()
    return compiled, lowered, {"arch": arch, "shape": shape}


class SkipCell(Exception):
    pass


def run_cell(arch_name, shape_name, *, multi_pod=False, policy_kind="vanilla",
             cr=1.0, dms_train=False, use_kernel=False, remat=True,
             attn_impl="chunked", accum_steps=None, save=True, verbose=True,
             variant="", memory_pass=True, flops_pass=True, tp=None):
    """Two compiles per cell:

    * **flops pass** — layers unrolled, no grad accumulation: XLA's cost model
      sees every layer, so FLOPs / bytes / collective counts are exact.
    * **memory pass** — ``lax.scan`` over layers + microbatch accumulation:
      while-loop buffer reuse makes ``memory_analysis()`` reflect the real
      per-device working set (the CPU backend's concurrent scheduler inflates
      unrolled-graph temp sizes by scheduling independent layer recomputes in
      parallel; scan restores the sequential schedule a TPU would use).
    Roofline terms come from the flops pass; the memory-fit proof from the
    memory pass.  Both must compile — that is the dry-run gate.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(map(str, mesh.devices.shape))
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    if accum_steps is None:
        if shape.kind == "train" and shape.global_batch >= 8:
            # fine-grained MoE dispatch flats scale with microbatch tokens
            moe = arch.mlp is not None and arch.mlp.moe is not None
            accum_steps = 32 if moe else 8
        else:
            accum_steps = 1

    rec = {}
    report = None
    if flops_pass:
        t0 = time.time()
        with mesh:
            compiled, lowered, meta = lower_cell(
                arch_name, shape_name, mesh, policy_kind=policy_kind, cr=cr,
                dms_train=dms_train, use_kernel=use_kernel, remat=remat,
                scan_layers=False, attn_impl=attn_impl, accum_steps=1, tp=tp)
        compile_s = time.time() - t0
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax < 0.4.30 returned [dict]
            cost = cost[0] if cost else {}
        if verbose:
            print(f"[{arch_name} × {shape_name} × {mesh_desc}] flops-pass "
                  f"compiled in {compile_s:.1f}s")
            print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
                  f"bytes={cost.get('bytes accessed', 0):.3e}")
        from repro.launch.mesh import dp_size, tp_size
        modeled = roofline.modeled_bytes_per_device(
            arch, shape, shape.kind, num_devices=mesh.size,
            tp=(tp or tp_size(mesh)),
            dp=dp_size(mesh) * (tp_size(mesh) if tp == 1 else 1),
            policy=policy_kind, cr=cr,
            accum=accum_steps, remat=remat)
        report = roofline.analyze(
            compiled, arch=arch_name, shape=shape_name, mesh_desc=mesh_desc,
            num_devices=mesh.size, modeled=modeled,
            model_flops=roofline.model_flops_for(arch, shape, shape.kind))
        rec = report.as_dict()
        rec["compile_seconds"] = compile_s

    if memory_pass:
        t0 = time.time()
        with mesh:
            compiled_m, _, _ = lower_cell(
                arch_name, shape_name, mesh, policy_kind=policy_kind, cr=cr,
                dms_train=dms_train, use_kernel=use_kernel, remat=remat,
                scan_layers=True, attn_impl="chunked_scan",
                accum_steps=accum_steps, tp=tp)
        mem = compiled_m.memory_analysis()
        fit = {
            "argument_bytes": float(mem.argument_size_in_bytes),
            "output_bytes": float(mem.output_size_in_bytes),
            "alias_bytes": float(mem.alias_size_in_bytes),
            "temp_bytes": float(mem.temp_size_in_bytes),
            "peak_bytes": float(mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
            "accum_steps": accum_steps,
            "compile_seconds": time.time() - t0,
            "fits_hbm_16g": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
                            < 16e9,
        }
        rec["memory_fit"] = fit
        if verbose:
            print(f"  memory-pass (scan, accum={accum_steps}): "
                  f"peak={fit['peak_bytes']/1e9:.2f}GB/device "
                  f"fits_16GB={fit['fits_hbm_16g']} "
                  f"({fit['compile_seconds']:.1f}s)")

    rec.update(policy=policy_kind, cr=cr, variant=variant or policy_kind,
               multi_pod=multi_pod)
    if save:
        ARTIFACT_DIR.mkdir(parents=True, exist_ok=True)
        tag = f"{arch_name}__{shape_name}__{mesh_desc}"
        if variant:
            tag += f"__{variant}"
        (ARTIFACT_DIR / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    if verbose and report is not None:
        print(f"  roofline: compute={report.compute_s:.4f}s "
              f"memory={report.memory_model_s:.4f}s (hlo-ub {report.memory_s:.4f}s) "
              f"collective={report.collective_s:.4f}s -> {report.bottleneck}-bound; "
              f"useful-FLOPs={report.useful_flops_ratio:.2f} util={report.hw_util:.3f}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="all 40 cells")
    from repro.core.policy import available_policies
    ap.add_argument("--policy", default="vanilla",
                    choices=list(available_policies()))
    ap.add_argument("--cr", type=float, default=1.0)
    ap.add_argument("--dms-train", action="store_true")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--memory-only", action="store_true")
    ap.add_argument("--flops-only", action="store_true")
    ap.add_argument("--accum", type=int, default=0,
                    help="microbatch accumulation steps for the memory pass")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    results, failures, skips = [], [], []
    for arch_name in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch_name, shape_name, multi_pod=mp,
                                   policy_kind=args.policy, cr=args.cr,
                                   dms_train=args.dms_train,
                                   use_kernel=args.use_kernel,
                                   remat=not args.no_remat,
                                   flops_pass=not args.memory_only,
                                   memory_pass=not args.flops_only,
                                   accum_steps=args.accum or None,
                                   variant=args.variant)
                    results.append(rec)
                except SkipCell as e:
                    print(f"[{arch_name} × {shape_name} × mp={mp}] SKIP: {e}")
                    skips.append((arch_name, shape_name, mp, str(e)))
                except Exception as e:
                    print(f"[{arch_name} × {shape_name} × mp={mp}] FAIL: {e}")
                    traceback.print_exc()
                    failures.append((arch_name, shape_name, mp, repr(e)))
    print(f"\n=== dry-run summary: {len(results)} ok, {len(skips)} skipped, "
          f"{len(failures)} failed ===")
    for f in failures:
        print("  FAIL:", f[:3])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
