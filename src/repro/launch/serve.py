"""Serving launcher: ``python -m repro.launch.serve --arch <id> --policy dms``.

Boots the engine with a smoke-scale model, serves a batch of synthetic
requests, and prints the hyper-scaling budget metrics (KV reads / peak
tokens) per request — the serving-side counterpart of the dry-run, runnable
on CPU.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.config import KVPolicyConfig
from repro.core.policy import available_policies
from repro.models import transformer as tfm
from repro.serving.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen-r1-1.5b")
    ap.add_argument("--policy", default="dms",
                    choices=list(available_policies()))
    ap.add_argument("--cr", type=float, default=4.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--use-kernel", action="store_true")
    args = ap.parse_args(argv)

    arch = get_smoke(args.arch)
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    policy = KVPolicyConfig(kind=args.policy, cr=args.cr, window=arch.dms.window)
    engine = Engine(arch, params, policy, use_kernel=args.use_kernel)
    prompts = np.random.default_rng(0).integers(
        3, arch.vocab_size, size=(args.batch, args.prompt_len)).astype(np.int32)
    res = engine.generate(prompts, args.max_new)
    print(json.dumps({
        "policy": args.policy, "cr": args.cr,
        "generated_shape": list(res.tokens.shape),
        "kv_reads": res.meter.kv_reads,
        "peak_tokens": res.meter.peak_tokens,
        "peak_bytes": res.meter.peak_bytes,
        "steps": res.meter.steps,
    }))


if __name__ == "__main__":
    main()
