"""Serving launcher: ``python -m repro.launch.serve --arch <id> --policy dms``.

Boots the engine with a smoke-scale model and serves synthetic requests
through the continuous-batching scheduler: staggered arrivals, mixed prompt
lengths, optional hyper-scaling width (shared-prefill fork) and EOS early
exit.  Prints per-request budget metrics (prefill/decode KV reads, peak
tokens) — the serving-side counterpart of the dry-run, runnable on CPU.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.config import KVPolicyConfig
from repro.core.policy import available_policies
from repro.models import transformer as tfm
from repro.serving import workload
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, SLOSpec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen-r1-1.5b")
    ap.add_argument("--policy", default="dms",
                    choices=list(available_policies()))
    ap.add_argument("--cr", type=float, default=4.0)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--num-lanes", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32,
                    help="max prompt length; --stagger mixes lengths")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--width", type=int, default=1,
                    help="hyper-scaling chains per request (shared prefill)")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--stagger", action="store_true",
                    help="staggered arrivals + mixed prompt lengths")
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="cross-request radix prefix cache host budget "
                         "(0 = off)")
    ap.add_argument("--prefix-cache-device-mb", type=float, default=0.0,
                    help="device-resident hot-tier slab budget: hot hits "
                         "import device-to-device (zero host bytes), exports "
                         "defer host materialization to demotion (0 = cold "
                         "tier only)")
    ap.add_argument("--export-policy", default="always",
                    choices=["always", "second-miss"],
                    help="boundary export gating: 'always' exports every new "
                         "chunk boundary; 'second-miss' exports only "
                         "boundaries earlier traffic missed on (unshared "
                         "prompts export nothing)")
    ap.add_argument("--export-stride", type=int, default=1,
                    help="snapshot stride: offer only every Nth prefill-chunk "
                         "boundary for export (the full-prompt boundary is "
                         "always offered) — bounds hot-tier slot churn on "
                         "very long shared prefixes")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens to "
                         "every request (exercises the prefix cache)")
    ap.add_argument("--paged", action="store_true",
                    help="back KV caches with the shared paged block pool "
                         "(on-demand lane arenas, copy-on-write fork); lane "
                         "footprint tracks live tokens, not provisioned "
                         "capacity")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="shared pool size in block_p pages per cache "
                         "(default: lanes*heads*arena_blocks — never binds; "
                         "shrink to oversubscribe lanes against live "
                         "footprint, admission then gates on pool blocks)")
    ap.add_argument("--oversub", type=float, default=1.0,
                    help="admission oversubscription factor: reserve only "
                         "worst-case-demand/oversub pool blocks per request "
                         "(1.0 = sound admission, pool can never exhaust; "
                         ">1 admits more and lets preemption absorb real "
                         "divergence)")
    ap.add_argument("--on-pressure", default="preempt",
                    choices=["preempt", "ignore"],
                    help="pool-pressure response: 'preempt' snapshots and "
                         "requeues the youngest request at the tick boundary; "
                         "'ignore' keeps the seed behaviour (silent dropped "
                         "writes) for demonstration only")
    ap.add_argument("--deadline", type=int, default=None,
                    help="per-request deadline in scheduler ticks from "
                         "arrival; exceeded -> status 'timeout'")
    ap.add_argument("--arrival", default=None,
                    choices=["poisson", "burst"],
                    help="draw the trace from the seeded workload generator "
                         "(repro.serving.workload) instead of --stagger: "
                         "'poisson' open-loop arrivals at --rate, 'burst' "
                         "on/off windows (--burst-on/--burst-off) at --rate "
                         "inside each burst; prompt lengths mix over "
                         "[prompt_len/2, prompt_len]")
    ap.add_argument("--rate", type=float, default=0.5,
                    help="workload arrival rate in requests/tick "
                         "(--arrival modes)")
    ap.add_argument("--burst-on", type=int, default=4,
                    help="burst window length in ticks (--arrival burst)")
    ap.add_argument("--burst-off", type=int, default=8,
                    help="silence between bursts in ticks (--arrival burst)")
    ap.add_argument("--slo-ttft", type=int, default=None,
                    help="TTFT SLO in ticks (arrival -> first token); also "
                         "enables SLO-aware queue shedding")
    ap.add_argument("--slo-tpot", type=float, default=None,
                    help="TPOT SLO in decode ticks per post-first token "
                         "(measured; counts against goodput)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded queue: when the live backlog of arrived, "
                         "never-admitted requests exceeds this depth, the "
                         "newest arrivals are rejected (backpressure)")
    args = ap.parse_args(argv)

    arch = get_smoke(args.arch)
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    policy = KVPolicyConfig(kind=args.policy, cr=args.cr, window=arch.dms.window,
                            paged=args.paged, pool_blocks=args.pool_blocks)
    engine = Engine(arch, params, policy, use_kernel=args.use_kernel,
                    chunk=args.chunk, prefix_cache_mb=args.prefix_cache_mb,
                    prefix_cache_device_mb=args.prefix_cache_device_mb,
                    export_policy=args.export_policy,
                    export_stride=args.export_stride)

    rng = np.random.default_rng(0)
    shared = rng.integers(3, arch.vocab_size,
                          size=(args.shared_prefix,)).astype(np.int32)
    max_len = args.shared_prefix + args.prompt_len + args.max_new
    slo = None
    if (args.slo_ttft is not None or args.slo_tpot is not None
            or args.max_queue is not None):
        slo = SLOSpec(ttft_ticks=args.slo_ttft, tpot_ticks=args.slo_tpot,
                      max_queue=args.max_queue)
    sched = engine.scheduler(num_lanes=args.num_lanes, max_len=max_len,
                             on_pressure=args.on_pressure,
                             oversub=args.oversub, slo=slo)
    if args.arrival is not None:
        spec = workload.WorkloadSpec(
            vocab=arch.vocab_size,
            max_len=max_len - args.shared_prefix,
            prompt_len=(max(1, args.prompt_len // 2), args.prompt_len),
            max_new=(args.max_new, args.max_new),
            widths=(args.width,), eos_id=args.eos_id,
            deadline=args.deadline)
        if args.arrival == "poisson":
            reqs = workload.poisson_trace(0, args.requests, rate=args.rate,
                                          spec=spec)
        else:
            reqs = workload.burst_trace(0, args.requests, rate=args.rate,
                                        on_ticks=args.burst_on,
                                        off_ticks=args.burst_off, spec=spec)
        for r in reqs:
            sched.submit(Request(
                uid=r.uid, prompt=np.concatenate([shared, r.prompt]),
                max_new=r.max_new, width=r.width, eos_id=r.eos_id,
                arrival=r.arrival, deadline=r.deadline))
    else:
        for i in range(args.requests):
            plen = (int(rng.integers(args.prompt_len // 2,
                                     args.prompt_len + 1))
                    if args.stagger else args.prompt_len)
            own = rng.integers(3, arch.vocab_size,
                               size=(plen,)).astype(np.int32)
            sched.submit(Request(
                uid=i, prompt=np.concatenate([shared, own]),
                max_new=args.max_new, width=args.width,
                eos_id=args.eos_id, arrival=i if args.stagger else 0,
                deadline=args.deadline))
    results = sched.run()

    for r in sorted(results, key=lambda r: r.uid):
        print(json.dumps({
            "uid": r.uid, "chains": len(r.lengths),
            "status": r.status, "degraded": r.degraded,
            "preempts": r.preempt_count,
            "generated": r.lengths.tolist(),
            "kv_reads": r.meter.kv_reads,
            "kv_reads_prefill": r.prefill_meter.kv_reads,
            "kv_reads_saved": r.prefill_meter.kv_reads_saved,
            "kv_reads_decode": r.decode_meter.kv_reads,
            "peak_tokens": r.meter.peak_tokens,
            "peak_bytes": r.meter.peak_bytes,
            "ticks": [r.admitted_tick, r.finished_tick],
            "latency_ticks": r.latency_ticks,
            "ttft_ticks": r.ttft_ticks,
            "tpot_ticks": round(r.tpot_ticks, 4),
        }))
    # per-request TTFT/TPOT/status summary table (human-scan view of the
    # JSON rows above)
    print(f"# {'uid':>4} {'status':>9} {'deg':>4} {'ttft':>5} "
          f"{'tpot':>6} {'lat':>5}")
    for r in sorted(results, key=lambda r: r.uid):
        print(f"# {r.uid:>4} {r.status:>9} "
              f"{'y' if r.degraded else '-':>4} {r.ttft_ticks:>5} "
              f"{r.tpot_ticks:>6.2f} {r.latency_ticks:>5}")
    print(json.dumps({
        "policy": args.policy, "cr": args.cr,
        "requests": len(results), "lanes": args.num_lanes,
        "scheduler_ticks": sched.ticks, "scheduler_steps": sched.steps,
    }))
    print(json.dumps({"slo": sched.slo_stats()}))
    pool = sched.pool_stats()
    if pool is not None:
        print(json.dumps({"block_pool": pool}))
    if engine.prefix_cache is not None:
        print(json.dumps({"prefix_cache": engine.prefix_cache.stats()}))


if __name__ == "__main__":
    main()
