"""Production mesh builders.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis crosses DCI links and carries only data parallelism (+ optionally
compressed gradient reduction; see repro.optim.compress).

Defined as functions so importing this module never touches jax device state
(the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Tiny mesh over the actually-available devices (tests / CPU)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (data-parallel) dimension."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def tp_size(mesh) -> int:
    return mesh.shape["model"]


def dp_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
