"""Deterministic fault injection for the serving stack (the chaos harness).

Robustness claims are only as strong as the faults they were tested under.
This module gives the scheduler's chaos tests a seeded, replayable way to
hurt a live serving trace at chosen ticks:

* ``pool_shrink`` — reserve free pages in every paged block pool (as if a
  co-tenant grabbed them), optionally releasing them at a later tick.  The
  reservations are *ghost refs*: refcount bumps on pages that map to no
  lane, tracked host-side so the conservation oracle stays checkable as
  ``ref == recount(phys) + ghost``.
* ``cow_storm`` — duplicate every page one lane currently maps (ghost refs
  again), so the lane's next writes all take the copy-on-write slow path
  and the pool drains at CoW speed.
* ``nan_logits`` — poison a chosen lane's logits with NaN for one chunk,
  exercising the scheduler's tick-boundary numeric tripwire.
* ``stall`` — jump the scheduler clock forward, exercising deadlines and
  arrival/backoff arithmetic.
* ``preempt`` — force-preempt whatever request owns a lane, exercising the
  snapshot→requeue→resume path without needing real pool pressure.

Determinism: a :class:`FaultPlan` is a plain list of :class:`Fault` records;
:meth:`FaultPlan.random` derives one from a seed.  Replaying the same plan
against the same trace reproduces the same failure bit-for-bit (the
scheduler is host-driven and greedy decoding carries no RNG stream).

The injector's own device readbacks run under ``sanctioned("fault-inject")``
— a tag deliberately *not* in ``hostsync.DEFAULT_ALLOW``: injection is a
test-harness act, and an armed tripwire should attribute its syncs loudly
rather than fold them into the serving budget.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis.hostsync import sanctioned
from repro.core import policy as policy_lib

KINDS = ("pool_shrink", "cow_storm", "nan_logits", "stall", "preempt")


@dataclass(frozen=True)
class Fault:
    """One scheduled injury.

    ``tick`` is the earliest scheduler tick the fault fires at (it fires
    once, at the first tick boundary where ``scheduler.ticks >= tick``).
    ``lane`` targets ``nan_logits`` / ``cow_storm`` / ``preempt`` (taken
    modulo ``num_lanes``); ``blocks`` sizes ``pool_shrink`` (free pages
    reserved per pool row); ``duration`` sizes ``stall`` (ticks skipped);
    ``release`` optionally schedules the tick a shrink/storm's ghost refs
    are returned to the pool."""

    kind: str
    tick: int
    lane: int = 0
    blocks: int = 0
    duration: int = 0
    release: Optional[int] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


class FaultPlan:
    """A deterministic schedule of :class:`Fault` records plus the host-side
    ghost-ref ledger that keeps pool conservation checkable under injection.

    The scheduler calls :meth:`on_tick` once per tick (before admission) and
    :meth:`poison` once per chunk dispatch; :meth:`reapply` re-adds ghost
    refs after any lifecycle op that recomputed ``ref = recount(phys)``
    (gather / reclaim / prefix import), which would otherwise silently wipe
    the injected pressure."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: List[Fault] = sorted(faults, key=lambda f: f.tick)
        self._fired = [False] * len(self.faults)
        #: pooled_idx -> int32 ghost refcounts, shaped like that pool's
        #: ``ref`` (iter_policy_caches order restricted to pooled caches)
        self.ghosts: Dict[int, np.ndarray] = {}
        self._releases: List[Tuple[int, Dict[int, np.ndarray]]] = []
        self.log: List[Tuple[int, str]] = []

    # -- construction -------------------------------------------------------

    @staticmethod
    def random(seed: int, *, lanes: int, horizon: int = 12,
               max_faults: int = 3, paged: bool = True,
               arrivals: Optional[Sequence[int]] = None) -> "FaultPlan":
        """A seeded plan: 1..max_faults faults over the first ``horizon``
        ticks.  Pool faults are only drawn for paged states (they are no-ops
        on fixed arenas, which would waste fuzz budget).

        ``arrivals`` is the workload-generator hook: pass a trace's arrival
        ticks (e.g. ``repro.serving.workload.burst_arrivals``) and each
        fault tick is drawn near a sampled arrival instead of uniformly —
        bursty traces get their faults *inside* the burst, where requests
        are actually in flight, and ``horizon`` stretches to cover the
        trace's span.  With ``arrivals=None`` the draw sequence is unchanged
        (one uniform integer per fault), so existing seeded plans replay
        bit-identically."""
        rng = np.random.default_rng(seed)
        kinds = list(KINDS) if paged else ["nan_logits", "stall", "preempt"]
        arr = None
        if arrivals is not None and len(arrivals):
            arr = np.sort(np.asarray(arrivals, np.int64))
            horizon = max(horizon, int(arr.max()) + 2)

        def draw_tick() -> int:
            if arr is None:
                return int(rng.integers(1, horizon))
            base = int(arr[int(rng.integers(len(arr)))])
            return max(1, base + int(rng.integers(0, 3)))

        faults = []
        for _ in range(int(rng.integers(1, max_faults + 1))):
            kind = kinds[int(rng.integers(len(kinds)))]
            tick = draw_tick()
            if kind == "pool_shrink":
                release = (tick + int(rng.integers(2, horizon))
                           if rng.random() < 0.5 else None)
                faults.append(Fault(kind, tick,
                                    blocks=int(rng.integers(1, 5)),
                                    release=release))
            elif kind == "cow_storm":
                faults.append(Fault(kind, tick,
                                    lane=int(rng.integers(lanes)),
                                    release=tick + int(rng.integers(2, 6))))
            elif kind == "stall":
                faults.append(Fault(kind, tick,
                                    duration=int(rng.integers(1, 4))))
            else:
                faults.append(Fault(kind, tick,
                                    lane=int(rng.integers(lanes))))
        return FaultPlan(faults)

    # -- ledger queries ------------------------------------------------------

    def has_ghosts(self) -> bool:
        return any(int(g.sum()) > 0 for g in self.ghosts.values())

    def can_unblock(self) -> bool:
        """True while a future injector action could *free* pool pages —
        pending ghost releases, or unfired faults that schedule one.  The
        scheduler's starvation detector must keep waiting through these
        (a request blocked on ghost-held pages is waiting, not starved)."""
        if self._releases:
            return True
        return any(f.release is not None and not self._fired[i]
                   for i, f in enumerate(self.faults))

    def ghost_total(self, idx: int) -> int:
        g = self.ghosts.get(idx)
        return 0 if g is None else int(g.sum())

    # -- scheduler hooks -----------------------------------------------------

    def on_tick(self, sched, results) -> None:
        """Fire every due fault against ``sched`` (called once per tick,
        before admission).  ``nan_logits`` is consumed by :meth:`poison` at
        chunk dispatch instead; ``preempt`` stays pending until its target
        lane is actually owned."""
        for rel in list(self._releases):
            tick, deltas = rel
            if tick <= sched.ticks:
                self._releases.remove(rel)
                self._bump(sched, deltas, sign=-1)
                for i, d in deltas.items():
                    self.ghosts[i] = self.ghosts[i] - d
                self.log.append((sched.ticks, "release ghost refs"))
        for i, f in enumerate(self.faults):
            if self._fired[i] or f.tick > sched.ticks \
                    or f.kind == "nan_logits":
                continue
            if f.kind == "preempt":
                lane = f.lane % sched.num_lanes
                victim = sched.owner[lane]
                if victim is None:
                    continue              # pending until the lane is owned
                self._fired[i] = True
                self.log.append((sched.ticks, f"force-preempt lane {lane}"))
                sched._preempt(victim, results, reason="fault")
            elif f.kind == "stall":
                self._fired[i] = True
                self.log.append((sched.ticks, f"stall {f.duration} ticks"))
                sched.ticks += f.duration
            elif f.kind == "pool_shrink":
                self._fired[i] = True
                self._shrink(sched, f)
            elif f.kind == "cow_storm":
                self._fired[i] = True
                self._storm(sched, f)

    def poison(self, tick: int, num_lanes: int) -> Optional[np.ndarray]:
        """The (B,) NaN mask for the chunk dispatched at ``tick`` — None when
        no ``nan_logits`` fault is due (the common case: the scheduler then
        passes a cached all-False mask, and the jitted select is identity)."""
        out = None
        for i, f in enumerate(self.faults):
            if self._fired[i] or f.kind != "nan_logits" or f.tick > tick:
                continue
            self._fired[i] = True
            if out is None:
                out = np.zeros((num_lanes,), bool)
            out[f.lane % num_lanes] = True
            self.log.append((tick, f"nan logits lane {f.lane % num_lanes}"))
        return out

    def reapply(self, state):
        """Re-add ghost refs after an op that recomputed ``ref`` from
        ``phys`` (fork gather / reclaim / prefix import all call
        ``set_refcounts``, which sees only real mappings)."""
        def fn(idx, cache):
            g = self.ghosts.get(idx)
            if g is None or not int(g.sum()):
                return cache
            pool = cache.pool
            return dataclasses.replace(
                cache,
                pool=dataclasses.replace(pool, ref=pool.ref + jnp.asarray(g)))
        return policy_lib.map_pooled_caches(state, fn)

    # -- injectors -----------------------------------------------------------

    def _pooled_host(self, sched, want_phys: bool):
        """Host copies of every pooled cache's (ref[, phys]) — the injector's
        sanctioned readback."""
        out = []
        with sanctioned("fault-inject"):
            for pc in policy_lib.iter_policy_caches(sched.state):
                pool = getattr(pc.cache, "pool", None)
                if pool is None:
                    continue
                ref = np.asarray(pool.ref)
                phys = np.asarray(pc.cache.phys) if want_phys else None
                out.append((ref, phys))
        return out

    def _bump(self, sched, deltas: Dict[int, np.ndarray], sign: int) -> None:
        def fn(idx, cache):
            d = deltas.get(idx)
            if d is None:
                return cache
            pool = cache.pool
            return dataclasses.replace(
                cache, pool=dataclasses.replace(
                    pool, ref=pool.ref + sign * jnp.asarray(d)))
        sched.state = policy_lib.map_pooled_caches(sched.state, fn)

    def _charge(self, sched, f: Fault, deltas: Dict[int, np.ndarray],
                what: str) -> None:
        if not deltas:
            self.log.append((sched.ticks, f"{what}: nothing to grab"))
            return
        self._bump(sched, deltas, sign=+1)
        for i, d in deltas.items():
            self.ghosts[i] = self.ghosts.get(i, np.zeros_like(d)) + d
        if f.release is not None:
            self._releases.append((f.release, deltas))
        self.log.append((sched.ticks, what))

    def _shrink(self, sched, f: Fault) -> None:
        """Reserve up to ``f.blocks`` *free* pages per pool row: a co-tenant
        shrinking the effective pool out from under the scheduler."""
        deltas: Dict[int, np.ndarray] = {}
        for idx, (ref, _) in enumerate(self._pooled_host(sched, False)):
            flat = ref.reshape(-1, ref.shape[-1])
            grab = np.zeros_like(flat)
            for row in range(flat.shape[0]):
                free = np.flatnonzero(flat[row] == 0)[:f.blocks]
                grab[row, free] = 1
            if grab.any():
                deltas[idx] = grab.reshape(ref.shape).astype(ref.dtype)
        self._charge(sched, f, deltas,
                     f"pool_shrink {f.blocks} pages/row")

    def _storm(self, sched, f: Fault) -> None:
        """Ghost-share every page one lane maps, so the lane's next writes
        all CoW-copy (worst-case post-fork divergence, on demand)."""
        deltas: Dict[int, np.ndarray] = {}
        for idx, (ref, phys) in enumerate(self._pooled_host(sched, True)):
            lane = f.lane % phys.shape[-3]
            flat_ref = np.zeros_like(ref).reshape(-1, ref.shape[-1])
            lane_map = phys[..., lane, :, :].reshape(flat_ref.shape[0], -1)
            for row in range(flat_ref.shape[0]):
                mapped = lane_map[row][lane_map[row] >= 0]
                ids, cnt = np.unique(mapped, return_counts=True)
                flat_ref[row, ids] += cnt.astype(flat_ref.dtype)
            add = flat_ref.reshape(ref.shape)
            if add.any():
                deltas[idx] = add
        self._charge(sched, f, deltas,
                     f"cow_storm lane {f.lane}")
