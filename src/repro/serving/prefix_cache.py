"""Cross-request radix prefix cache: reuse compressed KV across the stream.

The scheduler's shared-prefill fork already amortises prefill *within* one
request; this module extends reuse *across* the request stream — the dominant
serving pattern (shared system prompts, few-shot headers, multi-turn chats)
— multiplying the KV-reads savings that compression policies make possible.

A host-side **radix tree over prompt token IDs** maps prefixes to per-lane
decode-state snapshots taken at token boundaries
(:func:`repro.models.transformer.export_lane_state`, dispatching through
:meth:`KVPolicy.export_prefix`).  Unlike block-granular prefix caches for
dense attention, a snapshot here is the policy's *complete* lane state —
compacted arenas, free lists, pending eviction rings, score accumulators,
page metadata — because for compressed/evicting policies the state after L
tokens is **not** a truncation of the state after T > L tokens.  That makes
reuse exact: importing a cached L-token snapshot and chunk-prefilling only
the suffix is bitwise-equal to a cold full prefill (pinned per policy in
``tests/test_prefix_cache.py``).

Mechanics:

* **Entries** live at radix-tree nodes (edges are compressed token runs;
  insertion splits edges so every snapshot boundary is a node).  Each entry
  holds the host-resident (numpy) snapshot, the boundary logits (predicting
  token L — so a full-prompt hit can skip prefill *and* still sample token
  0), and ``reads_cum``: the cumulative prefill ``reads_tokens`` a cold
  prefill of this prefix costs, used to meter saved-vs-paid reads honestly.
* **Lookup** walks the prompt and returns the deepest snapshot on its path;
  hits refresh LRU recency.
* **LRU byte budget**: entries account their true numpy bytes; inserting
  past ``capacity_bytes`` evicts least-recently-used entries (and prunes
  entry-less leaf nodes).  An over-budget snapshot is simply rejected — the
  stream degrades to cold prefill, never to an error.
* **Shape signatures**: snapshots are only interchangeable between decode
  states with identical tree structure / leaf shapes / dtypes
  (:func:`repro.models.transformer.lane_state_signature`).  One PrefixCache
  keeps one radix tree per signature, so an engine can safely share a cache
  across schedulers with different ``max_len`` without cross-importing.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import numpy as np


def snapshot_nbytes(snapshot: Any) -> int:
    """Host bytes of a snapshot pytree — shape-derived, so it works on
    device arrays WITHOUT materializing them (the insert fast-reject path)."""
    return int(sum(int(a.size) * np.dtype(a.dtype).itemsize
                   for a in jax.tree_util.tree_leaves(snapshot)))


def to_host(tree: Any) -> Any:
    """Device→host: numpy leaves, releasing device buffers for storage."""
    return jax.tree_util.tree_map(lambda a: np.asarray(a), jax.device_get(tree))


@dataclass
class PrefixHit:
    """A lookup result: the deepest cached boundary on the prompt's path."""

    length: int                   # prefix tokens covered
    snapshot: Any                 # host pytree, lane axis width 1
    logits: np.ndarray            # (V,) logits predicting token ``length``
    reads_cum: float              # cold-prefill reads_tokens for this prefix


@dataclass(eq=False)          # identity hash: entries key the LRU dict
class _Entry:
    snapshot: Any
    logits: np.ndarray
    reads_cum: float
    nbytes: int


class _Node:
    __slots__ = ("edge", "children", "entry")

    def __init__(self, edge: np.ndarray):
        self.edge = edge                       # tokens from parent to here
        self.children: Dict[int, _Node] = {}   # keyed by first edge token
        self.entry: Optional[_Entry] = None


def _common_len(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


class PrefixCache:
    """Radix tree of per-policy KV snapshots under an LRU byte budget.

    Thread-unsafe by design (the scheduler is single-threaded host code).
    Intended to be owned by the :class:`~repro.serving.engine.Engine` so it
    persists across Scheduler instances — that is what makes it
    *cross-request*: every served prompt seeds reuse for all later traffic.
    """

    def __init__(self, capacity_bytes: int):
        self.capacity_bytes = int(capacity_bytes)
        self._roots: Dict[Tuple, _Node] = {}   # one tree per shape signature
        # recency order: least-recently-used first; maps entry -> its node so
        # eviction pops in O(1) instead of scanning the whole tree
        self._lru: "collections.OrderedDict[_Entry, _Node]" = \
            collections.OrderedDict()
        self.total_bytes = 0
        # stats — surfaced by launch/serve and the prefix_cache benchmark
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.inserts = 0
        self.insert_rejects = 0
        self.evictions = 0

    # -- public ------------------------------------------------------------

    def _walk(self, signature: Tuple, tokens: np.ndarray
              ) -> Iterator[Tuple[int, _Node]]:
        """Yield (depth, node) for every node whose path is a prefix of
        ``tokens`` — the one radix descent all public reads share."""
        node = self._roots.get(signature)
        depth = 0
        while node is not None:
            yield depth, node
            rest = tokens[depth:]
            if len(rest) == 0:
                return
            child = node.children.get(int(rest[0]))
            if child is None or _common_len(child.edge, rest) < len(child.edge):
                return                     # tokens diverge inside the edge
            node = child
            depth += len(child.edge)

    def lookup(self, signature: Tuple, prompt: np.ndarray
               ) -> Optional[PrefixHit]:
        """Deepest cached boundary along ``prompt``; refreshes its recency.

        Never returns a boundary past ``len(prompt)`` (a hit covering the
        whole prompt is valid: its stored logits stand in for prefill)."""
        prompt = np.asarray(prompt)
        self.lookups += 1
        self.lookup_tokens += len(prompt)
        best = None
        for depth, node in self._walk(signature, prompt):
            if node.entry is not None and depth > 0:
                best = (depth, node.entry)
        if best is None:
            return None
        depth, entry = best
        self._lru.move_to_end(entry)
        self.hits += 1
        self.hit_tokens += depth
        return PrefixHit(length=depth, snapshot=entry.snapshot,
                         logits=entry.logits, reads_cum=entry.reads_cum)

    def covered(self, signature: Tuple, tokens: np.ndarray) -> int:
        """Deepest cached boundary along ``tokens`` WITHOUT touching stats or
        recency — the scheduler's "is exporting this boundary useful?" probe."""
        best = 0
        for depth, node in self._walk(signature, np.asarray(tokens)):
            if node.entry is not None:
                best = depth
        return best

    def insert(self, signature: Tuple, tokens: np.ndarray, snapshot: Any,
               logits: np.ndarray, reads_cum: float) -> bool:
        """Store a snapshot for the boundary ``len(tokens)``.

        No-op if that exact boundary already holds an entry.  Evicts LRU
        entries to fit; rejects (False) a snapshot larger than the whole
        budget — the caller falls back to cold prefill, never errors."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) == 0:
            return False
        if self.covered(signature, tokens) == len(tokens):
            return False                   # first writer wins (same prefix)
        # both rejects are shape-only: no device sync / host copy wasted
        nbytes = snapshot_nbytes(snapshot) + int(np.asarray(logits).nbytes)
        if nbytes > self.capacity_bytes:
            self.insert_rejects += 1
            return False
        snapshot = to_host(snapshot)
        node = self._node_for(signature, tokens)
        # np.array (not asarray): own the boundary row, don't pin the whole
        # per-tick (B, V) logits buffer alive via a view
        node.entry = _Entry(snapshot=snapshot, logits=np.array(logits),
                            reads_cum=float(reads_cum), nbytes=nbytes)
        self._lru[node.entry] = node
        self.total_bytes += nbytes
        self.inserts += 1
        self._evict_to_fit(keep=node.entry)
        return True

    def touch(self, signature: Tuple, tokens: np.ndarray) -> None:
        """Refresh recency of every boundary along ``tokens`` — the EOS
        reclamation hook: a finishing request offers its prompt's prefix
        chain back to the tree as recently-useful."""
        for _, node in self._walk(signature, np.asarray(tokens)):
            if node.entry is not None:
                self._lru.move_to_end(node.entry)

    def stats(self) -> Dict[str, Any]:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / max(self.lookups, 1),
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "token_hit_rate": self.hit_tokens / max(self.lookup_tokens, 1),
            "inserts": self.inserts,
            "insert_rejects": self.insert_rejects,
            "evictions": self.evictions,
            "entries": self._count_entries(),
            "bytes": self.total_bytes,
            "capacity_bytes": self.capacity_bytes,
        }

    # -- internals ----------------------------------------------------------

    def _node_for(self, signature: Tuple, tokens: np.ndarray) -> _Node:
        """Walk/extend/split the tree so ``tokens`` ends exactly at a node."""
        root = self._roots.setdefault(signature,
                                      _Node(np.empty((0,), np.int32)))
        node, depth = root, 0
        while depth < len(tokens):
            rest = tokens[depth:]
            child = node.children.get(int(rest[0]))
            if child is None:
                child = _Node(np.array(rest, np.int32))
                node.children[int(rest[0])] = child
                return child
            m = _common_len(child.edge, rest)
            if m < len(child.edge):
                # split the edge at m: node -> mid -> child
                mid = _Node(np.array(child.edge[:m], np.int32))
                child.edge = np.array(child.edge[m:], np.int32)
                mid.children[int(child.edge[0])] = child
                node.children[int(rest[0])] = mid
                child = mid
            node = child
            depth += m
        return node

    def _count_entries(self) -> int:
        return len(self._lru)

    def _evict_to_fit(self, keep: Optional[_Entry] = None) -> None:
        evicted = False
        while self.total_bytes > self.capacity_bytes and self._lru:
            entry, node = next(iter(self._lru.items()))   # LRU head
            if entry is keep:
                if len(self._lru) == 1:
                    break                  # only the fresh insert left
                self._lru.move_to_end(entry)
                continue
            del self._lru[entry]
            node.entry = None
            self.total_bytes -= entry.nbytes
            self.evictions += 1
            evicted = True
        if evicted:
            self._prune()

    def _prune(self) -> None:
        """Drop entry-less leaf chains so dead paths don't accumulate: one
        pass over each tree, children before parents (reversed BFS order)."""
        for root in self._roots.values():
            order = [(None, None, root)]
            i = 0
            while i < len(order):
                _, _, node = order[i]
                for key, c in node.children.items():
                    order.append((node, key, c))
                i += 1
            for parent, key, node in reversed(order):
                if parent is not None and node.entry is None \
                        and not node.children:
                    del parent.children[key]
