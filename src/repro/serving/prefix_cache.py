"""Cross-request radix prefix cache: reuse compressed KV across the stream.

The scheduler's shared-prefill fork already amortises prefill *within* one
request; this module extends reuse *across* the request stream — the dominant
serving pattern (shared system prompts, few-shot headers, multi-turn chats)
— multiplying the KV-reads savings that compression policies make possible.

A host-side **radix tree over prompt token IDs** maps prefixes to per-lane
decode-state snapshots taken at token boundaries
(:func:`repro.models.transformer.export_lane_state`, dispatching through
:meth:`KVPolicy.export_prefix`).  Unlike block-granular prefix caches for
dense attention, a snapshot here is the policy's *complete* lane state —
compacted arenas, free lists, pending eviction rings, score accumulators,
page metadata — because for compressed/evicting policies the state after L
tokens is **not** a truncation of the state after T > L tokens.  That makes
reuse exact: importing a cached L-token snapshot and chunk-prefilling only
the suffix is bitwise-equal to a cold full prefill (pinned per policy in
``tests/test_prefix_cache.py``).

Storage is **two-tier**, and both directions of the hot path are
device-resident:

* **Hot tier** — a pre-allocated per-signature **device slab** holding the K
  most-recently-used snapshots (K = device budget / per-entry bytes, capped
  at ``max_hot_slots`` per signature).  An
  insert writes the lane's freshly exported device snapshot straight into a
  slab slot (one jitted ``dynamic_update_slice`` — the export is *deferred*:
  nothing is synced to host, the decode scan never stalls on PCIe).  A hot
  hit fetches the slot and lane-inserts it into the arena device-to-device:
  **zero host↔device snapshot bytes** on the whole hit path.
* **Cold tier** — the host numpy LRU.  Eviction from the hot tier *demotes*:
  only then is the deferred snapshot materialised to host (the one d2h copy
  it will ever pay).  A cold hit *promotes* back into a slab slot (one h2d
  copy) so repeats of that prefix go device-resident again.

Every tier transition is metered (``h2d_bytes`` / ``d2h_bytes`` /
``d2d_bytes``; small boundary-logits syncs land on ``aux_sync_bytes``), so
``benchmarks/prefix_cache.py`` can assert the hit path's zero-copy claim
from counters rather than trust.

**Miss-driven exports** (``export_policy="second-miss"``): lookups record
miss depths along the prompt's path in the radix tree; a boundary reports
``want_export`` only once **two** lookups have asked for it — i.e. only
after earlier traffic proved the prefix is shared.  Single-shot unshared
prompts export *nothing* (the seed behaviour, ``"always"``, exported one
O(arena) snapshot per prefill chunk).

Mechanics:

* **Entries** live at radix-tree nodes (edges are compressed token runs;
  insertion splits edges so every snapshot boundary is a node).  Each entry
  holds the snapshot (a slab slot when hot, a host numpy pytree when cold),
  the boundary logits (predicting token L — so a full-prompt hit can skip
  prefill *and* still sample token 0), and ``reads_cum``: the cumulative
  prefill ``reads_tokens`` a cold prefill of this prefix costs, used to
  meter saved-vs-paid reads honestly.
* **Lookup** walks the prompt and returns the deepest snapshot on its path;
  hits refresh LRU recency (hot and cold recency share one order).
* **LRU byte budget**: cold entries account their true numpy bytes;
  inserting past ``capacity_bytes`` evicts least-recently-used *cold*
  entries (pruning entry-less nodes along the evicted path only, via parent
  links).  A snapshot too large for every tier is simply rejected — the
  stream degrades to cold prefill, never to an error.  Likewise a device
  budget too small for even one snapshot just means the hot tier stays
  empty: everything rides the cold tier as before.
* **Shape signatures**: snapshots are only interchangeable between decode
  states with identical tree structure / leaf shapes / dtypes
  (:func:`repro.models.transformer.lane_state_signature`).  One PrefixCache
  keeps one radix tree (and one device slab) per signature, so an engine can
  safely share a cache across schedulers with different ``max_len`` without
  cross-importing.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.models import transformer as tfm

EXPORT_POLICIES = ("always", "second-miss")

#: ghost-path budget: miss-depth records are int32 token runs hanging off the
#: radix tree; past this many recorded tokens per signature the records reset
#: (forgetting miss history is always safe — it only delays future exports).
MISS_RECORD_TOKENS = 1 << 16


def snapshot_nbytes(snapshot: Any) -> int:
    """Host bytes of a snapshot pytree — shape-derived, so it works on
    device arrays WITHOUT materializing them (the insert fast-reject path
    and the deferred-export hot tier)."""
    return int(sum(int(a.size) * np.dtype(a.dtype).itemsize
                   for a in jax.tree_util.tree_leaves(snapshot)))


def to_host(tree: Any, tag: str = "prefix-demote") -> Any:
    """Device→host: numpy leaves, releasing device buffers for storage.

    This is the serving stack's only snapshot d2h funnel — the *lazy
    demotion* of a hot-tier snapshot to the host LRU (plus cold-tier
    inserts), and — under ``tag="preempt-snapshot"`` — the scheduler's
    preemption path materializing an evicted lane's state for later resume.
    It is the one sanctioned snapshot d2h inside the serving loop;
    everything else must stay on device (``repro.analysis.hostsync``
    enforces this)."""
    from repro.analysis.hostsync import sanctioned
    with sanctioned(tag):
        return jax.tree_util.tree_map(lambda a: np.asarray(a),
                                      jax.device_get(tree))


def _is_device(a) -> bool:
    return not isinstance(a, np.ndarray)


# the hot-tier slab primitives.  Donation lets XLA update the slab in place
# (no O(slab) copy per insert); CPU ignores donation, so gate it to keep
# test logs clean.  The backend probe is LAZY — merely importing serving
# modules must not initialize the jax platform (CUDA-after-fork, late
# jax.config platform selection).
_SLAB_FETCH = jax.jit(tfm.fetch_lane_snapshot)
_SLAB_STORE_CACHE: list = []


def _slab_store():
    if not _SLAB_STORE_CACHE:
        try:
            donate = (0,) if jax.default_backend() in ("gpu", "tpu") else ()
        except Exception:                             # pragma: no cover
            donate = ()
        _SLAB_STORE_CACHE.append(
            jax.jit(tfm.store_lane_snapshot, donate_argnums=donate))
    return _SLAB_STORE_CACHE[0]


@dataclass
class PrefixHit:
    """A lookup result: the deepest cached boundary on the prompt's path.

    ``snapshot`` is a device pytree for hot-tier hits (import it straight
    into the arena — zero host bytes) and a host numpy pytree for cold hits
    (the jitted import pays the one h2d copy)."""

    length: int                   # prefix tokens covered
    snapshot: Any                 # lane-axis-width-1 pytree (device or host)
    logits: Any                   # (V,) logits predicting token ``length``
    reads_cum: float              # cold-prefill reads_tokens for this prefix
    tier: str = "cold"            # which tier served this hit


@dataclass(eq=False)          # identity hash: entries key the LRU dict
class _Entry:
    signature: Tuple
    reads_cum: float
    nbytes: int                   # snapshot + logits bytes (host-equivalent)
    snap_nbytes: int              # snapshot bytes only (slab accounting)
    tier: str = "cold"            # "hot" (slab slot) | "cold" (host numpy)
    slot: int = -1                # hot-tier slab slot
    snapshot: Any = None          # host pytree when cold, None when hot
    logits: Any = None            # device row while deferred, numpy when cold


class _Node:
    __slots__ = ("edge", "children", "entry", "parent", "misses")

    def __init__(self, edge: np.ndarray, parent: Optional["_Node"] = None):
        self.edge = edge                       # tokens from parent to here
        self.children: Dict[int, _Node] = {}   # keyed by first edge token
        self.entry: Optional[_Entry] = None
        self.parent = parent                   # None only at the root
        self.misses = 0                        # lookups that wanted past here


def _common_len(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    neq = np.nonzero(a[:n] != b[:n])[0]
    return int(neq[0]) if len(neq) else n


class _HotTier:
    """Per-signature device slab: K pre-allocated snapshot slots.

    The slab is one decode-snapshot-shaped pytree whose lane axis holds K
    slots; store/fetch are the jitted device-side copies
    (:func:`repro.models.transformer.store_lane_snapshot` /
    :func:`fetch_lane_snapshot`, dispatching through
    :meth:`KVPolicy.import_slab` / :meth:`export_slab`)."""

    __slots__ = ("slab", "free", "used")

    def __init__(self, exemplar_snap: Any, slots: int):
        self.slab = tfm.init_snapshot_slab(exemplar_snap, slots)
        self.free: List[int] = list(range(slots))
        # hot-entry recency, least-recent first (the demotion order)
        self.used: "collections.OrderedDict[_Entry, int]" = \
            collections.OrderedDict()


class PrefixCache:
    """Radix tree of per-policy KV snapshots: device-slab hot tier over a
    host LRU cold tier, under separate byte budgets.

    Thread-unsafe by design (the scheduler is single-threaded host code).
    Intended to be owned by the :class:`~repro.serving.engine.Engine` so it
    persists across Scheduler instances — that is what makes it
    *cross-request*: every served prompt seeds reuse for all later traffic.
    """

    def __init__(self, capacity_bytes: int, device_capacity_bytes: int = 0,
                 export_policy: str = "always", max_hot_slots: int = 32,
                 export_stride: int = 1):
        if export_policy not in EXPORT_POLICIES:
            raise ValueError(f"export_policy {export_policy!r} not in "
                             f"{EXPORT_POLICIES}")
        if export_stride < 1:
            raise ValueError(f"export_stride must be >= 1, got {export_stride}")
        self.capacity_bytes = int(capacity_bytes)
        self.device_capacity_bytes = int(device_capacity_bytes)
        self.export_policy = export_policy
        #: snapshot stride: only every Nth prefill-chunk boundary of a prompt
        #: is offered for export (the final full-prompt boundary always is).
        #: Coarser boundaries bound hot-tier slot churn on very long shared
        #: prefixes — a 10k-token system prompt at chunk 8 would otherwise
        #: push ~1250 snapshots through the slab LRU for one prompt.
        self.export_stride = int(export_stride)
        #: per-signature slab slot cap: bounds eager device allocation and
        #: keeps budget available for later signatures (see _ensure_hot)
        self.max_hot_slots = int(max_hot_slots)
        self._roots: Dict[Tuple, _Node] = {}   # one tree per shape signature
        self._hot: Dict[Tuple, Optional[_HotTier]] = {}   # None = can't fit
        self._device_bytes = 0                 # slab bytes actually allocated
        # recency order: least-recently-used first; maps entry -> its node so
        # eviction pops in O(1) instead of scanning the whole tree.  Hot and
        # cold entries share one recency order (a demoted entry keeps its
        # true age); budget eviction skips hot entries (the slab is not host
        # memory), hot-slot demotion uses the per-tier order in _HotTier.
        self._lru: "collections.OrderedDict[_Entry, _Node]" = \
            collections.OrderedDict()
        self.total_bytes = 0                   # cold (host) bytes only
        self._miss_tokens: Dict[Tuple, int] = {}
        # stats — surfaced by launch/serve and the prefix_cache benchmark
        self.lookups = 0
        self.hits = 0
        self.hot_hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.inserts = 0
        self.hot_inserts = 0
        self.insert_rejects = 0
        self.evictions = 0
        self.promotions = 0
        self.demotions = 0
        # byte-traffic counters: the benchmark's zero-copy assertions read
        # these instead of trusting the implementation
        self.h2d_bytes = 0          # snapshot bytes host→device (promotions,
        #                             and cold-hit imports shipped by jit)
        self.d2h_bytes = 0          # snapshot bytes device→host (demotions,
        #                             immediate materialization w/o hot tier)
        self.d2d_bytes = 0          # device-resident slab stores + fetches
        self.aux_sync_bytes = 0     # small boundary-logits rows synced on
        #                             full-prompt hot hits (O(V), not O(arena))

    # -- public ------------------------------------------------------------

    def _walk(self, signature: Tuple, tokens: np.ndarray
              ) -> Iterator[Tuple[int, _Node]]:
        """Yield (depth, node) for every node whose path is a prefix of
        ``tokens`` — the one radix descent all public reads share."""
        node = self._roots.get(signature)
        depth = 0
        while node is not None:
            yield depth, node
            rest = tokens[depth:]
            if len(rest) == 0:
                return
            child = node.children.get(int(rest[0]))
            if child is None or _common_len(child.edge, rest) < len(child.edge):
                return                     # tokens diverge inside the edge
            node = child
            depth += len(child.edge)

    def lookup(self, signature: Tuple, prompt: np.ndarray
               ) -> Optional[PrefixHit]:
        """Deepest cached boundary along ``prompt``; refreshes its recency.

        Never returns a boundary past ``len(prompt)`` (a hit covering the
        whole prompt is valid: its stored logits stand in for prefill).

        Under ``export_policy="second-miss"`` a lookup also *records* the
        prompt's path as a miss depth — the signal ``want_export`` later
        consults — so this is where "earlier traffic asked for this
        boundary" gets written down.  A hot hit hands back the device-slab
        slice (zero host↔device snapshot bytes); a cold hit promotes the
        entry into the slab (one h2d copy) when a slab exists."""
        prompt = np.asarray(prompt)
        self.lookups += 1
        self.lookup_tokens += len(prompt)
        best = None
        for depth, node in self._walk(signature, prompt):
            if node.entry is not None and depth > 0:
                best = (depth, node.entry)
        if self.export_policy == "second-miss" and (
                best is None or best[0] < len(prompt)):
            self._record_miss(signature, prompt)
        if best is None:
            return None
        depth, entry = best
        self._lru.move_to_end(entry)
        self.hits += 1
        self.hit_tokens += depth
        if entry.tier == "cold":
            self._promote(entry)
        if entry.tier == "hot":
            hot = self._hot[signature]
            hot.used.move_to_end(entry)
            self.hot_hits += 1
            snap = _SLAB_FETCH(hot.slab, np.int32(entry.slot))
            self.d2d_bytes += entry.snap_nbytes
            if depth == len(prompt) and _is_device(entry.logits):
                # full-prompt hit: the caller will materialize the boundary
                # logits row to sample token 0 — O(V), not O(arena)
                self.aux_sync_bytes += snapshot_nbytes(entry.logits)
            return PrefixHit(length=depth, snapshot=snap, logits=entry.logits,
                             reads_cum=entry.reads_cum, tier="hot")
        # cold hit without a usable slab: the caller's jitted import ships
        # the host snapshot up — that copy is this hit's h2d traffic
        self.h2d_bytes += entry.snap_nbytes
        return PrefixHit(length=depth, snapshot=entry.snapshot,
                         logits=entry.logits, reads_cum=entry.reads_cum,
                         tier="cold")

    def covered(self, signature: Tuple, tokens: np.ndarray) -> int:
        """Deepest cached boundary along ``tokens`` WITHOUT touching stats or
        recency."""
        best = 0
        for depth, node in self._walk(signature, np.asarray(tokens)):
            if node.entry is not None:
                best = depth
        return best

    def can_store(self, nbytes: int) -> bool:
        """Could a snapshot of ``nbytes`` ever be stored in either tier?
        Shape-only — the scheduler's "skip the export outright" fast gate."""
        return nbytes <= max(self.capacity_bytes, self.device_capacity_bytes)

    def want_export(self, signature: Tuple, tokens: np.ndarray,
                    chunk_index: Optional[int] = None,
                    final: bool = False) -> bool:
        """Should the scheduler export the boundary ``len(tokens)``?

        ``chunk_index`` is the 1-based ordinal of the prefill chunk that
        produced this boundary: with ``export_stride > 1`` only every Nth
        chunk boundary is offered (strided snapshots), except the ``final``
        full-prompt boundary which is always eligible — it is the one a
        full-prompt hit needs.  The stride check is pure host arithmetic, so
        skipped boundaries cost no radix descent either.

        Then one radix descent: False if that exact boundary already holds
        an entry; under ``"second-miss"`` additionally require that at least
        two lookups asked for this prefix (``misses >= 2`` — the requesting
        lookup itself contributes one, so the gate opens exactly when
        *earlier* traffic wanted it too)."""
        if (self.export_stride > 1 and not final and chunk_index is not None
                and chunk_index % self.export_stride != 0):
            return False
        tokens = np.asarray(tokens)
        node, exact = self._descend_to(signature, tokens)
        if exact and node.entry is not None:
            return False                       # boundary already cached
        if self.export_policy == "always":
            return True
        return node is not None and node.misses >= 2

    def insert(self, signature: Tuple, tokens: np.ndarray, snapshot: Any,
               logits: Any, reads_cum: float) -> bool:
        """Store a snapshot for the boundary ``len(tokens)``.

        ``snapshot`` may be a *device* pytree: with a hot tier it is slotted
        into the slab as-is (deferred export — no host sync; materialization
        happens lazily on demotion), otherwise it is materialized to host
        now.  No-op if that exact boundary already holds an entry.  Evicts
        LRU cold entries to fit; rejects (False) a snapshot larger than
        every tier — the caller falls back to cold prefill, never errors.
        One radix descent total (the coverage probe is folded into
        :meth:`_node_for`)."""
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) == 0:
            return False
        # both rejects are shape-only: no device sync / host copy wasted
        snap_nb = snapshot_nbytes(snapshot)
        nbytes = snap_nb + snapshot_nbytes(logits)
        hot = self._ensure_hot(signature, snapshot, nbytes)
        if hot is None and nbytes > self.capacity_bytes:
            self.insert_rejects += 1
            return False
        node = self._node_for(signature, tokens)
        if node.entry is not None:
            return False                   # first writer wins (same prefix)
        entry = _Entry(signature=signature, reads_cum=float(reads_cum),
                       nbytes=nbytes, snap_nbytes=snap_nb)
        if hot is not None:
            # attach the entry BEFORE acquiring a slot: a full slab demotes
            # its LRU occupant, whose eviction chain prunes dead radix paths
            # — the fresh (still entry-less) node must not look dead, and a
            # hot-tagged entry is invisible to the host-budget eviction
            entry.tier, entry.logits = "hot", logits
            node.entry = entry
            self._lru[entry] = node
            slot = self._acquire_slot(signature, hot)
            hot.slab = _slab_store()(hot.slab, snapshot, np.int32(slot))
            self.d2d_bytes += snap_nb
            entry.slot = slot
            hot.used[entry] = slot
            self.hot_inserts += 1
            self.inserts += 1
            return True
        if any(_is_device(a) for a in jax.tree_util.tree_leaves(snapshot)):
            self.d2h_bytes += snap_nb          # immediate materialization
        entry.snapshot = to_host(snapshot)
        # np.array (not asarray): own the boundary row, don't pin the
        # whole per-tick (B, V) logits buffer alive via a view
        entry.logits = np.array(np.asarray(logits))
        self.total_bytes += nbytes
        node.entry = entry
        self._lru[entry] = node
        self.inserts += 1
        self._evict_to_fit(keep=entry)
        return True

    def touch(self, signature: Tuple, tokens: np.ndarray) -> None:
        """Refresh recency of every boundary along ``tokens`` — the EOS
        reclamation hook: a finishing request offers its prompt's prefix
        chain back to the tree as recently-useful."""
        for _, node in self._walk(signature, np.asarray(tokens)):
            if node.entry is not None:
                self._lru.move_to_end(node.entry)
                if node.entry.tier == "hot":
                    self._hot[node.entry.signature].used.move_to_end(node.entry)

    def stats(self) -> Dict[str, Any]:
        hot_entries = sum(len(h.used) for h in self._hot.values()
                          if h is not None)
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hot_hits": self.hot_hits,
            "hit_rate": self.hits / max(self.lookups, 1),
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "token_hit_rate": self.hit_tokens / max(self.lookup_tokens, 1),
            "inserts": self.inserts,
            "hot_inserts": self.hot_inserts,
            "insert_rejects": self.insert_rejects,
            "evictions": self.evictions,
            "promotions": self.promotions,
            "demotions": self.demotions,
            "entries": self._count_entries(),
            "hot_entries": hot_entries,
            "bytes": self.total_bytes,
            "capacity_bytes": self.capacity_bytes,
            "device_bytes": self._device_bytes,
            "device_capacity_bytes": self.device_capacity_bytes,
            "h2d_bytes": self.h2d_bytes,
            "d2h_bytes": self.d2h_bytes,
            "d2d_bytes": self.d2d_bytes,
            "aux_sync_bytes": self.aux_sync_bytes,
        }

    def traffic(self) -> Dict[str, int]:
        """Just the byte-traffic counters — benchmark delta probes."""
        return {"h2d_bytes": self.h2d_bytes, "d2h_bytes": self.d2h_bytes,
                "d2d_bytes": self.d2d_bytes,
                "aux_sync_bytes": self.aux_sync_bytes}

    # -- hot tier ----------------------------------------------------------

    def _ensure_hot(self, signature: Tuple, snapshot: Any,
                    entry_nb: int) -> Optional[_HotTier]:
        """The signature's slab, allocating it on first use: K slots from
        the remaining device budget, capped at ``max_hot_slots`` so one
        arena geometry can't hog the budget an engine-shared cache needs
        for later signatures.  ``entry_nb`` includes the O(V) boundary
        logits row each hot entry keeps device-resident alongside its slab
        slot, so device residency stays inside ``device_capacity_bytes``.
        None when no slot can ever fit — the degraded-to-cold path, never
        an error."""
        if signature in self._hot:
            return self._hot[signature]
        slots = 0
        if entry_nb > 0:
            slots = min(
                (self.device_capacity_bytes - self._device_bytes) // entry_nb,
                self.max_hot_slots)
        if slots <= 0:
            self._hot[signature] = None
            return None
        tier = _HotTier(snapshot, int(slots))
        self._hot[signature] = tier
        self._device_bytes += int(slots) * entry_nb
        return tier

    def _acquire_slot(self, signature: Tuple, hot: _HotTier) -> int:
        """A free slab slot, demoting the least-recently-used hot entry
        (device→host, the deferred export's one materialization) if full."""
        if hot.free:
            return hot.free.pop()
        victim = next(iter(hot.used))          # hot-LRU head
        self._demote(victim, self._lru[victim])
        return hot.free.pop()

    def _promote(self, entry: _Entry) -> None:
        """Cold hit → hot: copy the host snapshot into a slab slot (one h2d)
        so repeats of this prefix go fully device-resident."""
        hot = self._hot.get(entry.signature)
        if hot is None:
            return
        slot = self._acquire_slot(entry.signature, hot)
        hot.slab = _slab_store()(hot.slab, entry.snapshot, np.int32(slot))
        self.h2d_bytes += entry.snap_nbytes
        self.total_bytes -= entry.nbytes       # leaves the host tier
        entry.tier, entry.slot, entry.snapshot = "hot", slot, None
        hot.used[entry] = slot
        hot.used.move_to_end(entry)            # it was just used
        self.promotions += 1

    def _demote(self, entry: _Entry, node: _Node) -> None:
        """Hot-tier eviction: materialize the deferred snapshot to host (the
        one d2h copy) and hand the entry to the cold LRU; an entry too large
        for the host budget is dropped outright."""
        hot = self._hot[entry.signature]
        snap = _SLAB_FETCH(hot.slab, np.int32(entry.slot))
        entry.snapshot = to_host(snap)
        self.d2h_bytes += entry.snap_nbytes
        if _is_device(entry.logits):
            self.aux_sync_bytes += snapshot_nbytes(entry.logits)
        entry.logits = np.array(np.asarray(entry.logits))
        hot.free.append(entry.slot)
        del hot.used[entry]
        entry.tier, entry.slot = "cold", -1
        self.total_bytes += entry.nbytes
        self.demotions += 1
        if entry.nbytes > self.capacity_bytes:
            self._drop(entry, node)
        else:
            self._evict_to_fit()

    # -- internals ----------------------------------------------------------

    def _descend_to(self, signature: Tuple, tokens: np.ndarray
                    ) -> Tuple[Optional[_Node], bool]:
        """Walk to position ``len(tokens)``: returns (node, exact) where
        ``node`` covers that position (None if the path leaves the tree) and
        ``exact`` means the position lands on the node itself rather than
        inside its edge.  A mid-edge node's ``misses`` still counts every
        recorded prompt through it, which is what ``want_export`` needs."""
        node = self._roots.get(signature)
        if node is None:
            return None, False
        depth, n = 0, len(tokens)
        while depth < n:
            rest = tokens[depth:]
            child = node.children.get(int(rest[0]))
            if child is None:
                return None, False
            m = _common_len(child.edge, rest)
            if m < len(child.edge):
                if depth + m == n:             # ends inside the edge
                    return child, False
                return None, False             # diverges inside the edge
            node = child
            depth += len(child.edge)
        return node, True

    def _record_miss(self, signature: Tuple, prompt: np.ndarray) -> None:
        """Write the missed prompt's path into the tree (ghost nodes are just
        token runs — no snapshots) and bump ``misses`` along it.  Bounded:
        past :data:`MISS_RECORD_TOKENS` recorded tokens the signature's miss
        history resets (only ever delays future exports)."""
        used = self._miss_tokens.get(signature, 0)
        if used > MISS_RECORD_TOKENS:
            self._reset_misses(signature)
            used = 0
        self._miss_tokens[signature] = used + len(prompt)
        self._node_for(signature, np.asarray(prompt, np.int32),
                       bump_misses=True)

    def _reset_misses(self, signature: Tuple) -> None:
        """Clear miss history: zero counters and prune ghost-only chains.
        One full-tree pass, amortised over MISS_RECORD_TOKENS lookups."""
        root = self._roots.get(signature)
        if root is None:
            return
        stack, order = [root], []
        while stack:
            node = stack.pop()
            node.misses = 0
            order.append(node)
            stack.extend(node.children.values())
        for node in reversed(order):
            self._prune_path(node)
        self._miss_tokens[signature] = 0

    def _node_for(self, signature: Tuple, tokens: np.ndarray,
                  bump_misses: bool = False) -> _Node:
        """Walk/extend/split the tree so ``tokens`` ends exactly at a node —
        ONE descent, also serving as insert's coverage probe (the returned
        node's ``entry`` says whether the boundary is already cached).  With
        ``bump_misses`` every node on the path counts one more lookup that
        wanted it (edge splits inherit the pass-through count)."""
        root = self._roots.setdefault(signature,
                                      _Node(np.empty((0,), np.int32)))
        node, depth = root, 0
        if bump_misses:
            root.misses += 1
        while depth < len(tokens):
            rest = tokens[depth:]
            child = node.children.get(int(rest[0]))
            if child is None:
                child = _Node(np.array(rest, np.int32), parent=node)
                node.children[int(rest[0])] = child
                if bump_misses:
                    child.misses += 1
                return child
            m = _common_len(child.edge, rest)
            if m < len(child.edge):
                # split the edge at m: node -> mid -> child; mid inherits the
                # pass-through miss count (every recorded path through child
                # also passed mid)
                mid = _Node(np.array(child.edge[:m], np.int32), parent=node)
                mid.misses = child.misses
                child.edge = np.array(child.edge[m:], np.int32)
                mid.children[int(child.edge[0])] = child
                child.parent = mid
                node.children[int(rest[0])] = mid
                child = mid
            node = child
            depth += m
            if bump_misses:
                node.misses += 1
        return node

    def _count_entries(self) -> int:
        return len(self._lru)

    def _drop(self, entry: _Entry, node: _Node) -> None:
        """Remove a cold entry entirely and prune its now-dead path."""
        del self._lru[entry]
        node.entry = None
        self.total_bytes -= entry.nbytes
        self.evictions += 1
        self._prune_path(node)

    def _evict_to_fit(self, keep: Optional[_Entry] = None) -> None:
        """Evict least-recently-used COLD entries until the host budget
        holds.  Hot entries are skipped — the slab is device memory with its
        own (pre-allocated) budget; they only hit the host ledger on
        demotion."""
        while self.total_bytes > self.capacity_bytes:
            victim = None
            for entry in self._lru:            # LRU head first
                if entry.tier == "cold" and entry is not keep:
                    victim = entry
                    break
            if victim is None:
                break                  # only hot entries / the fresh insert
            self._drop(victim, self._lru[victim])

    def _prune_path(self, node: _Node) -> None:
        """Drop entry-less childless nodes walking UP from ``node`` via
        parent links — O(depth), not O(whole tree).  Ghost nodes carrying
        live miss records (``misses > 0``) survive until the miss-history
        reset; the root always survives."""
        while (node.parent is not None and node.entry is None
               and not node.children and node.misses == 0):
            parent = node.parent
            del parent.children[int(node.edge[0])]
            node.parent = None
            node = parent
