"""Continuous-batching scheduler: the serving engine's admission / prefill /
fork / decode / reclaim lifecycle over a fixed arena of batch *lanes*.

The paper's hyper-scaling claim is a serving-time claim — more chains per
fixed KV budget — so the engine must actually serve: requests arrive over
time with different prompt lengths and stop at different steps.  This module
replaces the lockstep fixed batch with a real scheduler:

* **Lanes.**  The decode state is provisioned once for ``num_lanes`` batch
  rows.  Lanes are independent: each sits at its own sequence position
  (per-lane ``length`` in every cache, per-lane ``pos_t`` through RoPE and
  window masking) and is switched on/off per step by the ``active`` mask of
  :func:`repro.models.transformer.decode_step`.
* **Chunked prefill.**  Prompts are teacher-forced through the *decode* path
  in fixed-size T-chunks (one ``lax.scan`` compiled per chunk size, not one
  trace per prompt length), preserving exact per-policy eviction semantics —
  TOVA/H2O/DMS evict mid-prompt exactly as a per-token loop would.  Decoding
  lanes keep decoding inside the same chunk: prefill and decode interleave in
  one jitted step, which is what makes the batching *continuous*.
* **Shared-prefill fork.**  A width-W (hyper-scaling) request prefills its
  prompt in ONE lane; the finished cache is then forked into W chains via
  :meth:`KVPolicy.fork_cache` (`gather_lanes` inside the fixed batch).
  Forked chains carry bitwise-identical state, so step-0 logits match W
  independent prefills at 1/W of the prefill-phase KV reads.
* **EOS reclamation.**  A chain that emits EOS (or hits its token budget)
  goes inactive immediately — zero further KV reads — and its lane's arena
  is reclaimed (:meth:`KVPolicy.reclaim_cache`) for the next queued request.
* **Cross-request prefix reuse.**  With a
  :class:`~repro.serving.prefix_cache.PrefixCache` attached, admission looks
  up the longest cached prefix of the prompt, imports its snapshot into the
  lane (:meth:`KVPolicy.import_prefix`) — device-to-device when the boundary
  sits in the cache's hot tier — and chunk-prefills only the suffix; prefill
  offers a snapshot at chunk boundaries the cache's export policy asks for
  (all of them under ``"always"``, only prefixes earlier traffic missed on
  under ``"second-miss"``), deferred into the device slab when one exists;
  EOS reclamation offers the finished prompt's prefix chain back to the
  tree (LRU refresh).
  A full-prompt hit skips prefill entirely — the cached boundary logits
  stand in for the hold-state sample.
* **Honest per-request metering.**  Each request owns two
  :class:`BudgetMeter`\\ s (prefill phase / decode phase) fed only by its own
  lanes' per-step ``live_tokens`` / ``reads_tokens``.  Finished lanes
  contribute zero reads; idle lanes are never attributed to anyone.
* **Failure semantics & preemption.**  Oversubscribed paged pools used to
  fail *silently*: :func:`repro.core.block_pool.alloc` latches ``exhausted``
  and drops the write, and the victim lane keeps decoding against zeroed
  keys.  The scheduler now defines what happens instead.  Before each chunk
  it checks that the active set's worst-case pool demand (plus any
  fault-injected ghost pages) still fits the pool — an exact bound, pure
  host arithmetic — and when it does not, **preempts** the youngest
  request: every lane's full decode state is
  snapshotted to host through the prefix-cache export machinery
  (:meth:`_preempt`), its lanes and pool pages are freed, and it requeues
  with exponential backoff and a bounded retry count — on re-admission it
  resumes *bitwise-exactly* from the snapshot, zero prompt re-prefill
  (:meth:`_resume`).  The tick boundary also arms two tripwires: a NaN/Inf
  logit check that **fails** the poisoned request (reclaiming its lanes
  instead of letting it squat), and an ``exhausted``-latch backstop that
  fails every request whose chunk raced a mid-chunk exhaustion (post-hoc
  attribution of a dropped write is impossible, so nobody keeps tokens from
  that chunk).  Every request ends in a definite
  :attr:`RequestResult.status`: ``ok`` (possibly after N preemptions —
  ``preempt_count``), ``failed``, or ``timeout`` (per-request deadline
  ticks).  docs/serving.md "Failure semantics & preemption" is the contract;
  ``serving/faults.py`` is the chaos harness that proves it.
* **SLO & overload control.**  With an :class:`SLOSpec` attached the
  scheduler meters TTFT (arrival → first sampled token, in ticks) and TPOT
  (decode ticks per post-first token) on every result and runs a
  *degradation ladder* when offered load exceeds capacity — **throttle**
  hyper-scaling fork width (a width-W request is served at W′, flagged
  ``degraded``, tokens equal to a solo width-W′ run) with hysteresis
  (``cooldown_ticks``) so the preemption path cannot storm; **shed** queued
  requests that provably cannot meet their deadline/TTFT SLO even if
  admitted this very tick (status ``rejected``, zero prefill reads burned —
  unlike ``timeout``, which fires only after the deadline has passed);
  the bounded queue (``max_queue``) **rejects** the newest arrivals at the
  door when the live backlog of arrived requests exceeds it; only then
  the PR-9 rungs: **preempt**, and finally **fail**.  Every projection is
  pure host arithmetic over admission descriptors and the read-only radix
  probe (:meth:`PrefixCache.covered`) — zero device syncs, zero compiles
  (the analysis tripwires cover these paths).  :meth:`Scheduler.slo_stats`
  reports goodput (offered requests finishing ``ok`` within SLO), TTFT/TPOT
  percentiles, and queue-depth / lane-utilization timelines;
  ``serving/workload.py`` generates the traffic, ``benchmarks/slo_harness.py``
  gates the goodput win over the uncontrolled baseline.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hostsync import sanctioned
from repro.core import block_pool
from repro.core import policy as policy_lib
from repro.core.hyperscale import BudgetMeter
from repro.models import transformer as tfm
from repro.serving import prefix_cache as prefix_cache_lib
from repro.serving.prefix_cache import PrefixCache


@dataclass
class Request:
    """One serving request: a prompt and a generation budget.

    ``width`` > 1 asks for W parallel hyper-scaling chains sharing one
    prefill.  ``eos_id`` enables early exit (None = decode the full budget).
    ``arrival`` delays admission until that scheduler tick (staggered-arrival
    simulation for benchmarks/tests).  ``deadline`` bounds end-to-end latency
    in ticks from arrival: a request still running (or still queued) past it
    times out with a definite status instead of squatting lanes forever.
    ``max_preempts`` bounds how often the scheduler may evict-and-resume this
    request before giving up and failing it."""

    uid: int
    prompt: np.ndarray            # (T0,) int32
    max_new: int
    width: int = 1
    eos_id: Optional[int] = None
    arrival: int = 0
    deadline: Optional[int] = None
    max_preempts: int = 3


@dataclass(frozen=True)
class SLOSpec:
    """Latency SLO + overload-control knobs (attach via ``Scheduler(slo=)``).

    ``ttft_ticks`` bounds arrival → first sampled token; ``tpot_ticks``
    bounds decode ticks per post-first token (both measured on every result;
    either may be None = unconstrained for goodput accounting).
    ``max_queue`` bounds the live backlog of arrived-but-unadmitted
    requests — arrivals past it are ``rejected`` at the door (backpressure,
    a definite outcome; enforced per tick so preloaded traces with future
    arrivals are not counted against today's queue).  ``shed``
    enables SLO-aware admission: a queued request that *provably* cannot
    meet its deadline/TTFT SLO even if admitted this tick is rejected
    before it burns any prefill reads.  ``degrade_width`` enables the
    throttle rung of the degradation ladder: under lane/pool pressure a
    width-W request is admitted at ``min_width`` instead (result flagged
    ``degraded``), and ``cooldown_ticks`` of hysteresis keep the throttle
    engaged after pressure recedes so admission cannot flap into the
    preemption path."""

    ttft_ticks: Optional[int] = None
    tpot_ticks: Optional[float] = None
    max_queue: Optional[int] = None
    shed: bool = True
    degrade_width: bool = True
    min_width: int = 1
    cooldown_ticks: int = 4


@dataclass
class RequestResult:
    """``status`` is always definite: ``"ok"`` (``preempt_count`` > 0 means
    preempted×N then completed — tokens still bitwise-equal to an
    uninterrupted run), ``"failed"`` (pool exhaustion backstop, NaN/Inf
    logits, retry budget exhausted, or unservable under injected pressure),
    ``"timeout"`` (deadline ticks exceeded), or ``"rejected"`` (bounded-queue
    backpressure on arrival, or SLO-driven shed while queued — either way the
    request never touched a lane and burned zero prefill reads).
    ``latency_ticks`` is end-to-end (arrival → finished), queueing and
    backoff included.  ``ttft_ticks`` is arrival → first sampled token (-1
    when no token was ever sampled); ``tpot_ticks`` is decode ticks per
    post-first token (0.0 for single-token generations).  ``degraded`` marks
    a hyper-scaling request served at reduced width by the overload ladder —
    ``tokens`` then has the *served* width's rows and equals a solo run at
    that width."""

    uid: int
    tokens: np.ndarray            # (W, max_new) int32, padded after EOS
    lengths: np.ndarray           # (W,) generated tokens per chain (incl. EOS)
    meter: BudgetMeter            # prefill + decode, sequential merge
    prefill_meter: BudgetMeter
    decode_meter: BudgetMeter
    admitted_tick: int = 0
    finished_tick: int = 0
    status: str = "ok"
    preempt_count: int = 0
    latency_ticks: int = 0
    first_token_tick: int = -1
    ttft_ticks: int = -1
    tpot_ticks: float = 0.0
    degraded: bool = False


class _ReqState:
    def __init__(self, req: Request, pad_id: int):
        self.req = req
        self.lanes: List[int] = []             # lane -> chain index by order
        self.width = req.width                 # SERVED width (ladder may cut)
        self.consumed = 0                      # prompt tokens prefetched
        self.prefill_chunks = 0                # chunks prefilled (export stride)
        self.hold_logits: Optional[np.ndarray] = None
        self.chains: List[List[int]] = [[] for _ in range(req.width)]
        self.chain_done = [False] * req.width
        self.prefill_meter = BudgetMeter()
        self.decode_meter = BudgetMeter()
        self.pad_id = pad_id
        self.admitted_tick = -1                # -1 = never admitted
        self.first_token_tick = -1             # -1 = no token ever sampled
        self.status = "ok"
        self.preempt_count = 0
        self.resume_at = 0                     # backoff: earliest re-admission
        # preemption snapshot: per-lane host state trees + host lane scalars
        self.snaps: Optional[List[Any]] = None
        self.saved: Optional[Dict[str, np.ndarray]] = None

    @property
    def done(self) -> bool:
        return bool(self.lanes) and all(self.chain_done)

    def ready(self, tick: int) -> bool:
        return self.req.arrival <= tick and self.resume_at <= tick

    def degrade(self, width: int) -> None:
        """Throttle to ``width`` chains (admission-time only: chains are
        still empty, no lane holds anything of ours yet)."""
        self.width = width
        self.chains = [[] for _ in range(width)]
        self.chain_done = [False] * width

    def result(self, peak_bytes: float, finished_tick: int) -> RequestResult:
        w, m = self.width, self.req.max_new
        toks = np.full((w, m), self.pad_id, np.int32)
        lens = np.zeros((w,), np.int32)
        for c, chain in enumerate(self.chains):
            lens[c] = len(chain)
            toks[c, :len(chain)] = chain
        for meter in (self.prefill_meter, self.decode_meter):
            meter.observe_peak_bytes(peak_bytes)
        ft = self.first_token_tick
        gen = int(lens.max()) if w else 0
        tpot = ((finished_tick - ft) / (gen - 1)
                if ft >= 0 and gen > 1 else 0.0)
        return RequestResult(
            uid=self.req.uid, tokens=toks, lengths=lens,
            meter=self.prefill_meter.merge_sequential(self.decode_meter),
            prefill_meter=self.prefill_meter, decode_meter=self.decode_meter,
            admitted_tick=self.admitted_tick, finished_tick=finished_tick,
            status=self.status, preempt_count=self.preempt_count,
            latency_ticks=max(0, finished_tick - self.req.arrival),
            first_token_tick=ft,
            ttft_ticks=ft - self.req.arrival if ft >= 0 else -1,
            tpot_ticks=float(tpot), degraded=self.width < self.req.width)


def make_chunk_fn(arch, *, use_kernel: bool = False,
                  temperature: float = 0.0) -> Callable:
    """Build the jittable mixed prefill/decode chunk step.

    One call advances every active lane ``chunk`` steps: prefill lanes
    teacher-force their next prompt tokens (``feed`` / ``feed_valid``),
    decode lanes sample autoregressively, finished/idle lanes are frozen by
    the ``active`` mask.  Compiled once per (num_lanes, chunk) — admission,
    prompt length, and EOS timing never retrace."""

    def chunk_fn(params, state, feed, feed_valid, cur_tok, pos, decoding,
                 finished, lane_eos, budget_left, rng, poison):
        # feed/feed_valid: (B, C); every other lane array: (B,)
        def body(carry, xs):
            (state, cur_tok, pos, finished, emit_cnt, rng, last_logits,
             bad) = carry
            tok_feed, fv = xs
            prefill_now = fv & ~decoding & ~finished
            decode_now = decoding & ~finished & (emit_cnt < budget_left)
            active = prefill_now | decode_now
            token = jnp.where(prefill_now, tok_feed, cur_tok)[:, None]
            rng, sub = jax.random.split(rng)
            logits, state, aux = tfm.decode_step(
                params, token, state, arch, pos,
                use_kernel=use_kernel, active=active)
            # fault injection + numeric tripwire: ``poison`` NaNs chosen
            # lanes' logits for this chunk (the chaos harness); ``bad``
            # latches any non-finite logit row an *active* lane produced —
            # injected or real — for the scheduler's tick-boundary check.
            # All-False poison is an identity select: the common path is
            # bitwise-unchanged.
            logits = jnp.where(poison[:, None], jnp.float32(jnp.nan), logits)
            bad = bad | (active & ~jnp.all(jnp.isfinite(logits), axis=-1))
            if temperature > 0.0:
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)
            emitted = jnp.where(decode_now, nxt, -1)
            cur_tok = jnp.where(decode_now, nxt, cur_tok)
            finished = finished | (decode_now & (lane_eos >= 0)
                                   & (nxt == lane_eos))
            emit_cnt = emit_cnt + decode_now.astype(jnp.int32)
            pos = pos + active.astype(jnp.int32)
            last_logits = jnp.where(active[:, None], logits, last_logits)
            return ((state, cur_tok, pos, finished, emit_cnt, rng,
                     last_logits, bad),
                    (emitted, aux["live_tokens"], aux["reads_tokens"], active))

        b = feed.shape[0]
        carry0 = (state, cur_tok, pos, finished, jnp.zeros((b,), jnp.int32),
                  rng, jnp.zeros((b, arch.padded_vocab), jnp.float32),
                  jnp.zeros((b,), bool))
        carry, ys = jax.lax.scan(body, carry0, (feed.T, feed_valid.T))
        (state, cur_tok, pos, finished, emit_cnt, rng, last_logits,
         bad) = carry
        emitted, live, reads, act = ys                 # each (C, B)
        return (state, cur_tok, pos, finished, emit_cnt, rng, last_logits,
                emitted, live, reads, act, bad)

    return chunk_fn


class Scheduler:
    """Drives one lane arena to completion over a queue of requests.

    The jitted step/reset/gather functions are owned by the caller (the
    :class:`~repro.serving.engine.Engine`) so their compile caches persist
    across Scheduler instances — per-request scheduling never retraces."""

    def __init__(self, arch, params, policy, *, num_lanes: int, max_len: int,
                 chunk: int = 8, chunk_jit=None, reset_jit=None,
                 gather_jit=None, use_kernel: bool = False,
                 temperature: float = 0.0, seed: int = 0, pad_id: int = 0,
                 prefix_cache: Optional[PrefixCache] = None,
                 export_jit=None, import_jit=None, faults=None,
                 on_pressure: str = "preempt", oversub: float = 1.0,
                 slo: Optional[SLOSpec] = None):
        self.arch, self.params, self.policy = arch, params, policy
        self.num_lanes, self.max_len, self.chunk = num_lanes, max_len, chunk
        self.pad_id = pad_id
        # failure-semantics knobs: ``faults`` is a serving.faults.FaultPlan
        # (tests/benchmarks only); ``on_pressure`` picks what pool pressure
        # does ("preempt" = evict-and-resume, "ignore" = the seed behaviour —
        # silent dropped writes, kept only to demonstrate the corruption);
        # ``oversub`` > 1 admits against 1/oversub of worst-case pool demand
        # (the documented oversubscription contract preemption absorbs).
        if on_pressure not in ("preempt", "ignore"):
            raise ValueError(f"on_pressure must be 'preempt' or 'ignore', "
                             f"got {on_pressure!r}")
        if oversub < 1.0:
            raise ValueError("oversub < 1 would reserve more than worst-case "
                             "demand; shrink pool_blocks instead")
        if slo is not None and slo.min_width < 1:
            raise ValueError("SLOSpec.min_width must be >= 1")
        self.faults = faults
        self.on_pressure = on_pressure
        self.oversub = float(oversub)
        self.slo = slo
        # lifecycle observability (lifecycle_stats / pool_stats / serve.py)
        self.preemptions = 0
        self.resumes = 0
        self.failures = 0
        self.timeouts = 0
        self.completed = 0
        self.rejected = 0              # bounded-queue backpressure on arrival
        self.shed = 0                  # SLO-driven queue sheds (also rejected)
        self.degraded = 0              # width-throttled admissions
        self.offered = 0               # every submit() that passed validation
        # SLO observability: every retired result (any status) plus per-tick
        # queue-depth / active-lane samples — all host-side, zero syncs
        self._finished: List[RequestResult] = []
        self._timeline: Dict[str, List[int]] = {"queue_depth": [],
                                                "active_lanes": []}
        self._hot_until = -1           # throttle hysteresis: degrade before it
        self._chunk_jit = chunk_jit or jax.jit(make_chunk_fn(
            arch, use_kernel=use_kernel, temperature=temperature))
        self._reset_jit = reset_jit or jax.jit(self._reset_fn,
                                               static_argnames=("b", "ml"))
        self._gather_jit = gather_jit or jax.jit(tfm.gather_lanes)
        self.prefix_cache = prefix_cache
        self._export_jit = export_jit or jax.jit(tfm.export_lane_state)
        self._import_jit = import_jit or jax.jit(tfm.import_lane_state)
        self.temperature = temperature

        self.state = tfm.init_decode_state(arch, num_lanes, max_len, policy)
        self.signature = tfm.lane_state_signature(self.state)
        # per-boundary snapshot bytes are shape-derived and constant for this
        # arena; knowing them up front lets _export_prefix skip the jitted
        # export entirely when no snapshot can ever fit in either tier.
        # eval_shape on the real export (no FLOPs, no allocation) rather than
        # whole-state-bytes // num_lanes: state leaves need not be
        # lane-proportional — a paged state's shared block pool has no lane
        # axis at all, and its snapshots densify to fixed-arena shape.
        snap_shapes = jax.eval_shape(tfm.export_lane_state, self.state,
                                     jnp.int32(0))
        self._snap_nbytes = (prefix_cache_lib.snapshot_nbytes(snap_shapes)
                             + int(arch.padded_vocab) * 4)  # + fp32 logits row
        self.peak_bytes = float(policy_lib.state_peak_bytes(self.state))
        # paged-pool admission descriptors: (kv_heads, arena_blocks, block_p,
        # pool_blocks) per pooled cache — a lane's worst-case footprint is
        # now a real byte-budget question, answered host-side in _admit
        self._pool_descs = []
        for pc in policy_lib.iter_policy_caches(self.state):
            pool = getattr(pc.cache, "pool", None)
            if pool is not None:
                phys = pc.cache.phys            # (nsb, B, H, NB)
                self._pool_descs.append(
                    (int(phys.shape[-2]), int(phys.shape[-1]),
                     int(pool.block_p), int(pool.num_blocks)))
        self.rng = jax.random.PRNGKey(seed)
        self._host_rng = jax.random.PRNGKey(seed ^ 0x5EED0)

        b = num_lanes
        self.pos = np.zeros((b,), np.int32)
        self.cur_tok = np.zeros((b,), np.int32)
        self.decoding = np.zeros((b,), bool)
        self.finished = np.zeros((b,), bool)
        self.lane_eos = np.full((b,), -1, np.int32)
        self.owner: List[Optional[_ReqState]] = [None] * b
        self.chain_of = np.zeros((b,), np.int32)
        self.queue: List[_ReqState] = []
        self.active_reqs: List[_ReqState] = []
        self.ticks = 0
        self.steps = 0

    def _reset_fn(self, state, mask, b, ml):
        fresh = tfm.init_decode_state(self.arch, b, ml, self.policy)
        return tfm.reclaim_lanes(state, mask, fresh)

    # -- public ------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.width > self.num_lanes:
            raise ValueError(
                f"request width {req.width} > num_lanes {self.num_lanes}")
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: nothing to sample from")
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError("prompt + max_new exceeds scheduler max_len")
        # a request whose worst-case pool demand exceeds the pool can never
        # be admitted (it would spin the run loop forever) — and mid-flight
        # it could exhaust the pool solo, which no victim selection can fix.
        # Rejecting here also guarantees the solo-fit invariant the
        # preemption layer relies on: one active request alone always fits.
        demand = self._lane_pool_demand(len(req.prompt) + req.max_new)
        for i, d in enumerate(demand):
            if req.width * d > self._pool_descs[i][3]:
                raise ValueError(
                    f"request {req.uid}: worst-case pool demand "
                    f"{req.width * d} blocks exceeds pool {i} capacity "
                    f"{self._pool_descs[i][3]} — unservable at any load")
        self.offered += 1
        self.queue.append(_ReqState(req, self.pad_id))

    def pool_stats(self) -> Optional[Dict[str, Any]]:
        """Paged-pool observability: live/free/allocated blocks, CoW share
        counts, fragmentation, high-water mark — aggregated over every pooled
        cache in the decode state (host-side sync; None when nothing is
        paged), plus the scheduler's request-lifecycle counters under
        ``"lifecycle"``.  Surfaced by launch/serve.py's run summary."""
        out = policy_lib.state_pool_stats(self.state)
        if out is not None:
            out["lifecycle"] = self.lifecycle_stats()
        return out

    def lifecycle_stats(self) -> Dict[str, int]:
        """Preemption / failure observability: how this scheduler's requests
        left the system.  ``preemptions`` counts evictions (a request can
        contribute several), ``resumes`` successful snapshot re-admissions;
        ``completed``/``failures``/``timeouts`` partition finished requests
        by terminal status; ``rejected`` counts bounded-queue backpressure on
        arrival, ``shed`` SLO-driven queue sheds (both retire as status
        ``rejected``), and ``degraded`` width-throttled admissions."""
        return {"preemptions": self.preemptions, "resumes": self.resumes,
                "completed": self.completed, "failures": self.failures,
                "timeouts": self.timeouts, "rejected": self.rejected,
                "shed": self.shed, "degraded": self.degraded}

    def slo_stats(self) -> Dict[str, Any]:
        """Goodput / latency observability over everything retired so far:
        goodput (fraction of offered requests finishing ``ok`` within the
        attached SLO), TTFT/TPOT percentiles over ok requests, per-status
        counts, and queue-depth / lane-utilization timeline aggregates —
        joined with :meth:`lifecycle_stats`.  Pure host arithmetic over the
        retired-result ledger."""
        out = compute_slo_stats(self._finished, self.slo,
                                offered=self.offered,
                                timeline=self._timeline,
                                num_lanes=self.num_lanes)
        out["lifecycle"] = self.lifecycle_stats()
        return out

    def run(self) -> List[RequestResult]:
        """Run the queue to completion; results in completion order.

        Termination is unconditional: every iteration either advances the
        clock (idle or chunk tick) or retires a request (completion, failure,
        timeout, retry exhaustion), and a queue that can never admit again —
        idle lanes, every request ready, no pending fault release — is
        failed out rather than spun on (see :meth:`_starved`)."""
        results: List[RequestResult] = []
        while self.queue or self.active_reqs:
            if self.faults is not None:
                self.faults.on_tick(self, results)
            self._expire_queued(results)
            self._bound_queue(results)
            self._shed_queued(results)
            # fork before admitting: freed lanes must reach held hyperscale
            # requests before new admissions can take them
            self._fork_ready()
            self._admit()
            self._fork_ready()
            if not any(o is not None for o in self.owner):
                if not self.queue and not self.active_reqs:
                    break
                if self._starved():
                    self._fail_starved(results)
                    continue
                # nothing admitted yet (future arrivals / backoff): tick time
                self._record_timeline()
                self.ticks += 1
                continue
            self._tick(results)
        return results

    # -- lifecycle stages --------------------------------------------------

    def _idle_lanes(self) -> List[int]:
        return [l for l in range(self.num_lanes) if self.owner[l] is None]

    def _lane_pool_demand(self, tokens: int) -> List[int]:
        """Worst-case pool blocks ONE chain of a ``tokens``-token request can
        ever hold, per pooled descriptor: ``H * min(ceil(T / bp), NB)`` — the
        request can't map more blocks than its tokens span, and the cache's
        logical arena caps retention at ``NB`` blocks per head regardless.
        Empty when nothing is paged (fixed arenas: admission is lanes-only).
        """
        return [h * min(-(-tokens // bp), nb)
                for (h, nb, bp, _) in self._pool_descs]

    def _reserved_demand(self, tokens: int, width: int) -> List[int]:
        """Pool blocks admission reserves for a ``tokens``-token request at
        serving width ``width``: worst case scaled by the oversubscription
        factor.  ``oversub == 1`` (the default) reserves the full width-W
        worst case — a fixed-arena-sound contract under which the pool can
        *never* exhaust via the public API (the CoW fork shares pages, so
        divergence only grows demand toward the reserved bound, never past
        it).  ``oversub > 1`` is the explicit contract change: admit more,
        and let the preemption layer absorb the overflow when divergence
        actually materializes."""
        return [math.ceil(width * d / self.oversub)
                for d in self._lane_pool_demand(tokens)]

    def _pool_fits(self, tokens: int, width: int) -> bool:
        """Byte-budget admission: would admitting ``req`` let total
        *reserved* pool demand exceed any pool's block count?  Host-side
        static arithmetic — no device sync.  With the default provisioning
        (``pool_blocks = B*H*NB``) this can never bind (lane demand is at
        most ``H*NB``), so fixed-arena-equivalent configs admit identically;
        an operator shrinks ``pool_blocks`` to oversubscribe lanes against
        live-token footprint (the hyper-scaling capacity win), and
        ``oversub > 1`` additionally under-reserves worst-case demand (see
        :meth:`_reserved_demand` — preemption absorbs what materializes)."""
        if not self._pool_descs:
            return True
        demand = self._reserved_demand(tokens, width)
        reserved = [0] * len(self._pool_descs)
        for r in self.active_reqs:
            d = self._reserved_demand(len(r.req.prompt) + r.req.max_new,
                                      r.width)
            for i in range(len(reserved)):
                reserved[i] += d[i]
        return all(reserved[i] + demand[i] <= self._pool_descs[i][3]
                   for i in range(len(self._pool_descs)))

    def _admit(self) -> None:
        """Admit queued requests into idle lanes — FIFO with skip-scan.

        A width-W request occupies one prefill lane now and W-1 fork lanes
        later; those W-1 are *reserved* at admission (``sum(width)`` over
        admitted requests never exceeds ``num_lanes``), which makes the fork
        wait in :meth:`_fork_ready` deadlock- and starvation-free: held
        requests' lanes can never be re-admitted out from under them.  Paged
        states add a second gate (:meth:`_pool_fits`): admission reserves
        worst-case pool blocks too (scaled by ``oversub``), so an
        oversubscribed lane count can never deadlock the shared pool.

        Preempted requests re-admit through the same scan once their backoff
        expires, with one extra gate: actual free pages must cover their full
        unscaled demand (a resumed victim that would land straight back
        under pressure ping-pongs forever — better to keep waiting)."""
        # idle lanes are always pristine (fresh at construction; _tick
        # reclaims every lane of a completing request, fork targets included;
        # chunk steps never mutate inactive lanes) — no reset needed here
        while True:
            idle = self._idle_lanes()
            if not idle:
                break
            reserved = sum(r.width - len(r.lanes)
                           for r in self.active_reqs)
            avail = len(idle) - reserved
            free = None                  # lazy free-page readback, ≤1 / pass
            nxt, nxt_w = None, 0
            for r in self.queue:
                if not r.ready(self.ticks):
                    continue
                w = self._effective_width(r)
                if w > avail or not self._pool_fits(
                        len(r.req.prompt) + r.req.max_new, w):
                    continue
                if r.snaps is not None and self._pool_descs \
                        and self._pressure_possible():
                    if free is None:
                        free = self._free_blocks()
                    need = self._lane_pool_demand(
                        len(r.req.prompt) + r.req.max_new)
                    if any(free[i] < len(r.snaps) * need[i]
                           for i in range(len(need))):
                        continue         # resume free-gate: wait it out
                nxt, nxt_w = r, w
                break
            if nxt is None:
                break
            self.queue.remove(nxt)
            if nxt.snaps is not None:
                self._resume(nxt, idle)
                continue
            if nxt_w < nxt.width:
                # throttle rung: serve the hyper-scaling request at reduced
                # width (degraded quality beats a preemption storm); arm the
                # hysteresis window so admission doesn't flap back
                nxt.degrade(nxt_w)
                self.degraded += 1
                self._hot_until = max(self._hot_until,
                                      self.ticks + self.slo.cooldown_ticks)
            lane = idle.pop(0)
            self.owner[lane] = nxt
            self.chain_of[lane] = 0
            nxt.lanes = [lane]
            nxt.admitted_tick = self.ticks
            self.active_reqs.append(nxt)
            self.pos[lane] = 0
            self.decoding[lane] = False
            self.finished[lane] = False
            self.lane_eos[lane] = -1 if nxt.req.eos_id is None else nxt.req.eos_id
            self._import_prefix(nxt, lane)

    # -- SLO & overload control (degradation ladder) -------------------------

    def _effective_width(self, r: _ReqState) -> int:
        """The width this request would be admitted at right now — the
        *throttle* rung of the degradation ladder.  Full width unless an
        SLOSpec enables width degradation AND either the throttle window is
        hot (lane demand exceeds the arena, or hysteresis from a recent
        throttle/preemption) or the pool fits the request only at reduced
        width.  Resumed requests keep their snapshot width (their lanes'
        state already has that shape).  Pure host arithmetic."""
        w = r.width
        if r.snaps is not None or self.slo is None \
                or not self.slo.degrade_width:
            return w
        lo = min(w, max(1, self.slo.min_width))
        if lo == w:
            return w
        if self._throttled():
            return lo
        tokens = len(r.req.prompt) + r.req.max_new
        if self._pool_descs and not self._pool_fits(tokens, w) \
                and self._pool_fits(tokens, lo):
            return lo                 # degrade instead of waiting to preempt
        return w

    def _throttled(self) -> bool:
        """Is the throttle window hot?  Overload signal: the *ready backlog*
        alone (arrived, unadmitted lane demand) exceeds the whole arena —
        even an empty arena could not take the waiting traffic at full
        width.  Active lanes deliberately don't count: one wide request plus
        a single arrival is a momentary queue, not overload, and must not
        degrade traffic a calm system would serve at full width.  Observing
        overload arms ``cooldown_ticks`` of hysteresis, so the throttle
        disengages only after a quiet cooldown — admission cannot flap
        between full-width and degraded and feed the preemption path."""
        if self.ticks < self._hot_until:
            return True
        backlog = sum(q.width for q in self.queue if q.ready(self.ticks))
        if backlog > self.num_lanes:
            self._hot_until = self.ticks + self.slo.cooldown_ticks
            return True
        return False

    def _min_prefill_ticks(self, r: _ReqState) -> int:
        """Optimistic prefill ticks if admitted THIS tick: chunked suffix
        after the longest cached prefix (read-only radix probe — no stats,
        no recency, no device work).  A lower bound: prefix reuse and idle
        lanes can only make the real admission this fast, never faster."""
        plen = len(r.req.prompt)
        cached = 0
        if self.prefix_cache is not None:
            cached = min(plen, self.prefix_cache.covered(
                self.signature, r.req.prompt))
        return -(-(plen - cached) // self.chunk)

    def _min_service_ticks(self, r: _ReqState) -> int:
        """Provable lower bound on admission → completion ticks: optimistic
        prefill plus the fewest decode ticks any outcome allows (one token —
        the first sample could be EOS — when ``eos_id`` is set, the full
        ``max_new`` budget otherwise).  Matches the tick mechanics exactly:
        token 0 is sampled at the post-prefill boundary and the final chunk
        that completes the request has already advanced the clock."""
        gen = 1 if r.req.eos_id is not None else r.req.max_new
        return self._min_prefill_ticks(r) + max(-(-(gen - 1) // self.chunk), 1)

    def _bound_queue(self, results: List[RequestResult]) -> None:
        """Bounded-queue backpressure (``max_queue``): when the backlog of
        *arrived*, never-admitted requests exceeds the bound, the newest
        arrivals bounce off the door with status ``rejected`` — a definite
        outcome instead of an unbounded wait.  The bound is enforced at
        arrival time against the live backlog, not at :meth:`submit` — a
        preloaded trace's future arrivals never count against today's queue.
        Preempted requests (``admitted_tick >= 0``) occupy depth but are
        never bounced: they were already accepted once."""
        slo = self.slo
        if slo is None or slo.max_queue is None:
            return
        arrived = [r for r in self.queue if r.req.arrival <= self.ticks]
        fresh = [r for r in arrived if r.admitted_tick == -1]
        over = len(arrived) - slo.max_queue
        # newest first: FIFO order is the door's admission promise
        for r in sorted(fresh, key=lambda r: (r.req.arrival, r.req.uid),
                        reverse=True)[:max(0, over)]:
            self.queue.remove(r)
            r.status = "rejected"
            self.rejected += 1
            self._finish(r, results, 0.0)

    def _shed_queued(self, results: List[RequestResult]) -> None:
        """The *shed* rung: reject queued requests that provably cannot meet
        their deadline (or TTFT SLO) even if admitted this very tick.  Today
        is the cheapest moment to say no — a shed request has burned zero
        prefill reads (``admitted_tick == -1``), unlike a ``timeout``, which
        fires only after the deadline has already passed and any prefill
        spend is lost.  Preempted requests are exempt (their prefill is
        already paid; expiry handles them).  Pure host arithmetic — the
        projection adds no device syncs and no compiles."""
        slo = self.slo
        if slo is None or not slo.shed:
            return
        for r in list(self.queue):
            if r.admitted_tick != -1 or r.req.arrival > self.ticks:
                continue
            arr = r.req.arrival
            dl = r.req.deadline
            doomed = (dl is not None and
                      self.ticks + self._min_service_ticks(r) > arr + dl)
            if not doomed and slo.ttft_ticks is not None:
                doomed = (self.ticks + self._min_prefill_ticks(r)
                          > arr + slo.ttft_ticks)
            if doomed:
                self.queue.remove(r)
                r.status = "rejected"
                self.shed += 1
                self._finish(r, results, 0.0)

    def _finish(self, r: _ReqState, results: List[RequestResult],
                peak_bytes: float) -> None:
        """Single choke point for retiring a request: the result goes to the
        caller AND onto the ledger :meth:`slo_stats` aggregates."""
        res = r.result(peak_bytes, self.ticks)
        results.append(res)
        self._finished.append(res)

    def _record_timeline(self) -> None:
        self._timeline["queue_depth"].append(len(self.queue))
        self._timeline["active_lanes"].append(
            sum(o is not None for o in self.owner))

    # -- preemption, failure semantics, pool pressure ------------------------

    def _pressure_possible(self) -> bool:
        """Can the pool come under pressure at all?  With the default sound
        admission (``oversub == 1``) and no fault injector, reserved demand
        bounds real demand and exhaustion is impossible — every pressure
        readback and preemption check is skipped, so the sound path pays
        zero extra host syncs."""
        return self.faults is not None or self.oversub > 1.0

    def _free_blocks(self) -> List[int]:
        """Free pages per pooled descriptor, worst row over stacked
        superblocks (each superblock row allocates independently, so the
        scarcest row binds first).  A ``sanctioned("pool-pressure")``
        readback — only taken when :meth:`_pressure_possible`."""
        out = []
        with sanctioned("pool-pressure"):
            for pc in policy_lib.iter_policy_caches(self.state):
                pool = getattr(pc.cache, "pool", None)
                if pool is None:
                    continue
                ref = np.asarray(pool.ref)
                flat = ref.reshape(-1, ref.shape[-1])
                out.append(int((flat == 0).sum(axis=-1).min()))
        return out

    def _ghost_rows(self) -> List[int]:
        """Worst-row injector-held ghost pages per pooled descriptor (all
        zero without a fault plan) — pages reserved by nobody the scheduler
        can evict, so they shrink the effective pool."""
        out = [0] * len(self._pool_descs)
        if self.faults is None:
            return out
        for i in range(len(out)):
            g = self.faults.ghosts.get(i)
            if g is not None:
                out[i] = int(np.asarray(g).reshape(-1, g.shape[-1])
                             .sum(axis=-1).max())
        return out

    def _relieve_pressure(self, results: List[RequestResult]) -> None:
        """Preemptive eviction at the tick boundary: while the worst-case
        pool demand of the active set (plus injector-held ghost pages) does
        not fit the pool, preempt the youngest active request (latest
        admission: its eviction wastes the least finished work, and FIFO
        order keeps the oldest request making progress — no starvation).

        The check is exact, not heuristic: a request never holds more pages
        than its worst-case demand (logical blocks cap retention; a CoW copy
        replaces a mapping, it doesn't add one), so an active set whose
        worst cases fit can never exhaust the pool mid-chunk — the same
        bound :meth:`submit` enforces for a single request.  Pure host
        arithmetic over the admission descriptors and the host-side ghost
        ledger: the sound path costs zero device syncs."""
        ghost = self._ghost_rows()
        while self.active_reqs:
            total = [0] * len(self._pool_descs)
            for r in self.active_reqs:
                d = self._lane_pool_demand(
                    len(r.req.prompt) + r.req.max_new)
                w = max(len(r.lanes), r.width)
                for i in range(len(total)):
                    total[i] += w * d[i]
            if all(total[i] + ghost[i] <= self._pool_descs[i][3]
                   for i in range(len(total))):
                return
            victim = max(self.active_reqs,
                         key=lambda r: (r.admitted_tick, r.req.uid))
            self._preempt(victim, results)

    def _preempt(self, r: _ReqState, results: List[RequestResult],
                 reason: str = "pool pressure") -> None:
        """Evict ``r`` without corrupting it: snapshot every lane's complete
        decode state to host (the same per-policy export the prefix cache's
        cold tier round-trips bitwise), free its lanes and pool pages, and
        requeue with exponential backoff.  Chains, meters, and consumed
        prompt ride the host-side request state, so resume re-prefills
        nothing.  Past ``max_preempts`` the request fails instead — retries
        are bounded, statuses definite."""
        r.preempt_count += 1
        self.preemptions += 1
        if self.slo is not None:
            # a preemption is the strongest overload signal there is: arm
            # the throttle window so follow-on admissions degrade width
            # instead of re-inflating demand (the ladder's anti-storm rung)
            self._hot_until = max(self._hot_until,
                                  self.ticks + self.slo.cooldown_ticks)
        lanes = list(r.lanes)
        give_up = r.preempt_count > r.req.max_preempts
        if not give_up:
            r.snaps = [prefix_cache_lib.to_host(
                self._export_jit(self.state, jnp.int32(lane)),
                tag="preempt-snapshot") for lane in lanes]
            r.saved = {
                "pos": self.pos[lanes].copy(),
                "cur_tok": self.cur_tok[lanes].copy(),
                "decoding": self.decoding[lanes].copy(),
                "finished": self.finished[lanes].copy(),
                "lane_eos": self.lane_eos[lanes].copy(),
            }
        self.active_reqs.remove(r)
        self._release_lanes(r, lanes)
        if give_up:
            r.status = "failed"
            self.failures += 1
            self._finish(r, results, self._req_peak(len(lanes)))
        else:
            r.resume_at = self.ticks + (1 << (r.preempt_count - 1))
            self.queue.append(r)

    def _resume(self, r: _ReqState, idle: List[int]) -> None:
        """Re-admit a preempted request from its host snapshots: import each
        lane's snapshot into a pristine lane (zero prompt re-prefill),
        restore the host lane scalars, and continue exactly where the
        preemption stopped.  Greedy decoding carries no RNG stream, so the
        continuation is bitwise-equal to the uninterrupted run (ref
        attention; the kernel's paged table order is reassociation-sensitive
        — see docs/serving.md)."""
        lanes = idle[:len(r.snaps)]
        for j, lane in enumerate(lanes):
            self.state = self._import_jit(self.state, r.snaps[j],
                                          jnp.int32(lane))
            self._reapply_ghosts()
            self.owner[lane] = r
            self.chain_of[lane] = j
            self.pos[lane] = r.saved["pos"][j]
            self.cur_tok[lane] = r.saved["cur_tok"][j]
            self.decoding[lane] = r.saved["decoding"][j]
            self.finished[lane] = r.saved["finished"][j]
            self.lane_eos[lane] = r.saved["lane_eos"][j]
        r.lanes = list(lanes)
        r.snaps = None
        r.saved = None
        self.active_reqs.append(r)
        self.resumes += 1

    def _retire(self, r: _ReqState, status: str,
                results: List[RequestResult]) -> None:
        """Terminal non-ok transition: reclaim lanes + pool pages, count,
        emit the result.  The failed/timed-out request stops squatting the
        arena immediately."""
        r.status = status
        if status == "timeout":
            self.timeouts += 1
        else:
            self.failures += 1
        self.active_reqs.remove(r)
        lanes = list(r.lanes)
        self._release_lanes(r, lanes)
        self._finish(r, results, self._req_peak(len(lanes)))

    def _release_lanes(self, r: _ReqState, lanes: List[int]) -> None:
        reclaim = np.zeros((self.num_lanes,), bool)
        for lane in lanes:
            self.owner[lane] = None
            reclaim[lane] = True
            self.decoding[lane] = False
            self.finished[lane] = False
            self.pos[lane] = 0
            self.cur_tok[lane] = 0
            self.lane_eos[lane] = -1
        r.lanes = []
        self._reset(reclaim)

    def _req_peak(self, n_lanes: int) -> float:
        return self.peak_bytes * n_lanes / self.num_lanes

    def _reapply_ghosts(self) -> None:
        # lifecycle ops (gather/reclaim/import) recompute ref = recount(phys),
        # which would silently drop fault-injected ghost refs — re-add them so
        # injected pool pressure survives the ops it is meant to stress
        if self.faults is not None and self.faults.has_ghosts():
            self.state = self.faults.reapply(self.state)

    def _expire_queued(self, results: List[RequestResult]) -> None:
        """Deadline enforcement for requests still *waiting* (never admitted,
        or preempted and backing off): past the deadline they time out
        without ever touching a lane.

        Boundary semantics (pinned by tests/test_scheduler.py): a deadline
        ``dl`` grants the closed tick window ``[arrival, arrival + dl]``.
        Strict ``>`` here and in the active-path check in :meth:`_tick` —
        both fire first at ``ticks == arrival + dl + 1``, and a request
        completing exactly at ``arrival + dl`` is ``ok`` (completion wins
        the tie in :meth:`_tick`, which collects tokens before the deadline
        scan)."""
        for r in list(self.queue):
            dl = r.req.deadline
            if dl is not None and self.ticks - r.req.arrival > dl:
                self.queue.remove(r)
                r.status = "timeout"
                self.timeouts += 1
                self._finish(r, results, 0.0)

    def _starved(self) -> bool:
        """True when nothing can ever change: all lanes idle, every queued
        request past arrival and backoff, the admission scan just admitted
        none of them, and no pending fault release can free the pages they
        are waiting on.  (Unreachable without injected ghost pages: with
        idle lanes and an empty pool the solo-fit bound admits any submitted
        request.)"""
        if any(not r.ready(self.ticks) for r in self.queue):
            return False
        if self.faults is not None and self.faults.can_unblock():
            return False
        return True

    def _fail_starved(self, results: List[RequestResult]) -> None:
        for r in list(self.queue):
            self.queue.remove(r)
            r.status = "failed"
            self.failures += 1
            self._finish(r, results, 0.0)

    def _import_prefix(self, r: _ReqState, lane: int) -> None:
        """Longest-cached-prefix import: the lane resumes at token boundary L
        and chunked prefill feeds only ``prompt[L:]``.  A hot-tier hit hands
        back a device-resident slab slice, so the jitted lane insert below is
        device-to-device — zero host↔device snapshot bytes; a cold hit ships
        its host snapshot up through the same jit (and promotes).  The
        avoided prefill reads go on the request's *saved* axis (``kv_reads``
        stays the honest paid integral); a full-prompt hit skips prefill
        entirely, with the cached boundary logits standing in as the
        hold-state sample."""
        if self.prefix_cache is None:
            return
        hit = self.prefix_cache.lookup(self.signature, r.req.prompt)
        if hit is None:
            return
        if self._pool_descs and self._pressure_possible():
            # a paged prefix import bulk-allocates the whole boundary's pages
            # up front; under pressure that can exhaust the pool mid-import —
            # degrade to a cold prefill instead (pays reads, stays correct)
            free = self._free_blocks()
            need = self._lane_pool_demand(hit.length)
            if any(free[i] < need[i] for i in range(len(need))):
                return
        self.state = self._import_jit(self.state, hit.snapshot,
                                      jnp.int32(lane))
        self._reapply_ghosts()
        self.pos[lane] = hit.length
        r.consumed = hit.length
        r.prefill_meter.observe_saved_reads(hit.reads_cum)
        if hit.length == len(r.req.prompt):
            with sanctioned("tick-boundary"):  # once per admission
                r.hold_logits = np.asarray(hit.logits).copy()

    def _want_prefix_export(self, r: _ReqState) -> bool:
        """Gate the per-chunk snapshot export on pure host checks, so the
        skip paths (no cache, over-budget snapshot, off-stride boundary,
        boundary already in the tree, no earlier traffic asked under
        ``second-miss``) cost no device sync at all — at most one radix
        descent total (``want_export``)."""
        if self.prefix_cache is None:
            return False
        if not self.prefix_cache.can_store(self._snap_nbytes):
            return False                   # can never fit: skip the export
        prefix = r.req.prompt[:r.consumed]
        return self.prefix_cache.want_export(
            self.signature, prefix, chunk_index=r.prefill_chunks,
            final=r.consumed == len(r.req.prompt))

    def _export_prefix(self, r: _ReqState, lane: int, logits) -> None:
        """Offer the just-prefilled boundary ``prompt[:consumed]`` to the
        radix tree.  ``reads_cum`` is what a cold prefill of this prefix
        reads — the request's own paid prefill reads plus whatever its own
        admission-time import saved (the invariant holds recursively, so hits
        on hits stay honest).  ``logits`` predict the boundary token, letting
        a later full-prompt hit skip prefill entirely.

        The export is *deferred*: one jitted lane slice hands the cache a
        device snapshot (and an unsynced device logits row).  With a hot
        tier the snapshot goes straight into the device slab — zero
        host↔device bytes, no stall of the decode scan — and is only
        materialized to host if the hot tier later demotes it.  Without a
        hot tier the cache materializes immediately (the seed behaviour).
        ``second-miss`` export gating (see :meth:`_want_prefix_export`)
        bounds how often this O(arena) copy happens at all: cold unshared
        prompts export nothing."""
        prefix = r.req.prompt[:r.consumed]
        snap = self._export_jit(self.state, jnp.int32(lane))
        reads_cum = r.prefill_meter.kv_reads_saved + r.prefill_meter.kv_reads
        self.prefix_cache.insert(self.signature, prefix, snap, logits,
                                 reads_cum)

    def _fork_ready(self) -> None:
        """hold → decode: fork prefilled lanes into W chains, sample token 0."""
        for r in list(self.active_reqs):
            if r.hold_logits is None or len(r.lanes) == r.width:
                continue
            need = r.width - 1
            idle = self._idle_lanes()
            if len(idle) < need:
                continue                      # wait for lanes to free up
            src = np.arange(self.num_lanes, dtype=np.int32)
            for lane in idle[:need]:
                src[lane] = r.lanes[0]
                self.owner[lane] = r
                self.chain_of[lane] = len(r.lanes)
                r.lanes.append(lane)
            self.state = self._gather_jit(self.state, jnp.asarray(src))
            self._reapply_ghosts()
            self.pos[r.lanes] = self.pos[r.lanes[0]]
            self.lane_eos[r.lanes] = self.lane_eos[r.lanes[0]]
            self._start_decode(r)
        for r in list(self.active_reqs):      # width-1 fast path
            if r.hold_logits is not None and len(r.lanes) == r.width \
                    and not self.decoding[r.lanes].any():
                self._start_decode(r)

    def _start_decode(self, r: _ReqState) -> None:
        """Sample each chain's first token from the shared prefill logits."""
        w = len(r.lanes)
        logits = jnp.asarray(r.hold_logits)[None].repeat(w, axis=0)
        if self.temperature > 0.0:
            self._host_rng, sub = jax.random.split(self._host_rng)
            first = jax.random.categorical(sub, logits / self.temperature,
                                           axis=-1)
        else:
            first = jnp.argmax(logits, axis=-1)
        with sanctioned("tick-boundary"):      # once per request, not per step
            first = np.asarray(first, np.int32)
        if r.first_token_tick < 0:
            r.first_token_tick = self.ticks    # TTFT endpoint
        r.decode_meter.observe_step([0.0], new_tokens=w,
                                    reads_tokens_per_layer=[0.0])
        for c, lane in enumerate(r.lanes):
            tok = int(first[c])
            r.chains[c].append(tok)
            self.cur_tok[lane] = tok
            self.decoding[lane] = True
            if (r.req.eos_id is not None and tok == r.req.eos_id) \
                    or len(r.chains[c]) >= r.req.max_new:
                self.finished[lane] = True
        r.hold_logits = None

    def _tick(self, results: List[RequestResult]) -> None:
        self._record_timeline()
        # preemptive pressure relief BEFORE dispatch: post-hoc preemption
        # cannot be bitwise (writes were already dropped mid-chunk), so the
        # margin check runs at the boundary, where snapshots are still exact
        if self.on_pressure == "preempt" and self._pool_descs \
                and self._pressure_possible():
            self._relieve_pressure(results)
            if not self.active_reqs:
                self.ticks += 1        # everything evicted: time still passes
                return
        b, c = self.num_lanes, self.chunk
        feed = np.zeros((b, c), np.int32)
        feed_valid = np.zeros((b, c), bool)
        budget_left = np.zeros((b,), np.int32)
        prefill_take: Dict[int, int] = {}
        for lane in range(b):
            r = self.owner[lane]
            if r is None:
                continue
            if self.decoding[lane]:
                budget_left[lane] = r.req.max_new - len(
                    r.chains[self.chain_of[lane]])
            elif r.hold_logits is None and lane == r.lanes[0]:
                take = min(c, len(r.req.prompt) - r.consumed)
                if take > 0:
                    feed[lane, :take] = r.req.prompt[r.consumed:r.consumed + take]
                    feed_valid[lane, :take] = True
                    prefill_take[lane] = take
        poison = (self.faults.poison(self.ticks, b)
                  if self.faults is not None else None)
        if poison is None:
            poison = np.zeros((b,), bool)

        out = self._chunk_jit(
            self.params, self.state, jnp.asarray(feed), jnp.asarray(feed_valid),
            jnp.asarray(self.cur_tok), jnp.asarray(self.pos),
            jnp.asarray(self.decoding), jnp.asarray(self.finished),
            jnp.asarray(self.lane_eos), jnp.asarray(budget_left), self.rng,
            jnp.asarray(poison))
        (self.state, cur_tok, pos, finished, _, self.rng, last_logits,
         emitted, live, reads, act, bad) = out
        # the scheduler's ONE sanctioned host sync: once per chunk, never
        # per step (the host-sync tripwire in repro.analysis enforces this).
        # The failure tripwires ride the same boundary: the chunk's latched
        # bad-logit mask and the pool's exhausted latch are chunk outputs,
        # not extra stalls.
        with sanctioned("tick-boundary"):
            self.cur_tok = np.array(cur_tok)   # writable host copies
            self.pos = np.array(pos)
            self.finished = np.array(finished)
            emitted = np.asarray(emitted)      # (C, B)
            live = np.asarray(live)
            reads = np.asarray(reads)
            act = np.asarray(act)
            bad = np.asarray(bad)              # (B,) non-finite logits seen
            exhausted = (self._pools_exhausted()
                         if self._pool_descs and self.on_pressure != "ignore"
                         else False)
        self.ticks += 1
        self.steps += c

        # failure semantics, decided BEFORE token/hold collection: a doomed
        # request keeps nothing from a corrupt or poisoned chunk
        doomed: Dict[int, Tuple[_ReqState, str]] = {}
        if exhausted:
            # the pool latched exhausted INSIDE the chunk: some write was
            # silently dropped, and post-hoc attribution is impossible —
            # every request that stepped this chunk is suspect.  The
            # preemptive margin check above makes this a loud backstop (it
            # fires only when injected faults ate pages mid-chunk or the
            # margin bound was defeated), never the normal pressure path.
            for r in self.active_reqs:
                if any(act[:, lane].any() for lane in r.lanes):
                    doomed[id(r)] = (r, "failed")
            self._clear_pool_flags()
        for lane in range(b):
            r = self.owner[lane]
            if r is not None and bad[lane]:
                # NaN/Inf logit tripwire: fail the poisoned request and
                # reclaim its lanes instead of decoding garbage forever
                doomed[id(r)] = (r, "failed")

        # per-request, per-step metering from this request's own lanes only
        for r in self.active_reqs:
            lanes = r.lanes
            meter = (r.decode_meter if self.decoding[lanes[0]]
                     else r.prefill_meter)
            for t in range(c):
                if not act[t, lanes].any():
                    continue
                meter.observe_step(
                    [float(live[t, lanes].sum())],
                    new_tokens=int((emitted[t, lanes] >= 0).sum()),
                    reads_tokens_per_layer=[float(reads[t, lanes].sum())])

        # prefill completion -> hold (host samples token 0 next tick)
        ll = None
        for lane, take in prefill_take.items():
            r = self.owner[lane]
            if id(r) in doomed:
                continue
            r.consumed += take
            r.prefill_chunks += 1
            if r.consumed == len(r.req.prompt):
                if ll is None:
                    with sanctioned("tick-boundary"):   # prefill completion
                        ll = np.asarray(last_logits)
                r.hold_logits = ll[lane].copy()
            if self._want_prefix_export(r):
                # deferred export: the device logits row rides along unsynced
                # (ll materialization above is only for prefill completion)
                self._export_prefix(r, lane, last_logits[lane])

        # collect emitted tokens; EOS / budget exhaustion finishes chains.
        # Doomed requests collect nothing: a token sampled after a dropped
        # pool write or from poisoned logits must never reach a result.
        for lane in range(b):
            r = self.owner[lane]
            if r is None or not self.decoding[lane] or id(r) in doomed:
                continue
            chain = r.chains[self.chain_of[lane]]
            for t in range(c):
                tok = emitted[t, lane]
                if tok >= 0:
                    chain.append(int(tok))
            if self.finished[lane] or len(chain) >= r.req.max_new:
                r.chain_done[self.chain_of[lane]] = True
                self.finished[lane] = True

        # reclaim lanes of completed requests
        done = [r for r in self.active_reqs
                if r.done and id(r) not in doomed]
        if done:
            reclaim = np.zeros((b,), bool)
            for r in done:
                self.active_reqs.remove(r)
                self.completed += 1
                if self.prefix_cache is not None:
                    # EOS reclamation offers the finished prompt's prefix
                    # chain back to the tree (LRU recency refresh)
                    self.prefix_cache.touch(self.signature, r.req.prompt)
                self._finish(r, results, self._req_peak(len(r.lanes)))
                for lane in r.lanes:
                    self.owner[lane] = None
                    reclaim[lane] = True
                    self.decoding[lane] = False
                    self.finished[lane] = False
                    self.pos[lane] = 0
            self._reset(reclaim)

        # deadlines: completion above wins a tie; anything still active past
        # its deadline times out now (definite status, lanes reclaimed).
        # Strict ``>`` against the post-increment clock — the same boundary
        # as _expire_queued: the closed window [arrival, arrival + dl] is
        # usable, the first doomed tick is arrival + dl + 1 (pinned by
        # tests/test_scheduler.py::test_deadline_boundary_exact_tick)
        for r in list(self.active_reqs):
            dl = r.req.deadline
            if dl is not None and self.ticks - r.req.arrival > dl:
                doomed.setdefault(id(r), (r, "timeout"))
        for r, status in doomed.values():
            self._retire(r, status, results)

    def _pools_exhausted(self) -> bool:
        # reads only the per-pool exhausted scalars — part of the chunk's
        # output state, synced inside the caller's tick-boundary region
        for pc in policy_lib.iter_policy_caches(self.state):
            pool = getattr(pc.cache, "pool", None)
            if pool is not None and bool(np.asarray(pool.exhausted).any()):
                return True
        return False

    def _clear_pool_flags(self) -> None:
        """Un-latch ``exhausted`` once the backstop has failed the affected
        requests — the latch is sticky device state, and leaving it set
        would condemn every later request on the same pool."""
        self.state = policy_lib.map_pooled_caches(
            self.state,
            lambda idx, cache: dataclasses.replace(
                cache, pool=block_pool.clear_flags(cache.pool)))

    def _reset(self, mask: np.ndarray) -> None:
        self.state = self._reset_jit(self.state, jnp.asarray(mask),
                                     b=self.num_lanes, ml=self.max_len)
        self._reapply_ghosts()


# -- SLO accounting (shared by Scheduler.slo_stats and benchmarks) -----------


def slo_attained(res: RequestResult, slo: Optional[SLOSpec]) -> bool:
    """Did this request land inside the SLO?  ``ok`` status is necessary;
    with no SLO attached it is also sufficient.  Measuring an *uncontrolled*
    run against the same SLOSpec (as ``benchmarks/slo_harness.py`` does) is
    the point of keeping this a pure function of the result."""
    if res.status != "ok":
        return False
    if slo is None:
        return True
    if slo.ttft_ticks is not None and not (
            0 <= res.ttft_ticks <= slo.ttft_ticks):
        return False
    if slo.tpot_ticks is not None and res.tpot_ticks > slo.tpot_ticks:
        return False
    return True


def _pctiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": -1.0, "p90": -1.0, "max": -1.0}
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)), "max": float(a.max())}


def compute_slo_stats(results: List[RequestResult],
                      slo: Optional[SLOSpec] = None, *,
                      offered: Optional[int] = None,
                      timeline: Optional[Dict[str, List[int]]] = None,
                      num_lanes: Optional[int] = None) -> Dict[str, Any]:
    """Goodput + latency aggregates over retired results.

    Goodput is the fraction of *offered* requests (``offered`` defaults to
    ``len(results)``) that finished ``ok`` within ``slo`` — rejected, shed,
    timed-out, and failed requests all count against it, which is exactly
    why shedding hopeless work can raise it: lanes spend their ticks on
    requests that can still land inside the SLO."""
    offered = len(results) if offered is None else int(offered)
    by_status: Dict[str, int] = {}
    for r in results:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    ok = [r for r in results if r.status == "ok"]
    within = sum(1 for r in results if slo_attained(r, slo))
    out: Dict[str, Any] = {
        "offered": offered,
        "finished": len(results),
        "statuses": by_status,
        "ok": len(ok),
        "ok_within_slo": int(within),
        "goodput": within / offered if offered else 0.0,
        "degraded": sum(1 for r in results if r.degraded),
        "ttft": _pctiles([float(r.ttft_ticks) for r in ok
                          if r.ttft_ticks >= 0]),
        "tpot": _pctiles([float(r.tpot_ticks) for r in ok
                          if r.ttft_ticks >= 0]),
    }
    if timeline is not None:
        qd = timeline.get("queue_depth", [])
        al = timeline.get("active_lanes", [])
        out["queue_depth"] = {"mean": float(np.mean(qd)) if qd else 0.0,
                              "max": int(max(qd)) if qd else 0}
        out["lane_util"] = (float(np.mean(al)) / num_lanes
                            if al and num_lanes else 0.0)
    return out
