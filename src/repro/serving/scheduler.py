"""Continuous-batching scheduler: the serving engine's admission / prefill /
fork / decode / reclaim lifecycle over a fixed arena of batch *lanes*.

The paper's hyper-scaling claim is a serving-time claim — more chains per
fixed KV budget — so the engine must actually serve: requests arrive over
time with different prompt lengths and stop at different steps.  This module
replaces the lockstep fixed batch with a real scheduler:

* **Lanes.**  The decode state is provisioned once for ``num_lanes`` batch
  rows.  Lanes are independent: each sits at its own sequence position
  (per-lane ``length`` in every cache, per-lane ``pos_t`` through RoPE and
  window masking) and is switched on/off per step by the ``active`` mask of
  :func:`repro.models.transformer.decode_step`.
* **Chunked prefill.**  Prompts are teacher-forced through the *decode* path
  in fixed-size T-chunks (one ``lax.scan`` compiled per chunk size, not one
  trace per prompt length), preserving exact per-policy eviction semantics —
  TOVA/H2O/DMS evict mid-prompt exactly as a per-token loop would.  Decoding
  lanes keep decoding inside the same chunk: prefill and decode interleave in
  one jitted step, which is what makes the batching *continuous*.
* **Shared-prefill fork.**  A width-W (hyper-scaling) request prefills its
  prompt in ONE lane; the finished cache is then forked into W chains via
  :meth:`KVPolicy.fork_cache` (`gather_lanes` inside the fixed batch).
  Forked chains carry bitwise-identical state, so step-0 logits match W
  independent prefills at 1/W of the prefill-phase KV reads.
* **EOS reclamation.**  A chain that emits EOS (or hits its token budget)
  goes inactive immediately — zero further KV reads — and its lane's arena
  is reclaimed (:meth:`KVPolicy.reclaim_cache`) for the next queued request.
* **Cross-request prefix reuse.**  With a
  :class:`~repro.serving.prefix_cache.PrefixCache` attached, admission looks
  up the longest cached prefix of the prompt, imports its snapshot into the
  lane (:meth:`KVPolicy.import_prefix`) — device-to-device when the boundary
  sits in the cache's hot tier — and chunk-prefills only the suffix; prefill
  offers a snapshot at chunk boundaries the cache's export policy asks for
  (all of them under ``"always"``, only prefixes earlier traffic missed on
  under ``"second-miss"``), deferred into the device slab when one exists;
  EOS reclamation offers the finished prompt's prefix chain back to the
  tree (LRU refresh).
  A full-prompt hit skips prefill entirely — the cached boundary logits
  stand in for the hold-state sample.
* **Honest per-request metering.**  Each request owns two
  :class:`BudgetMeter`\\ s (prefill phase / decode phase) fed only by its own
  lanes' per-step ``live_tokens`` / ``reads_tokens``.  Finished lanes
  contribute zero reads; idle lanes are never attributed to anyone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hostsync import sanctioned
from repro.core import policy as policy_lib
from repro.core.hyperscale import BudgetMeter
from repro.models import transformer as tfm
from repro.serving import prefix_cache as prefix_cache_lib
from repro.serving.prefix_cache import PrefixCache


@dataclass
class Request:
    """One serving request: a prompt and a generation budget.

    ``width`` > 1 asks for W parallel hyper-scaling chains sharing one
    prefill.  ``eos_id`` enables early exit (None = decode the full budget).
    ``arrival`` delays admission until that scheduler tick (staggered-arrival
    simulation for benchmarks/tests)."""

    uid: int
    prompt: np.ndarray            # (T0,) int32
    max_new: int
    width: int = 1
    eos_id: Optional[int] = None
    arrival: int = 0


@dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray            # (W, max_new) int32, padded after EOS
    lengths: np.ndarray           # (W,) generated tokens per chain (incl. EOS)
    meter: BudgetMeter            # prefill + decode, sequential merge
    prefill_meter: BudgetMeter
    decode_meter: BudgetMeter
    admitted_tick: int = 0
    finished_tick: int = 0


class _ReqState:
    def __init__(self, req: Request, pad_id: int):
        self.req = req
        self.lanes: List[int] = []             # lane -> chain index by order
        self.consumed = 0                      # prompt tokens prefetched
        self.prefill_chunks = 0                # chunks prefilled (export stride)
        self.hold_logits: Optional[np.ndarray] = None
        self.chains: List[List[int]] = [[] for _ in range(req.width)]
        self.chain_done = [False] * req.width
        self.prefill_meter = BudgetMeter()
        self.decode_meter = BudgetMeter()
        self.pad_id = pad_id
        self.admitted_tick = 0

    @property
    def done(self) -> bool:
        return bool(self.lanes) and all(self.chain_done)

    def result(self, peak_bytes: float, finished_tick: int) -> RequestResult:
        w, m = self.req.width, self.req.max_new
        toks = np.full((w, m), self.pad_id, np.int32)
        lens = np.zeros((w,), np.int32)
        for c, chain in enumerate(self.chains):
            lens[c] = len(chain)
            toks[c, :len(chain)] = chain
        for meter in (self.prefill_meter, self.decode_meter):
            meter.observe_peak_bytes(peak_bytes)
        return RequestResult(
            uid=self.req.uid, tokens=toks, lengths=lens,
            meter=self.prefill_meter.merge_sequential(self.decode_meter),
            prefill_meter=self.prefill_meter, decode_meter=self.decode_meter,
            admitted_tick=self.admitted_tick, finished_tick=finished_tick)


def make_chunk_fn(arch, *, use_kernel: bool = False,
                  temperature: float = 0.0) -> Callable:
    """Build the jittable mixed prefill/decode chunk step.

    One call advances every active lane ``chunk`` steps: prefill lanes
    teacher-force their next prompt tokens (``feed`` / ``feed_valid``),
    decode lanes sample autoregressively, finished/idle lanes are frozen by
    the ``active`` mask.  Compiled once per (num_lanes, chunk) — admission,
    prompt length, and EOS timing never retrace."""

    def chunk_fn(params, state, feed, feed_valid, cur_tok, pos, decoding,
                 finished, lane_eos, budget_left, rng):
        # feed/feed_valid: (B, C); every other lane array: (B,)
        def body(carry, xs):
            state, cur_tok, pos, finished, emit_cnt, rng, last_logits = carry
            tok_feed, fv = xs
            prefill_now = fv & ~decoding & ~finished
            decode_now = decoding & ~finished & (emit_cnt < budget_left)
            active = prefill_now | decode_now
            token = jnp.where(prefill_now, tok_feed, cur_tok)[:, None]
            rng, sub = jax.random.split(rng)
            logits, state, aux = tfm.decode_step(
                params, token, state, arch, pos,
                use_kernel=use_kernel, active=active)
            if temperature > 0.0:
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)
            emitted = jnp.where(decode_now, nxt, -1)
            cur_tok = jnp.where(decode_now, nxt, cur_tok)
            finished = finished | (decode_now & (lane_eos >= 0)
                                   & (nxt == lane_eos))
            emit_cnt = emit_cnt + decode_now.astype(jnp.int32)
            pos = pos + active.astype(jnp.int32)
            last_logits = jnp.where(active[:, None], logits, last_logits)
            return ((state, cur_tok, pos, finished, emit_cnt, rng, last_logits),
                    (emitted, aux["live_tokens"], aux["reads_tokens"], active))

        b = feed.shape[0]
        carry0 = (state, cur_tok, pos, finished, jnp.zeros((b,), jnp.int32),
                  rng, jnp.zeros((b, arch.padded_vocab), jnp.float32))
        carry, ys = jax.lax.scan(body, carry0, (feed.T, feed_valid.T))
        state, cur_tok, pos, finished, emit_cnt, rng, last_logits = carry
        emitted, live, reads, act = ys                 # each (C, B)
        return (state, cur_tok, pos, finished, emit_cnt, rng, last_logits,
                emitted, live, reads, act)

    return chunk_fn


class Scheduler:
    """Drives one lane arena to completion over a queue of requests.

    The jitted step/reset/gather functions are owned by the caller (the
    :class:`~repro.serving.engine.Engine`) so their compile caches persist
    across Scheduler instances — per-request scheduling never retraces."""

    def __init__(self, arch, params, policy, *, num_lanes: int, max_len: int,
                 chunk: int = 8, chunk_jit=None, reset_jit=None,
                 gather_jit=None, use_kernel: bool = False,
                 temperature: float = 0.0, seed: int = 0, pad_id: int = 0,
                 prefix_cache: Optional[PrefixCache] = None,
                 export_jit=None, import_jit=None):
        self.arch, self.params, self.policy = arch, params, policy
        self.num_lanes, self.max_len, self.chunk = num_lanes, max_len, chunk
        self.pad_id = pad_id
        self._chunk_jit = chunk_jit or jax.jit(make_chunk_fn(
            arch, use_kernel=use_kernel, temperature=temperature))
        self._reset_jit = reset_jit or jax.jit(self._reset_fn,
                                               static_argnames=("b", "ml"))
        self._gather_jit = gather_jit or jax.jit(tfm.gather_lanes)
        self.prefix_cache = prefix_cache
        self._export_jit = export_jit or jax.jit(tfm.export_lane_state)
        self._import_jit = import_jit or jax.jit(tfm.import_lane_state)
        self.temperature = temperature

        self.state = tfm.init_decode_state(arch, num_lanes, max_len, policy)
        self.signature = tfm.lane_state_signature(self.state)
        # per-boundary snapshot bytes are shape-derived and constant for this
        # arena; knowing them up front lets _export_prefix skip the jitted
        # export entirely when no snapshot can ever fit in either tier.
        # eval_shape on the real export (no FLOPs, no allocation) rather than
        # whole-state-bytes // num_lanes: state leaves need not be
        # lane-proportional — a paged state's shared block pool has no lane
        # axis at all, and its snapshots densify to fixed-arena shape.
        snap_shapes = jax.eval_shape(tfm.export_lane_state, self.state,
                                     jnp.int32(0))
        self._snap_nbytes = (prefix_cache_lib.snapshot_nbytes(snap_shapes)
                             + int(arch.padded_vocab) * 4)  # + fp32 logits row
        self.peak_bytes = float(policy_lib.state_peak_bytes(self.state))
        # paged-pool admission descriptors: (kv_heads, arena_blocks, block_p,
        # pool_blocks) per pooled cache — a lane's worst-case footprint is
        # now a real byte-budget question, answered host-side in _admit
        self._pool_descs = []
        for pc in policy_lib.iter_policy_caches(self.state):
            pool = getattr(pc.cache, "pool", None)
            if pool is not None:
                phys = pc.cache.phys            # (nsb, B, H, NB)
                self._pool_descs.append(
                    (int(phys.shape[-2]), int(phys.shape[-1]),
                     int(pool.block_p), int(pool.num_blocks)))
        self.rng = jax.random.PRNGKey(seed)
        self._host_rng = jax.random.PRNGKey(seed ^ 0x5EED0)

        b = num_lanes
        self.pos = np.zeros((b,), np.int32)
        self.cur_tok = np.zeros((b,), np.int32)
        self.decoding = np.zeros((b,), bool)
        self.finished = np.zeros((b,), bool)
        self.lane_eos = np.full((b,), -1, np.int32)
        self.owner: List[Optional[_ReqState]] = [None] * b
        self.chain_of = np.zeros((b,), np.int32)
        self.queue: List[_ReqState] = []
        self.active_reqs: List[_ReqState] = []
        self.ticks = 0
        self.steps = 0

    def _reset_fn(self, state, mask, b, ml):
        fresh = tfm.init_decode_state(self.arch, b, ml, self.policy)
        return tfm.reclaim_lanes(state, mask, fresh)

    # -- public ------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.width > self.num_lanes:
            raise ValueError(
                f"request width {req.width} > num_lanes {self.num_lanes}")
        if len(req.prompt) == 0:
            raise ValueError("empty prompt: nothing to sample from")
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError("prompt + max_new exceeds scheduler max_len")
        self.queue.append(_ReqState(req, self.pad_id))

    def pool_stats(self) -> Optional[Dict[str, Any]]:
        """Paged-pool observability: live/free/allocated blocks, CoW share
        counts, fragmentation, high-water mark — aggregated over every pooled
        cache in the decode state (host-side sync; None when nothing is
        paged).  Surfaced by launch/serve.py's run summary."""
        return policy_lib.state_pool_stats(self.state)

    def run(self) -> List[RequestResult]:
        """Run the queue to completion; results in completion order."""
        results: List[RequestResult] = []
        while self.queue or self.active_reqs:
            # fork before admitting: freed lanes must reach held hyperscale
            # requests before new admissions can take them
            self._fork_ready()
            self._admit()
            self._fork_ready()
            if not any(o is not None for o in self.owner):
                # nothing admitted yet (future arrivals only): advance time
                self.ticks += 1
                continue
            self._tick(results)
        return results

    # -- lifecycle stages --------------------------------------------------

    def _idle_lanes(self) -> List[int]:
        return [l for l in range(self.num_lanes) if self.owner[l] is None]

    def _lane_pool_demand(self, tokens: int) -> List[int]:
        """Worst-case pool blocks ONE chain of a ``tokens``-token request can
        ever hold, per pooled descriptor: ``H * min(ceil(T / bp), NB)`` — the
        request can't map more blocks than its tokens span, and the cache's
        logical arena caps retention at ``NB`` blocks per head regardless.
        Empty when nothing is paged (fixed arenas: admission is lanes-only).
        """
        return [h * min(-(-tokens // bp), nb)
                for (h, nb, bp, _) in self._pool_descs]

    def _pool_fits(self, req: Request) -> bool:
        """Byte-budget admission: would admitting ``req`` let total
        worst-case pool demand exceed any pool's block count?  Host-side
        static arithmetic — no device sync.  With the default provisioning
        (``pool_blocks = B*H*NB``) this can never bind (lane demand is at
        most ``H*NB``), so fixed-arena-equivalent configs admit identically;
        an operator shrinks ``pool_blocks`` to oversubscribe lanes against
        live-token footprint (the hyper-scaling capacity win)."""
        if not self._pool_descs:
            return True
        demand = self._lane_pool_demand(len(req.prompt) + req.max_new)
        reserved = [0] * len(self._pool_descs)
        for r in self.active_reqs:
            d = self._lane_pool_demand(len(r.req.prompt) + r.req.max_new)
            for i in range(len(reserved)):
                reserved[i] += r.req.width * d[i]
        return all(reserved[i] + req.width * demand[i]
                   <= self._pool_descs[i][3]
                   for i in range(len(self._pool_descs)))

    def _admit(self) -> None:
        """Admit queued requests into idle lanes — FIFO with skip-scan.

        A width-W request occupies one prefill lane now and W-1 fork lanes
        later; those W-1 are *reserved* at admission (``sum(width)`` over
        admitted requests never exceeds ``num_lanes``), which makes the fork
        wait in :meth:`_fork_ready` deadlock- and starvation-free: held
        requests' lanes can never be re-admitted out from under them.  Paged
        states add a second gate (:meth:`_pool_fits`): admission reserves
        worst-case pool blocks too, so an oversubscribed lane count can never
        deadlock the shared pool."""
        # idle lanes are always pristine (fresh at construction; _tick
        # reclaims every lane of a completing request, fork targets included;
        # chunk steps never mutate inactive lanes) — no reset needed here
        idle = self._idle_lanes()
        while idle:
            reserved = sum(r.req.width - len(r.lanes)
                           for r in self.active_reqs)
            nxt = next((r for r in self.queue
                        if r.req.arrival <= self.ticks
                        and r.req.width <= len(idle) - reserved
                        and self._pool_fits(r.req)), None)
            if nxt is None:
                break
            self.queue.remove(nxt)
            lane = idle.pop(0)
            self.owner[lane] = nxt
            self.chain_of[lane] = 0
            nxt.lanes = [lane]
            nxt.admitted_tick = self.ticks
            self.active_reqs.append(nxt)
            self.pos[lane] = 0
            self.decoding[lane] = False
            self.finished[lane] = False
            self.lane_eos[lane] = -1 if nxt.req.eos_id is None else nxt.req.eos_id
            self._import_prefix(nxt, lane)

    def _import_prefix(self, r: _ReqState, lane: int) -> None:
        """Longest-cached-prefix import: the lane resumes at token boundary L
        and chunked prefill feeds only ``prompt[L:]``.  A hot-tier hit hands
        back a device-resident slab slice, so the jitted lane insert below is
        device-to-device — zero host↔device snapshot bytes; a cold hit ships
        its host snapshot up through the same jit (and promotes).  The
        avoided prefill reads go on the request's *saved* axis (``kv_reads``
        stays the honest paid integral); a full-prompt hit skips prefill
        entirely, with the cached boundary logits standing in as the
        hold-state sample."""
        if self.prefix_cache is None:
            return
        hit = self.prefix_cache.lookup(self.signature, r.req.prompt)
        if hit is None:
            return
        self.state = self._import_jit(self.state, hit.snapshot,
                                      jnp.int32(lane))
        self.pos[lane] = hit.length
        r.consumed = hit.length
        r.prefill_meter.observe_saved_reads(hit.reads_cum)
        if hit.length == len(r.req.prompt):
            with sanctioned("tick-boundary"):  # once per admission
                r.hold_logits = np.asarray(hit.logits).copy()

    def _want_prefix_export(self, r: _ReqState) -> bool:
        """Gate the per-chunk snapshot export on pure host checks, so the
        skip paths (no cache, over-budget snapshot, off-stride boundary,
        boundary already in the tree, no earlier traffic asked under
        ``second-miss``) cost no device sync at all — at most one radix
        descent total (``want_export``)."""
        if self.prefix_cache is None:
            return False
        if not self.prefix_cache.can_store(self._snap_nbytes):
            return False                   # can never fit: skip the export
        prefix = r.req.prompt[:r.consumed]
        return self.prefix_cache.want_export(
            self.signature, prefix, chunk_index=r.prefill_chunks,
            final=r.consumed == len(r.req.prompt))

    def _export_prefix(self, r: _ReqState, lane: int, logits) -> None:
        """Offer the just-prefilled boundary ``prompt[:consumed]`` to the
        radix tree.  ``reads_cum`` is what a cold prefill of this prefix
        reads — the request's own paid prefill reads plus whatever its own
        admission-time import saved (the invariant holds recursively, so hits
        on hits stay honest).  ``logits`` predict the boundary token, letting
        a later full-prompt hit skip prefill entirely.

        The export is *deferred*: one jitted lane slice hands the cache a
        device snapshot (and an unsynced device logits row).  With a hot
        tier the snapshot goes straight into the device slab — zero
        host↔device bytes, no stall of the decode scan — and is only
        materialized to host if the hot tier later demotes it.  Without a
        hot tier the cache materializes immediately (the seed behaviour).
        ``second-miss`` export gating (see :meth:`_want_prefix_export`)
        bounds how often this O(arena) copy happens at all: cold unshared
        prompts export nothing."""
        prefix = r.req.prompt[:r.consumed]
        snap = self._export_jit(self.state, jnp.int32(lane))
        reads_cum = r.prefill_meter.kv_reads_saved + r.prefill_meter.kv_reads
        self.prefix_cache.insert(self.signature, prefix, snap, logits,
                                 reads_cum)

    def _fork_ready(self) -> None:
        """hold → decode: fork prefilled lanes into W chains, sample token 0."""
        for r in list(self.active_reqs):
            if r.hold_logits is None or len(r.lanes) == r.req.width:
                continue
            need = r.req.width - 1
            idle = self._idle_lanes()
            if len(idle) < need:
                continue                      # wait for lanes to free up
            src = np.arange(self.num_lanes, dtype=np.int32)
            for lane in idle[:need]:
                src[lane] = r.lanes[0]
                self.owner[lane] = r
                self.chain_of[lane] = len(r.lanes)
                r.lanes.append(lane)
            self.state = self._gather_jit(self.state, jnp.asarray(src))
            self.pos[r.lanes] = self.pos[r.lanes[0]]
            self.lane_eos[r.lanes] = self.lane_eos[r.lanes[0]]
            self._start_decode(r)
        for r in list(self.active_reqs):      # width-1 fast path
            if r.hold_logits is not None and len(r.lanes) == r.req.width \
                    and not self.decoding[r.lanes].any():
                self._start_decode(r)

    def _start_decode(self, r: _ReqState) -> None:
        """Sample each chain's first token from the shared prefill logits."""
        w = len(r.lanes)
        logits = jnp.asarray(r.hold_logits)[None].repeat(w, axis=0)
        if self.temperature > 0.0:
            self._host_rng, sub = jax.random.split(self._host_rng)
            first = jax.random.categorical(sub, logits / self.temperature,
                                           axis=-1)
        else:
            first = jnp.argmax(logits, axis=-1)
        with sanctioned("tick-boundary"):      # once per request, not per step
            first = np.asarray(first, np.int32)
        r.decode_meter.observe_step([0.0], new_tokens=w,
                                    reads_tokens_per_layer=[0.0])
        for c, lane in enumerate(r.lanes):
            tok = int(first[c])
            r.chains[c].append(tok)
            self.cur_tok[lane] = tok
            self.decoding[lane] = True
            if (r.req.eos_id is not None and tok == r.req.eos_id) \
                    or len(r.chains[c]) >= r.req.max_new:
                self.finished[lane] = True
        r.hold_logits = None

    def _tick(self, results: List[RequestResult]) -> None:
        b, c = self.num_lanes, self.chunk
        feed = np.zeros((b, c), np.int32)
        feed_valid = np.zeros((b, c), bool)
        budget_left = np.zeros((b,), np.int32)
        prefill_take: Dict[int, int] = {}
        for lane in range(b):
            r = self.owner[lane]
            if r is None:
                continue
            if self.decoding[lane]:
                budget_left[lane] = r.req.max_new - len(
                    r.chains[self.chain_of[lane]])
            elif r.hold_logits is None and lane == r.lanes[0]:
                take = min(c, len(r.req.prompt) - r.consumed)
                if take > 0:
                    feed[lane, :take] = r.req.prompt[r.consumed:r.consumed + take]
                    feed_valid[lane, :take] = True
                    prefill_take[lane] = take

        out = self._chunk_jit(
            self.params, self.state, jnp.asarray(feed), jnp.asarray(feed_valid),
            jnp.asarray(self.cur_tok), jnp.asarray(self.pos),
            jnp.asarray(self.decoding), jnp.asarray(self.finished),
            jnp.asarray(self.lane_eos), jnp.asarray(budget_left), self.rng)
        (self.state, cur_tok, pos, finished, _, self.rng, last_logits,
         emitted, live, reads, act) = out
        # the scheduler's ONE sanctioned host sync: once per chunk, never
        # per step (the host-sync tripwire in repro.analysis enforces this)
        with sanctioned("tick-boundary"):
            self.cur_tok = np.array(cur_tok)   # writable host copies
            self.pos = np.array(pos)
            self.finished = np.array(finished)
            emitted = np.asarray(emitted)      # (C, B)
            live = np.asarray(live)
            reads = np.asarray(reads)
            act = np.asarray(act)
        self.ticks += 1
        self.steps += c

        # per-request, per-step metering from this request's own lanes only
        for r in self.active_reqs:
            lanes = r.lanes
            meter = (r.decode_meter if self.decoding[lanes[0]]
                     else r.prefill_meter)
            for t in range(c):
                if not act[t, lanes].any():
                    continue
                meter.observe_step(
                    [float(live[t, lanes].sum())],
                    new_tokens=int((emitted[t, lanes] >= 0).sum()),
                    reads_tokens_per_layer=[float(reads[t, lanes].sum())])

        # prefill completion -> hold (host samples token 0 next tick)
        ll = None
        for lane, take in prefill_take.items():
            r = self.owner[lane]
            r.consumed += take
            r.prefill_chunks += 1
            if r.consumed == len(r.req.prompt):
                if ll is None:
                    with sanctioned("tick-boundary"):   # prefill completion
                        ll = np.asarray(last_logits)
                r.hold_logits = ll[lane].copy()
            if self._want_prefix_export(r):
                # deferred export: the device logits row rides along unsynced
                # (ll materialization above is only for prefill completion)
                self._export_prefix(r, lane, last_logits[lane])

        # collect emitted tokens; EOS / budget exhaustion finishes chains
        for lane in range(b):
            r = self.owner[lane]
            if r is None or not self.decoding[lane]:
                continue
            chain = r.chains[self.chain_of[lane]]
            for t in range(c):
                tok = emitted[t, lane]
                if tok >= 0:
                    chain.append(int(tok))
            if self.finished[lane] or len(chain) >= r.req.max_new:
                r.chain_done[self.chain_of[lane]] = True
                self.finished[lane] = True

        # reclaim lanes of completed requests
        done = [r for r in self.active_reqs if r.done]
        if done:
            reclaim = np.zeros((b,), bool)
            for r in done:
                self.active_reqs.remove(r)
                if self.prefix_cache is not None:
                    # EOS reclamation offers the finished prompt's prefix
                    # chain back to the tree (LRU recency refresh)
                    self.prefix_cache.touch(self.signature, r.req.prompt)
                results.append(r.result(
                    self.peak_bytes * len(r.lanes) / self.num_lanes,
                    self.ticks))
                for lane in r.lanes:
                    self.owner[lane] = None
                    reclaim[lane] = True
                    self.decoding[lane] = False
                    self.finished[lane] = False
                    self.pos[lane] = 0
            self._reset(reclaim)

    def _reset(self, mask: np.ndarray) -> None:
        self.state = self._reset_jit(self.state, jnp.asarray(mask),
                                     b=self.num_lanes, ml=self.max_len)
