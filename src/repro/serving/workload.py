"""Seeded, deterministic workload generators for the serving stack.

Production claims need production traffic.  Benchmarks and tests used to
hand-build their request traces (fixed arrivals, one prompt length); this
module generates them instead, in the shapes real serving sees:

* **Poisson arrivals** — memoryless open-loop traffic at a target rate
  (requests per scheduler tick).
* **Bursty (on/off) arrivals** — Poisson at ``rate`` inside ``on_ticks``
  windows, silence for ``off_ticks`` between them: the overload shape the
  scheduler's SLO layer (shed / width-throttle / preempt) is built for.
* **Multi-turn sessions** — each turn's prompt extends the previous turn's
  full context, so a session re-hits its own prefix in the radix prefix
  cache under load (`docs/serving.md`).
* **Mixed lengths and width-W reasoning requests** — prompt/output lengths
  drawn per request from closed ranges, hyper-scaling width drawn from a
  weighted choice.

Everything is driven by one ``np.random.default_rng(seed)`` stream per
generator call: same seed ⇒ bit-identical `Request` list (uids, arrivals,
prompts, lengths, widths) — tests, benchmarks, the `FaultPlan` chaos
harness, and `launch/serve.py` all replay the same traces.  Generators emit
plain :class:`~repro.serving.scheduler.Request` lists sorted by arrival;
no scheduler state is touched.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.scheduler import Request


@dataclass(frozen=True)
class WorkloadSpec:
    """Per-request shape distribution (arrival processes are separate).

    ``prompt_len`` / ``max_new`` are inclusive ``(lo, hi)`` ranges;
    ``max_new`` draws are additionally clamped so every request satisfies
    ``prompt_len + max_new <= max_len`` (the scheduler's submit contract).
    ``widths`` is the hyper-scaling width choice set, weighted by
    ``width_weights`` (uniform when None).  Prompt tokens are drawn from
    ``[3, vocab)`` — clear of pad(0), the synthetic "="(1) marker, and any
    small reserved ids — and never contain ``eos_id``."""

    vocab: int
    max_len: int
    prompt_len: Tuple[int, int] = (4, 12)
    max_new: Tuple[int, int] = (2, 6)
    widths: Tuple[int, ...] = (1,)
    width_weights: Optional[Tuple[float, ...]] = None
    eos_id: Optional[int] = None
    deadline: Optional[int] = None

    def __post_init__(self):
        if self.prompt_len[0] < 1 or self.prompt_len[0] > self.prompt_len[1]:
            raise ValueError(f"bad prompt_len range {self.prompt_len}")
        if self.max_new[0] < 1 or self.max_new[0] > self.max_new[1]:
            raise ValueError(f"bad max_new range {self.max_new}")
        if self.prompt_len[1] + self.max_new[0] > self.max_len:
            raise ValueError(
                f"prompt_len hi {self.prompt_len[1]} + max_new lo "
                f"{self.max_new[0]} exceeds max_len {self.max_len}: "
                "some draws could never be submitted")
        if self.width_weights is not None \
                and len(self.width_weights) != len(self.widths):
            raise ValueError("width_weights length != widths length")


# -- arrival processes -------------------------------------------------------


def poisson_arrivals(seed: int, n: int, rate: float) -> np.ndarray:
    """``n`` sorted integer arrival ticks, exponential inter-arrivals at
    ``rate`` requests/tick (open-loop Poisson process)."""
    if rate <= 0:
        raise ValueError("rate must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.floor(np.cumsum(gaps)).astype(np.int64)


def burst_arrivals(seed: int, n: int, *, rate: float, on_ticks: int,
                   off_ticks: int) -> np.ndarray:
    """On/off-modulated Poisson: arrivals land only inside ``on_ticks``-long
    busy windows separated by ``off_ticks`` of silence.  Drawn by running a
    Poisson process over *busy time* and re-mapping each arrival into its
    on-window (so the within-burst rate is exactly ``rate``)."""
    if on_ticks < 1 or off_ticks < 0:
        raise ValueError("need on_ticks >= 1, off_ticks >= 0")
    busy = poisson_arrivals(seed, n, rate)
    cycle, ooff = np.divmod(busy, on_ticks)
    return (cycle * (on_ticks + off_ticks) + ooff).astype(np.int64)


# -- request synthesis -------------------------------------------------------


def _draw_prompt(rng: np.random.Generator, spec: WorkloadSpec,
                 length: int) -> np.ndarray:
    toks = rng.integers(3, spec.vocab, size=(length,)).astype(np.int32)
    if spec.eos_id is not None and 3 <= spec.eos_id < spec.vocab:
        toks[toks == spec.eos_id] = 2      # prompts never contain EOS
    return toks


def _draw_width(rng: np.random.Generator, spec: WorkloadSpec) -> int:
    if len(spec.widths) == 1:
        return int(spec.widths[0])
    p = None
    if spec.width_weights is not None:
        w = np.asarray(spec.width_weights, np.float64)
        p = w / w.sum()
    return int(rng.choice(np.asarray(spec.widths), p=p))


def requests_from_arrivals(seed: int, arrivals: Sequence[int],
                           spec: WorkloadSpec, *,
                           uid_base: int = 0) -> List[Request]:
    """Flesh out arrival ticks into full ``Request``\\ s: per-request prompt
    length, prompt tokens, output budget, and width, all from one seeded
    stream.  uids are sequential in arrival order."""
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    for i, arr in enumerate(np.sort(np.asarray(arrivals, np.int64))):
        plen = int(rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1))
        hi = min(spec.max_new[1], spec.max_len - plen)
        mnew = int(rng.integers(spec.max_new[0], hi + 1))
        out.append(Request(
            uid=uid_base + i, prompt=_draw_prompt(rng, spec, plen),
            max_new=mnew, width=_draw_width(rng, spec),
            eos_id=spec.eos_id, arrival=int(arr), deadline=spec.deadline))
    return out


def poisson_trace(seed: int, n: int, *, rate: float,
                  spec: WorkloadSpec) -> List[Request]:
    """Poisson arrivals + per-request shapes from one seed."""
    return requests_from_arrivals(
        seed ^ 0xA11CE, poisson_arrivals(seed, n, rate), spec)


def burst_trace(seed: int, n: int, *, rate: float, on_ticks: int,
                off_ticks: int, spec: WorkloadSpec) -> List[Request]:
    """Bursty on/off arrivals + per-request shapes from one seed — the
    2× overload shape ``benchmarks/slo_harness.py`` calibrates against."""
    return requests_from_arrivals(
        seed ^ 0xA11CE,
        burst_arrivals(seed, n, rate=rate, on_ticks=on_ticks,
                       off_ticks=off_ticks), spec)


def multi_turn_trace(seed: int, *, sessions: int, turns: int,
                     spec: WorkloadSpec, session_rate: float = 0.25,
                     think_ticks: int = 4) -> List[Request]:
    """Multi-turn chat sessions that re-hit their own prefixes.

    Each session opens at a Poisson arrival; turn ``k``'s prompt is turn
    ``k-1``'s prompt, plus a simulated assistant reply (``max_new`` tokens —
    the context grows the way a real chat transcript does), plus a fresh
    user message.  Later turns therefore share their whole history as a
    radix-cache prefix.  A session stops early when its next turn could no
    longer fit ``max_len``; turns are spaced ``think_ticks`` apart (plus
    jitter).  uids are sequential in arrival order across all sessions."""
    if sessions < 1 or turns < 1:
        raise ValueError("need sessions >= 1 and turns >= 1")
    rng = np.random.default_rng(seed ^ 0x5E55)
    opens = poisson_arrivals(seed, sessions, session_rate)
    drafts = []                      # (arrival, prompt, max_new, width)
    for s in range(sessions):
        plen = int(rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1))
        prompt = _draw_prompt(rng, spec, plen)
        arr = int(opens[s])
        for _ in range(turns):
            hi = min(spec.max_new[1], spec.max_len - len(prompt))
            if hi < spec.max_new[0]:
                break                # context full: session ends early
            mnew = int(rng.integers(spec.max_new[0], hi + 1))
            drafts.append((arr, prompt, mnew, _draw_width(rng, spec)))
            # next turn extends the full context: prior prompt + the
            # assistant's reply + a fresh user message
            reply = _draw_prompt(rng, spec, mnew)
            user = _draw_prompt(
                rng, spec,
                int(rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1)))
            prompt = np.concatenate([prompt, reply, user])
            arr += think_ticks + int(rng.integers(0, 3))
    drafts.sort(key=lambda d: d[0])
    return [Request(uid=i, prompt=p, max_new=m, width=w, eos_id=spec.eos_id,
                    arrival=a, deadline=spec.deadline)
            for i, (a, p, m, w) in enumerate(drafts)]


def trace_summary(reqs: Sequence[Request]) -> Dict[str, float]:
    """Offered-load accounting for calibrating over/under-load: total
    tokens the trace asks for and the tick span it asks them over."""
    if not reqs:
        return {"requests": 0, "span_ticks": 0, "prompt_tokens": 0,
                "max_new_tokens": 0, "mean_width": 0.0,
                "offered_tokens_per_tick": 0.0}
    span = max(r.arrival for r in reqs) - min(r.arrival for r in reqs) + 1
    prompt_toks = sum(len(r.prompt) for r in reqs)
    gen_toks = sum(r.max_new * r.width for r in reqs)
    return {
        "requests": len(reqs),
        "span_ticks": int(span),
        "prompt_tokens": int(prompt_toks),
        "max_new_tokens": int(gen_toks),
        "mean_width": float(np.mean([r.width for r in reqs])),
        "offered_tokens_per_tick": float((prompt_toks + gen_toks) / span),
    }
