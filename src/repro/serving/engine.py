"""Serving engine: prefill/decode split, DMS-compressed paged KV, continuous
batching, and exact budget metering for inference-time hyper-scaling.

The engine is the production face of the paper: a request asks for W parallel
chains of up to L tokens at compression CR; the engine provisions slot arenas
of ``P ≈ L/CR + w`` per kv head (the physical memory saving), decodes with
the compressed cache, and reports the two paper budget metrics (KV reads,
peak tokens) measured from the real cache state.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as policy_lib
from repro.core.config import ArchConfig, KVPolicyConfig
from repro.core.hyperscale import BudgetMeter, ScalingConfig, majority_vote
from repro.models import transformer as tfm


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (W, L_gen)
    meter: BudgetMeter
    answers: List[int] = field(default_factory=list)


class Engine:
    """Single-host engine; the same step functions lower onto the production
    mesh (see launch/serve.py)."""

    def __init__(self, arch: ArchConfig, params, policy: KVPolicyConfig,
                 use_kernel: bool = False, temperature: float = 0.0):
        self.arch = arch
        self.params = params
        self.policy = policy
        self.use_kernel = use_kernel
        self.temperature = temperature
        self._decode_jit = jax.jit(self._decode_step)
        self._prefill_jit = jax.jit(self._prefill, static_argnames=("t",))

    # -- jitted internals ------------------------------------------------

    def _decode_step(self, params, token, state, pos, rng):
        logits, state, aux = tfm.decode_step(
            params, token, state, self.arch, pos, use_kernel=self.use_kernel)
        if self.temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / self.temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), state, aux

    def _prefill(self, params, tokens, state, t):
        # teacher-forced prefill through the decode path: exact cache-policy
        # semantics (incl. TOVA/H2O eviction during prompt processing)
        def body(carry, tok_t):
            state, i = carry
            _, state, _ = tfm.decode_step(
                params, tok_t[:, None], state, self.arch, i,
                use_kernel=self.use_kernel)
            return (state, i + 1), None

        (state, _), _ = jax.lax.scan(
            body, (state, jnp.zeros((), jnp.int32)), tokens.T)
        return state

    # -- public API -------------------------------------------------------

    def generate(self, prompts: np.ndarray, max_new: int,
                 seed: int = 0) -> GenerationResult:
        """prompts: (B, T0) int32.  Continuous batch of B chains."""
        b, t0 = prompts.shape
        max_len = t0 + max_new
        state = tfm.init_decode_state(self.arch, b, max_len, self.policy)
        state = self._prefill_jit(self.params, jnp.asarray(prompts), state, t=t0)
        tok = jnp.asarray(prompts[:, -1:])
        meter = BudgetMeter()
        # physical arena bytes are static per policy/state — from metrics(),
        # not engine guesses
        meter.observe_peak_bytes(policy_lib.state_peak_bytes(state))
        outs = []
        rng = jax.random.PRNGKey(seed)
        for i in range(max_new):
            rng, sub = jax.random.split(rng)
            tok, state, aux = self._decode_jit(
                self.params, tok, state, jnp.asarray(t0 + i, jnp.int32), sub)
            outs.append(np.asarray(tok[:, 0]))
            live = np.asarray(aux["live_tokens"])       # (B,) summed over layers
            reads = np.asarray(aux["reads_tokens"])     # KV-reads axis (≠ live
            meter.observe_step([float(live.sum())],     # for e.g. Quest)
                               new_tokens=b,
                               reads_tokens_per_layer=[float(reads.sum())])
        return GenerationResult(tokens=np.stack(outs, 1), meter=meter)

    def hyperscale_generate(self, prompt: np.ndarray, cfg: ScalingConfig,
                            seed: int = 0) -> GenerationResult:
        """One problem, W parallel chains (paper L-W-CR scaling)."""
        prompts = np.tile(prompt[None], (cfg.width, 1))
        max_new = cfg.max_len - prompt.shape[0]
        return self.generate(prompts, max_new, seed=seed)


def answer_from_chain(chain: np.ndarray, eq_token: int = 1) -> Optional[int]:
    """First generated token is the answer in our synthetic tasks."""
    return int(chain[0]) if len(chain) else None


def evaluate_hyperscale(
    engine: Engine, prompts: np.ndarray, answers: np.ndarray,
    cfg: ScalingConfig, seed: int = 0,
) -> Dict[str, float]:
    """Accuracy + budget over an eval set for one L-W-CR point."""
    meter = BudgetMeter()
    hits = 0
    for i in range(len(prompts)):
        res = engine.hyperscale_generate(prompts[i], cfg, seed=seed + i)
        votes = [answer_from_chain(res.tokens[w]) for w in range(cfg.width)]
        pred = majority_vote([str(v) for v in votes if v is not None])
        hits += int(pred is not None and int(pred) == int(answers[i]))
        meter = meter.merge(res.meter)
    n = max(len(prompts), 1)
    return {
        "accuracy": hits / n,
        "kv_reads": meter.kv_reads / n,
        "peak_tokens": meter.peak_tokens / n,
        "peak_bytes": meter.peak_bytes / n,
        "config": cfg.label,
    }
