"""Serving engine: scheduler-driven continuous batching, DMS-compressed
paged KV, shared-prefill hyperscale fork, and exact budget metering.

The engine is the production face of the paper: a request asks for W parallel
chains of up to L tokens at compression CR; the engine provisions slot arenas
of ``P ≈ L/CR + w`` per kv head (the physical memory saving), decodes with
the compressed cache, and reports the two paper budget metrics (KV reads,
peak tokens) measured from the real cache state.

Generation runs on the :class:`~repro.serving.scheduler.Scheduler` lane
arena: prompts prefill in T-chunks through the decode path (exact eviction
semantics), hyperscale requests prefill **once** and fork the cache into W
chains (:meth:`KVPolicy.fork_cache`), EOS exits early and reclaims the lane,
and every request gets its own honest prefill/decode meters — a finished
chain contributes zero KV reads.

With ``prefix_cache_mb > 0`` the engine owns a cross-request
:class:`~repro.serving.prefix_cache.PrefixCache`: prompts sharing a prefix
with earlier traffic (system prompts, few-shot headers, multi-turn chats)
import the cached KV snapshot and prefill only their suffix — avoided reads
land on the meters' ``kv_reads_saved`` axis, paid reads stay honest.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ArchConfig, KVPolicyConfig
from repro.core.hyperscale import BudgetMeter, ScalingConfig, majority_vote
from repro.models import transformer as tfm
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import (Request, RequestResult, Scheduler,
                                     make_chunk_fn)


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (W, L_gen)
    meter: BudgetMeter
    answers: List[int] = field(default_factory=list)
    requests: List[RequestResult] = field(default_factory=list)


# Engine.scheduler's prefix_cache default: "use the engine's own cache".
# A sentinel (not None) so callers can pass prefix_cache=None to get one
# explicitly cold scheduler from a warm engine.
_ENGINE_CACHE = object()


class Engine:
    """Single-host engine; the same step functions lower onto the production
    mesh (see launch/serve.py)."""

    def __init__(self, arch: ArchConfig, params, policy: KVPolicyConfig,
                 use_kernel: bool = False, temperature: float = 0.0,
                 chunk: int = 8, prefix_cache_mb: float = 0.0,
                 prefix_cache_device_mb: float = 0.0,
                 export_policy: str = "always", export_stride: int = 1):
        self.arch = arch
        self.params = params
        self.policy = policy
        self.use_kernel = use_kernel
        self.temperature = temperature
        self.chunk = chunk
        # engine-owned so it persists across Scheduler instances: every
        # served prompt seeds prefix reuse for all later traffic.
        # prefix_cache_device_mb buys the device-resident hot tier (zero-copy
        # hit path, deferred exports); export_policy="second-miss" stops
        # unshared prompts from exporting at all; export_stride=N keeps only
        # every Nth chunk boundary (+ the full-prompt one) — bounded slot
        # churn on very long shared prefixes.
        self.prefix_cache = (
            PrefixCache(int(prefix_cache_mb * 2 ** 20),
                        int(prefix_cache_device_mb * 2 ** 20),
                        export_policy=export_policy,
                        export_stride=export_stride)
            if prefix_cache_mb > 0 or prefix_cache_device_mb > 0 else None)
        # jitted once per Engine: the compile cache survives across Scheduler
        # instances (per-request scheduling never retraces)
        self._chunk_jit = jax.jit(make_chunk_fn(
            arch, use_kernel=use_kernel, temperature=temperature))
        self._gather_jit = jax.jit(tfm.gather_lanes)
        self._reset_jit = jax.jit(self._reset_fn, static_argnames=("b", "ml"))
        self._prefill_jit = jax.jit(self._prefill, static_argnames=("t",))
        self._export_jit = jax.jit(tfm.export_lane_state)
        self._import_jit = jax.jit(tfm.import_lane_state)

    def _reset_fn(self, state, mask, b, ml):
        fresh = tfm.init_decode_state(self.arch, b, ml, self.policy)
        return tfm.reclaim_lanes(state, mask, fresh)

    # -- jitted internals ------------------------------------------------

    def _prefill(self, params, tokens, state, t):
        # reference per-token prefill through the decode path (exact cache-
        # policy semantics); production serving uses the scheduler's chunked
        # prefill — tests pin the two equivalent per policy
        def body(carry, tok_t):
            state, i = carry
            _, state, _ = tfm.decode_step(
                params, tok_t[:, None], state, self.arch, i,
                use_kernel=self.use_kernel)
            return (state, i + 1), None

        (state, _), _ = jax.lax.scan(
            body, (state, jnp.zeros((), jnp.int32)), tokens.T)
        return state

    def scheduler(self, num_lanes: int, max_len: int, *, seed: int = 0,
                  chunk: Optional[int] = None,
                  prefix_cache: Any = _ENGINE_CACHE, faults: Any = None,
                  on_pressure: str = "preempt", oversub: float = 1.0,
                  slo: Any = None) -> Scheduler:
        """A lane arena bound to this engine's jitted step functions.

        The engine's :class:`PrefixCache` (if any) rides along by default, so
        prompts served by one scheduler seed prefix reuse in the next; pass
        ``prefix_cache=None`` for an explicitly cold scheduler, or another
        PrefixCache instance to override.  ``faults`` attaches a
        :class:`~repro.serving.faults.FaultPlan` (chaos tests/benchmarks);
        ``on_pressure``/``oversub`` configure the preemption layer and
        ``slo`` an :class:`~repro.serving.scheduler.SLOSpec` for the
        overload-control ladder (see :class:`Scheduler`)."""
        if prefix_cache is _ENGINE_CACHE:
            prefix_cache = self.prefix_cache
        return Scheduler(
            self.arch, self.params, self.policy,
            num_lanes=num_lanes, max_len=max_len,
            chunk=chunk or self.chunk, chunk_jit=self._chunk_jit,
            reset_jit=self._reset_jit, gather_jit=self._gather_jit,
            use_kernel=self.use_kernel, temperature=self.temperature,
            seed=seed, prefix_cache=prefix_cache,
            export_jit=self._export_jit, import_jit=self._import_jit,
            faults=faults, on_pressure=on_pressure, oversub=oversub,
            slo=slo)

    # -- public API -------------------------------------------------------

    def generate(self, prompts: np.ndarray, max_new: int, seed: int = 0,
                 eos_id: Optional[int] = None) -> GenerationResult:
        """prompts: (B, T0) int32 — B requests served concurrently, one lane
        each.  With ``eos_id`` set, chains exit early: no further KV reads
        are metered for a finished lane and its arena is reclaimed; output
        rows are padded with ``eos_id`` past each chain's end."""
        b, t0 = prompts.shape
        sched = self.scheduler(b, t0 + max_new, seed=seed)
        for i in range(b):
            sched.submit(Request(uid=i, prompt=np.asarray(prompts[i]),
                                 max_new=max_new, eos_id=eos_id))
        results = {r.uid: r for r in sched.run()}
        pad = eos_id if eos_id is not None else 0
        tokens = np.stack([
            _pad_chain(results[i].tokens[0], results[i].lengths[0],
                       max_new, pad)
            for i in range(b)])
        meter = BudgetMeter()
        for i in range(b):            # concurrent requests: co-resident lanes
            meter = meter.merge(results[i].meter)
        return GenerationResult(tokens=tokens, meter=meter,
                                requests=[results[i] for i in range(b)])

    def hyperscale_generate(self, prompt: np.ndarray, cfg: ScalingConfig,
                            seed: int = 0) -> GenerationResult:
        """One problem, W parallel chains (paper L-W-CR scaling).

        The prompt prefills ONCE; the cache then forks into W chains
        (shared-prefill fork) — prefill-phase KV reads are W× lower than
        re-prefilling per chain, and step-0 logits are bitwise identical."""
        max_new = cfg.max_len - int(prompt.shape[0])
        sched = self.scheduler(cfg.width, cfg.max_len, seed=seed)
        sched.submit(Request(uid=0, prompt=np.asarray(prompt),
                             max_new=max_new, width=cfg.width,
                             eos_id=cfg.eos_id))
        res = sched.run()[0]
        return GenerationResult(tokens=res.tokens, meter=res.meter,
                                requests=[res])


def _pad_chain(chain: np.ndarray, length: int, max_new: int, pad: int
               ) -> np.ndarray:
    out = np.full((max_new,), pad, np.int32)
    out[:length] = chain[:length]
    return out


def answer_from_chain(chain: np.ndarray, eq_token: int = 1) -> Optional[int]:
    """Extract the answer token from a generated chain.

    Our synthetic tasks answer right after the last ``eq_token`` ("=") the
    model emits; chains that never emit one answer with their first token
    (prompts end in "=", so token 0 is the direct answer)."""
    chain = np.asarray(chain)
    if len(chain) == 0:
        return None
    eq_pos = np.where(chain[:-1] == eq_token)[0]
    if len(eq_pos):
        return int(chain[eq_pos[-1] + 1])
    return int(chain[0])


def evaluate_hyperscale(
    engine: Engine, prompts: np.ndarray, answers: np.ndarray,
    cfg: ScalingConfig, seed: int = 0, eq_token: int = 1,
) -> Dict[str, float]:
    """Accuracy + budget over an eval set for one L-W-CR point."""
    meter = BudgetMeter()
    hits = 0
    for i in range(len(prompts)):
        res = engine.hyperscale_generate(prompts[i], cfg, seed=seed + i)
        votes = [answer_from_chain(res.tokens[w], eq_token=eq_token)
                 for w in range(cfg.width)]
        pred = majority_vote([str(v) for v in votes if v is not None])
        hits += int(pred is not None and int(pred) == int(answers[i]))
        meter = meter.merge(res.meter)
    n = max(len(prompts), 1)
    return {
        "accuracy": hits / n,
        "kv_reads": meter.kv_reads / n,
        "peak_tokens": meter.peak_tokens / n,
        "peak_bytes": meter.peak_bytes / n,
        "config": cfg.label,
    }
