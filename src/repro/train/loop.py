"""Training loop with fault tolerance: auto-resume, async checkpoints,
preemption handling, deterministic data, and the two-phase DMS retrofit.

The same loop runs a CPU-scale smoke model and (via pjit shardings from
repro.parallel) a multi-pod production job — the launcher decides.
"""
from __future__ import annotations

import signal
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.core.config import ArchConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.optim import adamw


@dataclass
class TrainConfig:
    total_steps: int = 200
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_last: int = 2
    seed: int = 0
    retrofit: bool = False           # DMS retrofit (distill from vanilla self)
    phase1_steps: int = 0            # borrowed-neuron zeroing prologue
    accum_steps: int = 1
    use_kernel: bool = False
    remat: bool = False


class PreemptionGuard:
    """SIGTERM → checkpoint-now-and-exit (cluster preemption style)."""

    def __init__(self):
        self.requested = False
        try:
            signal.signal(signal.SIGTERM, self._handler)
        except ValueError:
            pass  # non-main thread (tests)

    def _handler(self, *_):
        self.requested = True


def train(arch: ArchConfig, data_cfg: DataConfig, cfg: TrainConfig,
          opt_cfg: Optional[adamw.AdamWConfig] = None,
          params: Optional[Any] = None,
          log_fn: Callable[[Dict], None] = None) -> Dict[str, Any]:
    """Returns {params, opt_state, metrics_history, resumed_from}."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=1e-3, warmup_steps=20, total_steps=cfg.total_steps)
    key = jax.random.PRNGKey(cfg.seed)
    if params is None:
        params = tfm.init_model(key, arch)
    opt_state = adamw.init(params)
    teacher = None
    if cfg.retrofit:
        teacher = jax.tree_util.tree_map(jnp.copy, params)
        step_fn = steps_lib.make_retrofit_step(
            arch, opt_cfg, remat=cfg.remat, use_kernel=cfg.use_kernel)
        phase1_fn = steps_lib.make_retrofit_step(
            arch, opt_cfg, remat=cfg.remat, use_kernel=cfg.use_kernel, phase1=True)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 2))
        jit_phase1 = jax.jit(phase1_fn, donate_argnums=(0, 2))
    else:
        step_fn = steps_lib.make_train_step(
            arch, opt_cfg, dms_train=arch.dms.enabled, remat=cfg.remat,
            use_kernel=cfg.use_kernel, accum_steps=cfg.accum_steps)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    mgr = CheckpointManager(cfg.ckpt_dir, keep_last=cfg.keep_last) \
        if cfg.ckpt_dir else None
    start = 0
    resumed_from = None
    if mgr is not None and mgr.latest_step() is not None:
        (params, opt_state), start, _ = mgr.restore((params, opt_state))
        resumed_from = start

    guard = PreemptionGuard()
    history = []
    for step in range(start, cfg.total_steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(data_cfg, step).items()}
        sj = jnp.asarray(step, jnp.int32)
        if cfg.retrofit:
            if step < cfg.phase1_steps:
                params, opt_state, metrics = jit_phase1(
                    params, teacher, opt_state, batch, sj)
            else:
                params, opt_state, metrics = jit_step(
                    params, teacher, opt_state, batch, sj)
        else:
            params, opt_state, metrics = jit_step(params, opt_state, batch, sj)
        if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            history.append(m)
            if log_fn:
                log_fn(m)
        want_ckpt = mgr is not None and (
            (step + 1) % cfg.ckpt_every == 0 or guard.requested
            or step == cfg.total_steps - 1)
        if want_ckpt:
            mgr.save(step + 1, (params, opt_state), blocking=False)
        if guard.requested:
            if mgr:
                mgr.wait()
            break
    if mgr:
        mgr.wait()
    return {"params": params, "opt_state": opt_state, "history": history,
            "resumed_from": resumed_from, "teacher": teacher}
