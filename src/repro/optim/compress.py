"""Int8 error-feedback gradient compression for cross-pod data parallelism.

At 1000+ nodes the data-parallel all-reduce over the pod axis crosses DCI
(slow) links; quantising gradients to int8 with per-tensor scales cuts those
bytes ~4× (bf16 → int8 + one fp32 scale).  Error feedback (residual carry)
keeps the compression unbiased over time (1-bit Adam / EF-SGD lineage).

Use :func:`psum_compressed` around the *slow* axis only — fast in-pod
reductions stay full precision.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def zeros_like_residual(grads: Any) -> Any:
    return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_grads(grads: Any, residual: Optional[Any]) -> Tuple[Any, Any, Any]:
    """Returns (q_tree int8, scale_tree fp32 scalars, new_residual fp32)."""
    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    r_leaves = (jax.tree_util.tree_leaves(residual) if residual is not None
                else [jnp.zeros(g.shape, jnp.float32) for g in g_leaves])
    qs, ss, rs = [], [], []
    for g, r in zip(g_leaves, r_leaves):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        qs.append(q)
        ss.append(s)
        rs.append(x - dequantize_int8(q, s))
    unf = jax.tree_util.tree_unflatten
    return unf(treedef, qs), unf(treedef, ss), unf(treedef, rs)


def decompress_grads(q_tree: Any, scale_tree: Any) -> Any:
    return jax.tree_util.tree_map(dequantize_int8, q_tree, scale_tree)


def psum_compressed(grads: Any, axis_name: str, residual: Optional[Any] = None
                    ) -> Tuple[Any, Any]:
    """Error-feedback int8 mean-all-reduce over ``axis_name`` (under shard_map).

    Wire payload per tensor: int8 values + one fp32 scale.  Each shard's
    contribution is dequantised locally and summed in fp32 by the collective
    (XLA fuses the upcast into the reduce); the residual stays on-shard.
    """
    q_tree, s_tree, new_res = compress_grads(grads, residual)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)

    def reduce_one(q, s):
        return jax.lax.psum(dequantize_int8(q, s), axis_name) / n

    return jax.tree_util.tree_map(reduce_one, q_tree, s_tree), new_res
