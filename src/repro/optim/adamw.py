"""AdamW with fp32 master weights/moments (paper App. B trains bf16 params +
fp32 optimizer state) and global-norm clipping.  Pure pytree functions — no
external optimizer dependency."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any        # first moment (fp32)
    nu: Any        # second moment (fp32)
    master: Any    # fp32 master copy of params (None if params already fp32)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init(params: Any, keep_master: bool = True) -> AdamWState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # jnp.array copies: the master must never alias params (donation safety)
    master = (jax.tree_util.tree_map(lambda p: jnp.array(p, dtype=jnp.float32), params)
              if keep_master else None)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree_util.tree_map(jnp.copy, zeros), master)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params: Any, grads: Any, state: AdamWState, cfg: AdamWConfig
                  ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    lr = schedule(cfg, step)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p32):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        p32 = p32 - lr * (u + cfg.weight_decay * p32)
        return m, v, p32

    master = state.master if state.master is not None else jax.tree_util.tree_map(
        lambda p: p.astype(jnp.float32), params)
    flat = jax.tree_util.tree_map(upd, grads, state.mu, state.nu, master)
    mu = jax.tree_util.tree_map(lambda t: t[0], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree_util.tree_map(lambda t: t[2], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree_util.tree_map(
        lambda p, p32: p32.astype(p.dtype), params, new_master)
    new_state = AdamWState(step, mu, nu,
                           new_master if state.master is not None else None)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
