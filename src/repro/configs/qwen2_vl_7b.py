"""qwen2-vl-7b — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  Vision frontend is
a STUB: ``input_specs()`` provides precomputed patch embeddings (B, F, D)
prepended to the text tokens.  M-RoPE: head_dim/2 = 64 freq slots split into
(temporal=16, height=24, width=24) sections.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    num_layers=28,
    d_model=3584,
    vocab_size=152064,
    attn=AttentionConfig(num_heads=28, num_kv_heads=4, head_dim=128,
                         rope="mrope", mrope_sections=(16, 24, 24),
                         rope_theta=1e6),
    mlp=MLPConfig(d_ff=18944, kind="swiglu"),
    layer_pattern=("attn",),
    frontend="vision_patches",
    frontend_tokens=1024,
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="vlm",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
