"""chatglm3-6b — RoPE 2d (half-rotary), GQA [arXiv:2406.12793; hf].

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    num_layers=28,
    d_model=4096,
    vocab_size=65024,
    attn=AttentionConfig(num_heads=32, num_kv_heads=2, head_dim=128, rope="half"),
    mlp=MLPConfig(d_ff=13696, kind="swiglu"),
    layer_pattern=("attn",),
    norm="rmsnorm",
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="dense",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
