"""granite-moe-1b-a400m — 32 experts top-8 [hf:ibm-granite/granite-3.0; hf].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8.
"""
from repro.core.config import (ArchConfig, AttentionConfig, DMSConfig,
                               MLPConfig, MoEConfig)

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    num_layers=24,
    d_model=1024,
    vocab_size=49155,
    attn=AttentionConfig(num_heads=16, num_kv_heads=8, head_dim=64, rope="full"),
    mlp=MLPConfig(d_ff=512, kind="swiglu", moe=MoEConfig(num_experts=32, top_k=8)),
    layer_pattern=("attn",),
    tie_embeddings=True,
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="moe",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64, num_experts=8)
