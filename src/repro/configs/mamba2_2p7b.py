"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060; unverified].

64L d_model=2560, attention-free, vocab=50280, ssm_state=128.
DMS inapplicable (no KV cache) — see DESIGN.md §Arch-applicability.
"""
from repro.core.config import ArchConfig, DMSConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    num_layers=64,
    d_model=2560,
    vocab_size=50280,
    attn=None,
    mlp=None,
    layer_pattern=("ssd",),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1,
                  conv_kernel=4, chunk_size=256),
    norm="rmsnorm",
    tie_embeddings=True,
    dms=DMSConfig(enabled=False),
    family="ssm",
    sub_quadratic=True,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
