"""recurrentgemma-2b — RG-LRU + local attention, 1:2 [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000.
Pattern: (rglru, rglru, attn_local) with a 2048-token local window; but 26
layers is not divisible by 3, so the published model runs the temporal
pattern with the final block truncated — we keep the published layer count by
using a 13× repetition of (rglru, attn_local) which preserves the 1:2
recurrent:attention compute ratio at equal depth (noted in DESIGN.md).
DMS applies to the local-attention layers.
"""
from repro.core.config import (ArchConfig, AttentionConfig, DMSConfig,
                               MLPConfig, RGLRUConfig)

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    num_layers=26,
    d_model=2560,
    vocab_size=256000,
    attn=AttentionConfig(num_heads=10, num_kv_heads=1, head_dim=256,
                         rope="full", window=2048),
    mlp=MLPConfig(d_ff=7680, kind="geglu"),
    layer_pattern=("rglru", "attn_local"),
    rglru=RGLRUConfig(lru_width=2560, conv_kernel=4),
    tie_embeddings=True,
    embedding_multiplier=2560 ** 0.5,
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="hybrid",
    sub_quadratic=True,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
