"""seamless-m4t-large-v2 — enc-dec, multimodal [arXiv:2308.11596; hf].

24L (encoder) + 24L (decoder) d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
The speech frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (B, S_enc, D) consumed by the bidirectional encoder; the decoder
generates text with causal self-attention (DMS-compressible) + cross-attention
over the encoder memory (static, DMS off by default).
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    num_layers=24,
    d_model=1024,
    vocab_size=256206,
    attn=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64, rope="full"),
    mlp=MLPConfig(d_ff=8192, kind="gelu"),
    layer_pattern=("attn",),
    norm="layernorm",
    encoder_layers=24,
    encoder_bidirectional=True,
    cross_attention=True,
    frontend="audio_frames",
    frontend_tokens=0,          # frontend feeds the encoder, not the decoder
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="audio",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
