"""Qwen-R1 7B (paper §4). 28L d_model=3584 28H (GQA kv=4) d_ff=18944."""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="qwen-r1-7b",
    num_layers=28,
    d_model=3584,
    vocab_size=152064,
    attn=AttentionConfig(num_heads=28, num_kv_heads=4, head_dim=128,
                         rope="full", rope_theta=1e6),
    mlp=MLPConfig(d_ff=18944, kind="swiglu"),
    layer_pattern=("attn",),
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="dense",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
