"""phi3-mini-3.8b — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

32L d_model=3072 32H (GQA kv=32 == MHA) d_ff=8192 vocab=32064.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    num_layers=32,
    d_model=3072,
    vocab_size=32064,
    attn=AttentionConfig(num_heads=32, num_kv_heads=32, head_dim=96, rope="full"),
    mlp=MLPConfig(d_ff=8192, kind="swiglu"),
    layer_pattern=("attn",),
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="dense",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
