"""Architecture registry: one module per assigned arch + the paper's models.

``get_arch(name)`` returns the full-size :class:`ArchConfig`;
``get_smoke(name)`` a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.core.config import ArchConfig

_ARCH_MODULES = {
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini",
    "minitron-4b": "repro.configs.minitron_4b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t",
    # the paper's own model family (Qwen-R1 distills + Llama 3.2 1B)
    "qwen-r1-1.5b": "repro.configs.qwen_r1_1p5b",
    "qwen-r1-7b": "repro.configs.qwen_r1_7b",
    "qwen-r1-32b": "repro.configs.qwen_r1_32b",
    "llama32-1b": "repro.configs.llama32_1b",
}

ASSIGNED_ARCHS: List[str] = list(_ARCH_MODULES)[:10]
PAPER_ARCHS: List[str] = list(_ARCH_MODULES)[10:]


def get_arch(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    if hasattr(mod, "SMOKE"):
        return mod.SMOKE
    return get_arch(name).scaled_down()


def all_archs() -> Dict[str, ArchConfig]:
    return {n: get_arch(n) for n in _ARCH_MODULES}
