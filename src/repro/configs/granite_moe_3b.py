"""granite-moe-3b-a800m — 40 experts top-8 [hf:ibm-granite/granite-3.0; hf].

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 40e top-8.
"""
from repro.core.config import (ArchConfig, AttentionConfig, DMSConfig,
                               MLPConfig, MoEConfig)

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    num_layers=32,
    d_model=1536,
    vocab_size=49155,
    attn=AttentionConfig(num_heads=24, num_kv_heads=8, head_dim=64, rope="full"),
    mlp=MLPConfig(d_ff=512, kind="swiglu", moe=MoEConfig(num_experts=40, top_k=8)),
    layer_pattern=("attn",),
    tie_embeddings=True,
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="moe",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64, num_experts=8)
