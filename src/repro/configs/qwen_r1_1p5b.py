"""Qwen-R1 1.5B (DeepSeek-R1 distilled Qwen 2.5 1.5B) — the paper's smallest
reasoning model (§4).  28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="qwen-r1-1.5b",
    num_layers=28,
    d_model=1536,
    vocab_size=151936,
    attn=AttentionConfig(num_heads=12, num_kv_heads=2, head_dim=128,
                         rope="full", rope_theta=1e6),
    mlp=MLPConfig(d_ff=8960, kind="swiglu"),
    layer_pattern=("attn",),
    tie_embeddings=True,
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="dense",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
