"""Llama 3.2 1B Instruct — the paper's ablation model (§5.2, §5.3, Table 1).

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.  The Table-1 DMS
variant uses a 16-token window.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="llama32-1b",
    num_layers=16,
    d_model=2048,
    vocab_size=128256,
    attn=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=64,
                         rope="full", rope_theta=5e5),
    mlp=MLPConfig(d_ff=8192, kind="swiglu"),
    layer_pattern=("attn",),
    tie_embeddings=True,
    dms=DMSConfig(enabled=True, window=16, target_cr=4.0),
    family="dense",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
