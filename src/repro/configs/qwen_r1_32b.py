"""Qwen-R1 32B (paper §4, headline +9.1 AIME24 result).

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="qwen-r1-32b",
    num_layers=64,
    d_model=5120,
    vocab_size=152064,
    attn=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                         rope="full", rope_theta=1e6),
    mlp=MLPConfig(d_ff=27648, kind="swiglu"),
    layer_pattern=("attn",),
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="dense",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
