"""gemma2-2b — local+global alternating, logit softcap [arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; local window 4096,
attention softcap 50, final-logit softcap 30, pre+post block norms.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    num_layers=26,
    d_model=2304,
    vocab_size=256000,
    attn=AttentionConfig(num_heads=8, num_kv_heads=4, head_dim=256,
                         rope="full", window=4096, logit_softcap=50.0),
    mlp=MLPConfig(d_ff=9216, kind="geglu"),
    layer_pattern=("attn_local", "attn"),
    post_norm=True,
    logit_softcap=30.0,
    tie_embeddings=True,
    embedding_multiplier=2304 ** 0.5,
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="dense",
    # local+global hybrid: half the layers are windowed -> long_500k decodes
    # with bounded local caches + DMS-compressed global caches
    sub_quadratic=True,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
