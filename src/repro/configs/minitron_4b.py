"""minitron-4b — pruned nemotron [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    num_layers=32,
    d_model=3072,
    vocab_size=256000,
    attn=AttentionConfig(num_heads=24, num_kv_heads=8, head_dim=128, rope="full"),
    mlp=MLPConfig(d_ff=9216, kind="swiglu"),
    layer_pattern=("attn",),
    dms=DMSConfig(enabled=True, window=256, target_cr=8.0),
    family="dense",
    sub_quadratic=False,
)

SMOKE = CONFIG.scaled_down(num_layers=2, d_model=64)
