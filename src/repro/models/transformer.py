"""Unified model: decoder-only / hybrid / SSM / MoE / encoder-decoder LMs.

One code path covers every assigned architecture.  Layers are grouped into
*superblocks* (one repetition of ``arch.layer_pattern``); superblocks are
scanned with ``jax.lax.scan`` so HLO size and compile time stay bounded at
full depth (64-layer Mamba-2 compiles the same graph as a 2-layer one).

Params layout::

    {"embed": (V, D),
     "blocks": {"0": <stacked over superblocks>, "1": ...},   # per pattern pos
     "enc_blocks": {...},                                     # enc-dec only
     "final_norm": {...}, "lm_head": (D, V)?}

Caches for decode mirror the same structure: ``{"0": stacked-cache, ...}``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import policy as policy_lib
from repro.core.config import ArchConfig, KVPolicyConfig
from repro.models import attention as attn_lib
from repro.models import rglru as rglru_lib
from repro.models import ssd as ssd_lib
from repro.models.layers import init_mlp, init_norm, mlp_apply, norm_apply, softcap


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, arch: ArchConfig, kind: str, cross: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {}
    d = arch.d_model
    if kind in ("attn", "attn_local"):
        p["attn_norm"] = init_norm(d, arch.norm)
        p["attn"] = attn_lib.init_attention(ks[0], d, arch.attn)
        if arch.post_norm:
            p["attn_post_norm"] = init_norm(d, arch.norm)
        if cross:
            p["cross_norm"] = init_norm(d, arch.norm)
            p["cross"] = attn_lib.init_attention(ks[1], d, arch.attn)
        if arch.mlp is not None:
            p["mlp_norm"] = init_norm(d, arch.norm)
            p["mlp"] = init_mlp(ks[2], d, arch.mlp)
            if arch.post_norm:
                p["mlp_post_norm"] = init_norm(d, arch.norm)
    elif kind == "ssd":
        p["norm"] = init_norm(d, arch.norm)
        p["ssd"] = ssd_lib.init_ssd(ks[0], d, arch.ssm)
    elif kind == "rglru":
        p["rglru_norm"] = init_norm(d, arch.norm)
        p["rglru"] = rglru_lib.init_rglru(ks[0], d, arch.rglru)
        if arch.mlp is not None:
            p["mlp_norm"] = init_norm(d, arch.norm)
            p["mlp"] = init_mlp(ks[2], d, arch.mlp)
    else:
        raise ValueError(kind)
    return p


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_model(key, arch: ArchConfig) -> dict:
    ks = jax.random.split(key, 6)
    vp = arch.padded_vocab
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (vp, arch.d_model), jnp.float32) * 0.02,
        "final_norm": init_norm(arch.d_model, arch.norm),
    }
    if not arch.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            ks[1], (arch.d_model, vp), jnp.float32) * (arch.d_model ** -0.5)

    nsb = arch.num_superblocks
    blocks: Dict[str, Any] = {}
    for pi, kind in enumerate(arch.layer_pattern):
        layer_keys = jax.random.split(jax.random.fold_in(ks[2], pi), nsb)
        blocks[str(pi)] = _stack([
            _init_block(layer_keys[s], arch, kind, cross=arch.cross_attention)
            for s in range(nsb)])
    params["blocks"] = blocks

    if arch.encoder_layers:
        ne = arch.encoder_layers // arch.pattern_period
        enc_blocks: Dict[str, Any] = {}
        for pi, kind in enumerate(arch.layer_pattern):
            layer_keys = jax.random.split(jax.random.fold_in(ks[3], pi), ne)
            enc_blocks[str(pi)] = _stack([
                _init_block(layer_keys[s], arch, kind) for s in range(ne)])
        params["enc_blocks"] = enc_blocks
        params["enc_final_norm"] = init_norm(arch.d_model, arch.norm)
    return params


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------


def _layer_window(arch: ArchConfig, kind: str) -> Optional[int]:
    if kind == "attn_local":
        return arch.attn.window
    return None


def _apply_block_full(
    p: dict, x: jnp.ndarray, arch: ArchConfig, kind: str, *,
    mode: str, rng, positions, neuron_scale, use_kernel, collect_kv,
    causal: bool, enc_out: Optional[jnp.ndarray], attn_impl=None,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    aux: Dict[str, Any] = {}
    if kind in ("attn", "attn_local"):
        acfg = arch.attn if causal else dataclasses.replace(arch.attn, causal=False)
        h = norm_apply(p["attn_norm"], x, arch.norm, arch.norm_eps)
        a_out, a_aux = attn_lib.full_attention(
            p["attn"], h, acfg, arch,
            layer_window=_layer_window(arch, kind),
            mode=mode, dms_rng=rng, positions=positions,
            neuron_scale=neuron_scale, use_kernel=use_kernel,
            attn_impl=attn_impl, collect_kv=collect_kv)
        if arch.post_norm:
            a_out = norm_apply(p["attn_post_norm"], a_out, arch.norm, arch.norm_eps)
        x = x + a_out
        aux.update(a_aux)
        if enc_out is not None and "cross" in p:
            h = norm_apply(p["cross_norm"], x, arch.norm, arch.norm_eps)
            dtype = jnp.dtype(arch.dtype)
            ek = (enc_out.astype(dtype) @ p["cross"]["wk"].astype(dtype)).reshape(
                enc_out.shape[0], enc_out.shape[1], acfg.num_kv_heads, acfg.head_dim)
            ev = (enc_out.astype(dtype) @ p["cross"]["wv"].astype(dtype)).reshape(
                enc_out.shape[0], enc_out.shape[1], acfg.num_kv_heads, acfg.head_dim)
            c_out, _ = attn_lib.full_attention(
                p["cross"], h, dataclasses.replace(acfg, causal=False, rope="none"),
                arch, mode="vanilla", positions=positions, kv_override=(ek, ev))
            x = x + c_out
        if arch.mlp is not None:
            h = norm_apply(p["mlp_norm"], x, arch.norm, arch.norm_eps)
            m_out, m_aux = mlp_apply(p["mlp"], h, arch.mlp, jnp.dtype(arch.dtype))
            if arch.post_norm:
                m_out = norm_apply(p["mlp_post_norm"], m_out, arch.norm, arch.norm_eps)
            x = x + m_out
            aux.update(m_aux)
    elif kind == "ssd":
        h = norm_apply(p["norm"], x, arch.norm, arch.norm_eps)
        s_out, _ = ssd_lib.ssd_forward(p["ssd"], h, arch)
        x = x + s_out
    elif kind == "rglru":
        h = norm_apply(p["rglru_norm"], x, arch.norm, arch.norm_eps)
        r_out, _ = rglru_lib.rglru_forward(p["rglru"], h, arch)
        x = x + r_out
        if arch.mlp is not None:
            h = norm_apply(p["mlp_norm"], x, arch.norm, arch.norm_eps)
            m_out, m_aux = mlp_apply(p["mlp"], h, arch.mlp, jnp.dtype(arch.dtype))
            x = x + m_out
            aux.update(m_aux)
    return x, aux


def _scan_blocks(blocks, x, arch: ArchConfig, *, mode, rng, positions,
                 neuron_scale, use_kernel, collect_kv, causal, enc_out,
                 num_sb: int, remat: bool, scan_layers: bool = True,
                 attn_impl=None):
    """Apply all superblocks; accumulate DMS/MoE stats; optionally emit KV.

    ``scan_layers=True`` uses ``lax.scan`` (bounded HLO size / compile time);
    ``False`` unrolls (exact per-layer cost analysis for the dry-run roofline —
    XLA's cost model counts while-loop bodies once)."""
    if rng is None:
        rng = jax.random.PRNGKey(0)
    sb_rngs = jax.random.split(rng, num_sb * arch.pattern_period).reshape(
        num_sb, arch.pattern_period, 2)

    def body(carry, xs):
        x, a_sum, a_cnt, moe_aux = carry
        blk, rngs = xs
        ys = {}
        for pi, kind in enumerate(arch.layer_pattern):
            x, aux = _apply_block_full(
                blk[str(pi)], x, arch, kind,
                mode=mode, rng=rngs[pi], positions=positions,
                neuron_scale=neuron_scale, use_kernel=use_kernel,
                attn_impl=attn_impl, collect_kv=collect_kv, causal=causal,
                enc_out=enc_out)
            a_sum = a_sum + aux.get("alpha_sum", 0.0)
            a_cnt = a_cnt + aux.get("alpha_count", 0.0)
            moe_aux = moe_aux + aux.get("moe_aux_loss", 0.0)
            if collect_kv:
                ys[str(pi)] = {k: aux[k] for k in ("k_rope", "v", "retained", "alpha_bin")
                               if k in aux}
        return (x, a_sum, a_cnt, moe_aux), ys

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    zero = jnp.zeros((), jnp.float32)
    if scan_layers:
        (x, a_sum, a_cnt, moe_aux), ys = jax.lax.scan(
            body, (x, zero, zero, zero), (blocks, sb_rngs))
    else:
        carry = (x, zero, zero, zero)
        ys_list = []
        for s in range(num_sb):
            blk_s = jax.tree_util.tree_map(lambda a: a[s], blocks)
            carry, y = body(carry, (blk_s, sb_rngs[s]))
            ys_list.append(y)
        (x, a_sum, a_cnt, moe_aux) = carry
        ys = (jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys_list)
              if collect_kv and ys_list and ys_list[0] else {})
    return x, {"alpha_sum": a_sum, "alpha_count": a_cnt, "moe_aux_loss": moe_aux,
               "layer_kv": ys if collect_kv else None}


# ---------------------------------------------------------------------------
# public forward
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, arch: ArchConfig,
                 frontend_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(arch.dtype))
    if arch.embedding_multiplier != 1.0:
        x = x * jnp.asarray(arch.embedding_multiplier, x.dtype)
    if frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    return x


def lm_logits(params, x, arch: ArchConfig) -> jnp.ndarray:
    h = norm_apply(params["final_norm"], x, arch.norm, arch.norm_eps)
    dtype = jnp.dtype(arch.dtype)
    w = params["embed"].T if arch.tie_embeddings else params["lm_head"]
    logits = h.astype(dtype) @ w.astype(dtype)
    logits = softcap(logits.astype(jnp.float32), arch.logit_softcap)
    if arch.padded_vocab != arch.vocab_size:   # mask pad rows (see padded_vocab)
        live = jnp.arange(arch.padded_vocab) < arch.vocab_size
        logits = jnp.where(live, logits, -1e30)
    return logits


def encode(params, enc_embeds: jnp.ndarray, arch: ArchConfig, *,
           use_kernel: bool = False, scan_layers: bool = True,
           attn_impl=None) -> jnp.ndarray:
    """Encoder stack (bidirectional) over precomputed frontend embeddings."""
    ne = arch.encoder_layers // arch.pattern_period
    t = enc_embeds.shape[1]
    x, _ = _scan_blocks(
        params["enc_blocks"], enc_embeds.astype(jnp.dtype(arch.dtype)), arch,
        mode="vanilla", rng=None, positions=jnp.arange(t, dtype=jnp.int32),
        neuron_scale=0.0, use_kernel=use_kernel, collect_kv=False,
        causal=not arch.encoder_bidirectional, enc_out=None,
        num_sb=ne, remat=False, scan_layers=scan_layers, attn_impl=attn_impl)
    return norm_apply(params["enc_final_norm"], x, arch.norm, arch.norm_eps)


def model_forward(
    params: dict,
    tokens: jnp.ndarray,                       # (B, T_text) int32
    arch: ArchConfig,
    *,
    mode: str = "vanilla",                     # vanilla | dms_train | dms_eval | dms_phase1
    rng: Optional[jax.Array] = None,
    positions: Optional[jnp.ndarray] = None,
    neuron_scale: float = 0.0,
    use_kernel: bool = False,
    collect_kv: bool = False,
    remat: bool = False,
    scan_layers: bool = True,
    attn_impl: Optional[str] = None,
    frontend_embeds: Optional[jnp.ndarray] = None,   # (B, F, D) modality stub
    enc_embeds: Optional[jnp.ndarray] = None,        # (B, S_enc, D) enc-dec stub
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full forward.  Returns (logits (B, T, V), aux)."""
    enc_out = None
    if arch.encoder_layers and enc_embeds is not None:
        enc_out = encode(params, enc_embeds, arch, use_kernel=use_kernel,
                         scan_layers=scan_layers, attn_impl=attn_impl)
    x = embed_tokens(params, tokens, arch, frontend_embeds)
    t = x.shape[1]
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)
    x, aux = _scan_blocks(
        params["blocks"], x, arch, mode=mode, rng=rng, positions=positions,
        neuron_scale=neuron_scale, use_kernel=use_kernel, collect_kv=collect_kv,
        causal=True, enc_out=enc_out, num_sb=arch.num_superblocks, remat=remat,
        scan_layers=scan_layers, attn_impl=attn_impl)
    logits = lm_logits(params, x, arch)
    if enc_out is not None:
        aux["enc_out"] = enc_out
    return logits, aux


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------


def _init_layer_cache(arch: ArchConfig, kind: str, batch: int, max_len: int,
                      policy: KVPolicyConfig, dtype):
    if kind == "ssd":
        return ssd_lib.init_ssd_state(batch, arch.d_model, arch.ssm)
    if kind == "rglru":
        return rglru_lib.init_rglru_state(batch, arch.d_model, arch.rglru)
    # attention layers: every policy comes from the KVPolicy registry — the
    # model never special-cases a cache class (see repro.core.policy)
    return policy_lib.init_policy_cache(
        arch, batch, max_len, policy, layer_kind=kind,
        layer_window=_layer_window(arch, kind), dtype=dtype)


def init_decode_state(arch: ArchConfig, batch: int, max_len: int,
                      policy: KVPolicyConfig, dtype=None) -> Dict[str, Any]:
    """Provision the full decode state: one cache per layer-pattern position,
    stacked over superblocks (lane axis at position 1 on every leaf).

    KV arenas come out of the registry pre-padded to ``policy.block_p``
    multiples in the flash-decode kernel's native layout, with each cache's
    live-block table (docs/kernels.md) riding as ordinary lane-leading state
    — so fork/gather/reclaim/snapshot below need no block-table-specific
    code, and the decode step path never pads or reshapes an arena."""
    dtype = dtype or jnp.dtype(arch.dtype)
    nsb = arch.num_superblocks
    state: Dict[str, Any] = {}
    for pi, kind in enumerate(arch.layer_pattern):
        one = _init_layer_cache(arch, kind, batch, max_len, policy, dtype)
        state[str(pi)] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (nsb,) + a.shape), one)
    return state


# -- lane lifecycle over whole decode states --------------------------------
#
# Decode-state leaves are stacked over superblocks (axis 0) with the lane
# (batch) axis at position 1.  PolicyCache nodes dispatch through their
# policy's fork/reclaim lifecycle hooks; raw recurrent states (SSD / RG-LRU)
# fork and reset generically.


def _is_policy_cache(x) -> bool:
    return isinstance(x, policy_lib.PolicyCache)


def fork_decode_state(state: Dict[str, Any], width: int) -> Dict[str, Any]:
    """Shared-prefill fork: clone every lane into ``width`` chains.

    Prefill a prompt once, fork the whole decode state into W hyper-scaling
    chains — forked chains carry bitwise-identical cache/recurrent state, so
    step-0 decode logits match W independent prefills at 1/W of the
    prefill-phase KV reads."""

    def f(node):
        if _is_policy_cache(node):
            pol = policy_lib.get_policy(node.policy)
            return dataclasses.replace(
                node, cache=pol.fork_cache(node.cache, width, axis=1))
        return jnp.repeat(node, width, axis=1)

    return jax.tree_util.tree_map(f, state, is_leaf=_is_policy_cache)


def reclaim_lanes(state: Dict[str, Any], reset_mask: jnp.ndarray,
                  fresh: Dict[str, Any]) -> Dict[str, Any]:
    """EOS reclamation: lanes where ``reset_mask`` (B,) is True return to the
    pristine ``fresh`` state (arena empty, free list full, position 0)."""

    def f(node, init):
        if _is_policy_cache(node):
            pol = policy_lib.get_policy(node.policy)
            return dataclasses.replace(
                node, cache=pol.reclaim_cache(node.cache, reset_mask,
                                              init.cache, axis=1))
        m = reset_mask.reshape((1, -1) + (1,) * (node.ndim - 2))
        return jnp.where(m, init, node)

    return jax.tree_util.tree_map(f, state, fresh, is_leaf=_is_policy_cache)


def export_lane_state(state: Dict[str, Any], lane) -> Dict[str, Any]:
    """Snapshot one lane's complete decode state (cross-request prefix cache).

    Returns a width-1-lane pytree of the same structure: PolicyCache nodes
    dispatch through :meth:`KVPolicy.export_prefix`, raw recurrent states
    (SSD / RG-LRU) slice generically — a hybrid model's prefix snapshot
    carries its recurrent state too.  ``lane`` may be a traced int32 scalar,
    so one jit covers every lane."""

    def f(node):
        if _is_policy_cache(node):
            pol = policy_lib.get_policy(node.policy)
            return dataclasses.replace(
                node, cache=pol.export_prefix(node.cache, lane, axis=1))
        return jax.lax.dynamic_slice_in_dim(node, lane, 1, axis=1)

    return jax.tree_util.tree_map(f, state, is_leaf=_is_policy_cache)


def import_lane_state(state: Dict[str, Any], snap: Dict[str, Any],
                      lane) -> Dict[str, Any]:
    """Restore an :func:`export_lane_state` snapshot into lane ``lane``.

    The lane must be pristine (reclaimed); after the import it sits exactly
    where the exporting request's prefill stood, so chunk-prefilling only the
    suffix is bitwise-equal to a cold full prefill."""

    def f(node, s):
        if _is_policy_cache(node):
            pol = policy_lib.get_policy(node.policy)
            return dataclasses.replace(
                node, cache=pol.import_prefix(node.cache, s.cache, lane,
                                              axis=1))
        return jax.lax.dynamic_update_slice_in_dim(
            node, s.astype(node.dtype), lane, axis=1)

    return jax.tree_util.tree_map(f, state, snap, is_leaf=_is_policy_cache)


def init_snapshot_slab(snap: Dict[str, Any], slots: int) -> Dict[str, Any]:
    """Pre-allocate a device slab holding ``slots`` lane snapshots.

    ``snap`` is an :func:`export_lane_state` exemplar (lane axis width 1,
    position 1 on every leaf); the slab is the same pytree with the lane
    axis widened to ``slots`` — pure storage for the prefix cache's hot
    tier, written/read by :func:`store_lane_snapshot` /
    :func:`fetch_lane_snapshot` without ever leaving the device."""

    def f(a):
        return jnp.zeros(a.shape[:1] + (int(slots),) + a.shape[2:], a.dtype)

    return jax.tree_util.tree_map(f, snap)


def store_lane_snapshot(slab: Dict[str, Any], snap: Dict[str, Any],
                        slot) -> Dict[str, Any]:
    """Write a width-1 snapshot into slab slot ``slot`` — the device-side
    half of a *deferred* export: the freshly exported device snapshot is
    copied device-to-device into the slab and only materialized to host if
    the hot tier later demotes it.  PolicyCache nodes dispatch through
    :meth:`KVPolicy.import_slab`; raw recurrent state updates generically.
    ``slot`` may be a traced int32 scalar, so one jit covers every slot."""

    def f(node, s):
        if _is_policy_cache(node):
            pol = policy_lib.get_policy(node.policy)
            return dataclasses.replace(
                node, cache=pol.import_slab(node.cache, s.cache, slot,
                                            axis=1))
        return jax.lax.dynamic_update_slice_in_dim(
            node, s.astype(node.dtype), slot, axis=1)

    return jax.tree_util.tree_map(f, slab, snap, is_leaf=_is_policy_cache)


def fetch_lane_snapshot(slab: Dict[str, Any], slot) -> Dict[str, Any]:
    """Read the snapshot in slab slot ``slot`` — the zero-copy hot-hit path:
    the returned device pytree feeds :func:`import_lane_state` directly, so
    a hot prefix hit moves no host↔device bytes at all (dispatches through
    :meth:`KVPolicy.export_slab`)."""

    def f(node):
        if _is_policy_cache(node):
            pol = policy_lib.get_policy(node.policy)
            return dataclasses.replace(
                node, cache=pol.export_slab(node.cache, slot, axis=1))
        return jax.lax.dynamic_slice_in_dim(node, slot, 1, axis=1)

    return jax.tree_util.tree_map(f, slab, is_leaf=_is_policy_cache)


def lane_state_signature(state: Dict[str, Any]) -> Tuple:
    """Hashable shape signature of one lane's snapshot of ``state``.

    Two decode states produce interchangeable prefix snapshots iff their
    signatures match (same tree structure, same per-leaf shapes with the lane
    axis collapsed, same dtypes) — the prefix cache keys its radix trees by
    this, so snapshots from a scheduler with a different ``max_len``, policy
    config, or arch are never imported into an incompatible arena."""
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return (str(treedef),
            tuple((a.shape[:1] + (1,) + a.shape[2:], str(jnp.dtype(a.dtype)))
                  for a in leaves))


def gather_lanes(state: Dict[str, Any], src: jnp.ndarray) -> Dict[str, Any]:
    """Lane shuffle: new lane ``l`` takes old lane ``src[l]``'s full state.

    This is how the scheduler forks a prefilled lane into W free lanes inside
    a fixed-size batch (``src`` is the identity except forked targets).
    PolicyCache nodes dispatch through :meth:`KVPolicy.gather_cache` — the
    same override point as ``fork_cache`` for policies with non-lane state."""

    def f(node):
        if _is_policy_cache(node):
            pol = policy_lib.get_policy(node.policy)
            return dataclasses.replace(
                node, cache=pol.gather_cache(node.cache, src, axis=1))
        return jnp.take(node, src, axis=1)

    return jax.tree_util.tree_map(f, state, is_leaf=_is_policy_cache)


def decode_step(
    params: dict,
    token: jnp.ndarray,               # (B, 1) int32
    state: Dict[str, Any],
    arch: ArchConfig,
    pos_t: jnp.ndarray,               # scalar int32 OR per-lane (B,)
    *,
    use_kernel: bool = False,
    scan_layers: bool = True,
    enc_out: Optional[jnp.ndarray] = None,
    enc_valid: Optional[jnp.ndarray] = None,
    embed_override: Optional[jnp.ndarray] = None,
    active: Optional[jnp.ndarray] = None,   # (B,) bool — lane mask
) -> Tuple[jnp.ndarray, Dict[str, Any], Dict[str, Any]]:
    """One decode step.  Returns (logits (B, V), new_state, aux).

    Batch rows are independent *lanes*: ``pos_t`` may be per-lane and
    ``active`` masks lanes out of the step entirely — an inactive lane's
    cache/recurrent state is left untouched (the compute still runs, batched,
    but the state write is discarded) and it contributes zero to the
    ``reads_tokens`` budget axis.  This is what makes continuous batching
    honest: finished or idle lanes neither mutate state nor inflate meters.
    """
    x = (embed_override if embed_override is not None
         else embed_tokens(params, token, arch))
    impls = set()   # trace-time: attention implementations actually traced

    def body(carry, xs):
        x_t, live, reads = carry
        blk, cache = xs
        new_caches = {}
        for pi, kind in enumerate(arch.layer_pattern):
            p = blk[str(pi)]
            if kind in ("attn", "attn_local"):
                h = norm_apply(p["attn_norm"], x_t, arch.norm, arch.norm_eps)
                a_out, new_c, aux = attn_lib.decode_attention(
                    p["attn"], h, cache[str(pi)], arch.attn, arch,
                    layer_window=_layer_window(arch, kind), pos_t=pos_t,
                    use_kernel=use_kernel, active=active)
                impls.add(aux["attn_impl"])
                if arch.post_norm:
                    a_out = norm_apply(p["attn_post_norm"], a_out, arch.norm, arch.norm_eps)
                x_t = x_t + a_out
                live = live + aux["live_tokens"]
                reads = reads + aux["reads_tokens"]
                if enc_out is not None and "cross" in p:
                    h = norm_apply(p["cross_norm"], x_t, arch.norm, arch.norm_eps)
                    dtype = jnp.dtype(arch.dtype)
                    a = arch.attn
                    ek = (enc_out.astype(dtype) @ p["cross"]["wk"].astype(dtype)).reshape(
                        enc_out.shape[0], enc_out.shape[1], a.num_kv_heads, a.head_dim)
                    ev = (enc_out.astype(dtype) @ p["cross"]["wv"].astype(dtype)).reshape(
                        enc_out.shape[0], enc_out.shape[1], a.num_kv_heads, a.head_dim)
                    vmask = (enc_valid if enc_valid is not None else
                             jnp.ones(ek.shape[:2], bool))
                    c_out, _, _ = attn_lib.decode_attention(
                        p["cross"], h, None,
                        dataclasses.replace(a, causal=False, rope="none"), arch,
                        pos_t=pos_t,
                        cross_kv=(ek.transpose(0, 2, 1, 3), ev.transpose(0, 2, 1, 3),
                                  jnp.broadcast_to(vmask[:, None, :],
                                                   (ek.shape[0], a.num_kv_heads, ek.shape[1]))))
                    x_t = x_t + c_out
                if arch.mlp is not None:
                    h = norm_apply(p["mlp_norm"], x_t, arch.norm, arch.norm_eps)
                    m_out, _ = mlp_apply(p["mlp"], h, arch.mlp, jnp.dtype(arch.dtype))
                    if arch.post_norm:
                        m_out = norm_apply(p["mlp_post_norm"], m_out, arch.norm, arch.norm_eps)
                    x_t = x_t + m_out
            elif kind == "ssd":
                h = norm_apply(p["norm"], x_t, arch.norm, arch.norm_eps)
                s_out, new_c = ssd_lib.ssd_decode_step(p["ssd"], h, cache[str(pi)], arch)
                x_t = x_t + s_out
            elif kind == "rglru":
                h = norm_apply(p["rglru_norm"], x_t, arch.norm, arch.norm_eps)
                r_out, new_c = rglru_lib.rglru_decode_step(p["rglru"], h, cache[str(pi)], arch)
                x_t = x_t + r_out
                if arch.mlp is not None:
                    h = norm_apply(p["mlp_norm"], x_t, arch.norm, arch.norm_eps)
                    m_out, _ = mlp_apply(p["mlp"], h, arch.mlp, jnp.dtype(arch.dtype))
                    x_t = x_t + m_out
            new_caches[str(pi)] = new_c
        return (x_t, live, reads), new_caches

    b = x.shape[0]
    zero = jnp.zeros((b,), jnp.float32)
    if scan_layers:
        (x, live, reads), new_state = jax.lax.scan(
            body, (x, zero, zero), (params["blocks"], state))
    else:
        carry = (x, zero, zero)
        outs = []
        nsb = arch.num_superblocks
        for s in range(nsb):
            blk_s = jax.tree_util.tree_map(lambda a: a[s], params["blocks"])
            st_s = jax.tree_util.tree_map(lambda a: a[s], state)
            carry, y = body(carry, (blk_s, st_s))
            outs.append(y)
        (x, live, reads) = carry
        new_state = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *outs)
    if active is not None:
        new_state = lane_select(active, new_state, state)
        reads = reads * active.astype(reads.dtype)
    logits = lm_logits(params, x, arch)[:, 0]
    # static int (i32 under jit, lint-clean): 1 iff every attention layer
    # traced the Pallas kernel — a requested kernel that silently fell back
    # to the reference einsum is visible in the step metrics
    kernel_only = 1 if (impls and impls == {"kernel"}) else 0
    return logits, new_state, {"live_tokens": live, "reads_tokens": reads,
                               "attn_impl_kernel": kernel_only}


def lane_select(mask: jnp.ndarray, on_true: Any, on_false: Any) -> Any:
    """Per-lane select over two decode-state pytrees.

    Every array leaf of a decode state carries the batch (lane) axis at
    position 1 — leaves are stacked over superblocks first (see
    :func:`init_decode_state`) — so a (B,) bool mask broadcasts as
    (1, B, 1, ...).  Used for: freezing inactive lanes' state, reclaiming
    finished lanes back to a pristine arena, and scheduler lane admission.

    :class:`~repro.core.block_pool.BlockPool` nodes are lane-*shared* state
    with no lane axis: the updated pool is kept unconditionally — its
    mutation helpers already took the lane event mask, so inactive lanes
    produced no pool events to roll back (their per-lane ``phys`` page map
    rolls back here like any other leaf).
    """

    def sel(a, b):
        if isinstance(a, policy_lib.block_pool.BlockPool):
            return a
        m = mask.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, a, b)

    return jax.tree_util.tree_map(
        sel, on_true, on_false,
        is_leaf=lambda x: isinstance(x, policy_lib.block_pool.BlockPool))
