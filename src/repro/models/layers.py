"""Common neural-net layers: norms, RoPE variants, MLPs, MoE.

Pure-functional: ``init_*`` builds a params dict, ``*_apply`` consumes it.
All matmuls run in the config compute dtype (bf16 by default); norms and
softmax statistics in fp32.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, MLPConfig


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None, dtype=jnp.float32):
    scale = scale if scale is not None else d_in ** -0.5
    return jax.random.normal(key, (d_in, d_out), dtype) * scale


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(d: int, kind: str) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def norm_apply(p: dict, x: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE (full / half / M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float, rotary_dim: Optional[int] = None) -> jnp.ndarray:
    rd = rotary_dim or head_dim
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    kind: str = "full",
    mrope_sections: Tuple[int, ...] = (),
) -> jnp.ndarray:
    """x: (..., T, H, Dh); positions: (..., T) int — or (3, ..., T) for mrope.

    * full: rotate all head dims.
    * half: rotate the first Dh/2 dims only (ChatGLM-style 2-d RoPE).
    * mrope: Qwen2-VL multimodal RoPE — the Dh/2 frequency slots are split
      into sections (temporal, height, width), each driven by its own
      position stream.
    """
    dh = x.shape[-1]
    if kind == "none":
        return x
    if kind == "half":
        rot, keep = x[..., : dh // 2], x[..., dh // 2:]
        rotated = _rotate(rot, positions.astype(jnp.float32), theta)
        return jnp.concatenate([rotated, keep], axis=-1)
    if kind == "mrope":
        freqs = rope_freqs(dh, theta)                       # (Dh/2,)
        # section id per frequency slot
        sec_id = jnp.repeat(
            jnp.arange(len(mrope_sections)),
            jnp.array(mrope_sections),
            total_repeat_length=dh // 2,
        )
        pos = positions.astype(jnp.float32)                 # (3, ..., T)
        pos_per_freq = pos[sec_id]                          # (Dh/2, ..., T)
        ang = jnp.moveaxis(pos_per_freq, 0, -1) * freqs     # (..., T, Dh/2)
        return _apply_angles(x, ang)
    return _rotate(x, positions.astype(jnp.float32), theta)


def _rotate(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)
    ang = pos[..., None] * freqs                             # (..., T, Dh/2)
    return _apply_angles(x, ang)


def _apply_angles(x: jnp.ndarray, ang: jnp.ndarray) -> jnp.ndarray:
    """ang: (..., T, Dh_rot/2); x: (..., T, H, Dh_rot)."""
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin, cos = sin[..., None, :], cos[..., None, :]          # broadcast over heads
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense + MoE)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, cfg: MLPConfig) -> dict:
    ks = jax.random.split(key, 4)
    f = cfg.d_ff
    if cfg.moe is None:
        if cfg.kind in ("swiglu", "geglu"):
            return {
                "w_gate": dense_init(ks[0], d_model, f),
                "w_up": dense_init(ks[1], d_model, f),
                "w_down": dense_init(ks[2], f, d_model),
            }
        return {"w_up": dense_init(ks[0], d_model, f), "w_down": dense_init(ks[1], f, d_model)}
    e = cfg.moe.num_experts
    def einit(k, a, b):
        return jax.random.normal(k, (e, a, b), jnp.float32) * (a ** -0.5)
    p = {"router": dense_init(ks[3], d_model, e, scale=0.02)}
    if cfg.kind in ("swiglu", "geglu"):
        p.update(
            w_gate=einit(ks[0], d_model, f),
            w_up=einit(ks[1], d_model, f),
            w_down=einit(ks[2], f, d_model),
        )
    else:
        p.update(w_up=einit(ks[0], d_model, f), w_down=einit(ks[1], f, d_model))
    return p


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x)
    return jax.nn.gelu(x)


def _tp_divides(dim: int) -> bool:
    try:
        mesh = jax._src.mesh.thread_resources.env.physical_mesh
        return (not mesh.empty) and "model" in mesh.axis_names \
            and dim % mesh.shape["model"] == 0
    except Exception:
        return False


def _maybe_shard(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """Best-effort sharding constraint: applies when tracing under a mesh
    context (pjit/dry-run), no-op otherwise (CPU unit tests)."""
    try:
        from jax.sharding import PartitionSpec as P
        import jax.interpreters.pxla  # noqa: F401
        mesh = jax._src.mesh.thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
    except Exception:
        return x


def mlp_apply(p: dict, x: jnp.ndarray, cfg: MLPConfig, dtype) -> Tuple[jnp.ndarray, dict]:
    """Returns (y, aux) — aux carries the MoE load-balancing loss."""
    if cfg.moe is None:
        xd = x.astype(dtype)
        if cfg.kind in ("swiglu", "geglu"):
            h = _act(xd @ p["w_gate"].astype(dtype), cfg.kind) * (xd @ p["w_up"].astype(dtype))
        else:
            h = _act(xd @ p["w_up"].astype(dtype), cfg.kind)
        return (h @ p["w_down"].astype(dtype)).astype(x.dtype), {}
    return moe_apply(p, x, cfg, dtype)


def moe_apply(p: dict, x: jnp.ndarray, cfg: MLPConfig, dtype,
              capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, dict]:
    """Token-choice top-k MoE with *per-row* capacity dispatch (GShard groups
    = sequences).  Each batch row packs its own expert queues of capacity
    ``C = ceil(capacity_factor · T · k / E)`` so the dispatch buffers stay
    data-parallel-local — no global cumsum across shards.  Over-capacity
    tokens drop that expert (combine weight renormalised over survivors).
    Buffers are EP-sharded on experts when E divides the model axis (the
    scatter lowers to the EP all-to-all), else sharded on the hidden dim.
    """
    moe = cfg.moe
    b, t, d = x.shape
    e, k = moe.num_experts, moe.top_k
    xt = x.astype(dtype)                                             # (B, T, D)

    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)    # (B, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                           # (B, T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    capacity = max(int(capacity_factor * t * k / e), 4)
    # position of each (token, slot) within its (row, expert) queue
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)               # (B, T, k, E)
    flat = onehot.reshape(b, t * k, e)
    pos_in_e = jnp.cumsum(flat, axis=1) - 1                          # (B, T*k, E)
    pos = jnp.sum(pos_in_e.reshape(b, t, k, e) * onehot, axis=-1)    # (B, T, k)
    keep = pos < capacity
    top_p = jnp.where(keep, top_p, 0.0)

    nk = t * k
    b_idx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, nk)).reshape(-1)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[None, :, None], (b, t, k)).reshape(-1)
    e_idx = top_e.reshape(-1)
    c_idx = jnp.clip(pos.reshape(-1), 0, capacity - 1)
    w_disp = keep.reshape(-1).astype(dtype)
    buf = jnp.zeros((b, e, capacity, d), dtype)
    # EP on experts when E divides the model axis; otherwise shard the
    # capacity dim — a pure batch dim of the expert einsum, so the FFN stays
    # collective-free and only the (small) scatter/gather crosses shards
    buf = _maybe_shard(buf, None, "model", None, None) if _tp_divides(e) else \
        _maybe_shard(buf, None, None, "model", None)
    buf = buf.at[b_idx, e_idx, c_idx].add(xt[b_idx, tok_idx] * w_disp[:, None])

    # expert FFN: (B, E, C, D) x (E, D, F)
    if cfg.kind in ("swiglu", "geglu"):
        h = _act(jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dtype)), cfg.kind)
        h = h * jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dtype))
    else:
        h = _act(jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dtype)), cfg.kind)
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(dtype))

    # combine: gather each (row, token, slot)'s expert output, weight, sum
    gathered = out_buf[b_idx, e_idx, c_idx]                          # (B*T*k, D)
    w_comb = (top_p.reshape(-1).astype(dtype) * w_disp)[:, None]
    y = jnp.zeros((b, t, d), dtype).at[b_idx, tok_idx].add(gathered * w_comb)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=2).reshape(b * t, e), axis=0)
    frac_probs = jnp.mean(probs.reshape(b * t, e), axis=0)
    aux = {"moe_aux_loss": moe.aux_loss_weight * e * jnp.sum(frac_tokens * frac_probs),
           "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y.astype(x.dtype), aux
