"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Block = (x-branch: linear -> causal conv1d -> RG-LRU) ⊙ (y-branch: linear ->
GeLU) -> linear out.  RG-LRU recurrence (per channel):

    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(c * softplus(Λ) * (-r_t))     = a^(c·r_t), a = sigmoid(Λ)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training uses an associative scan over T; decode is a single step carrying
(h, conv buffer).  Fixed-size state ⇒ no KV cache ⇒ DMS does not apply to
these layers (it applies to the hybrid's local-attention layers).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, RGLRUConfig
from repro.core.kv_cache import _tree_dataclass
from repro.models.layers import dense_init

_C = 8.0  # Griffin's fixed gate exponent


@_tree_dataclass
class RGLRUState:
    h: jnp.ndarray      # (B, W) recurrent state (fp32)
    conv: jnp.ndarray   # (B, K-1, W)
    length: jnp.ndarray


def init_rglru(key, d_model: int, cfg: RGLRUConfig) -> dict:
    w = cfg.lru_width or d_model
    ks = jax.random.split(key, 6)
    # Λ init so that a^c = sigmoid(Λ)^c spreads over (0.9, 0.999) at r=1
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    a0 = jnp.exp(jnp.log(u) / _C)            # a = u^(1/c) in (0, 1)
    lam = jnp.log(a0) - jnp.log1p(-a0)       # logit(a)
    return {
        "w_x": dense_init(ks[1], d_model, w),
        "w_y": dense_init(ks[2], d_model, w),
        "conv_w": jax.random.normal(ks[3], (cfg.conv_kernel, w), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_gate_r": dense_init(ks[4], w, w, scale=w ** -0.5),
        "b_gate_r": jnp.zeros((w,), jnp.float32),
        "w_gate_i": dense_init(ks[5], w, w, scale=w ** -0.5),
        "b_gate_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "w_out": dense_init(jax.random.fold_in(key, 7), w, d_model),
    }


def _gates(p, u, dtype):
    """u: (..., W) conv output.  Returns (log_a, gated_input) fp32."""
    uf = u.astype(dtype)
    r = jax.nn.sigmoid((uf @ p["w_gate_r"].astype(dtype)).astype(jnp.float32) + p["b_gate_r"])
    i = jax.nn.sigmoid((uf @ p["w_gate_i"].astype(dtype)).astype(jnp.float32) + p["b_gate_i"])
    # log a_t = c * r_t * log sigmoid(Λ) = -c * r_t * softplus(-Λ)   (<= 0)
    log_a = -_C * jax.nn.softplus(-p["lam"]) * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * u.astype(jnp.float32))
    return log_a, gated


def rglru_forward(p: dict, xin: jnp.ndarray, arch: ArchConfig,
                  state: Optional[RGLRUState] = None
                  ) -> Tuple[jnp.ndarray, Optional[RGLRUState]]:
    """Full-sequence forward.  xin: (B, T, D)."""
    cfg = arch.rglru
    dtype = jnp.dtype(arch.dtype)
    bsz, t, _ = xin.shape
    w = cfg.lru_width or arch.d_model
    k = cfg.conv_kernel

    x = xin.astype(dtype) @ p["w_x"].astype(dtype)            # (B,T,W)
    y = jax.nn.gelu((xin.astype(dtype) @ p["w_y"].astype(dtype)).astype(jnp.float32))

    pad = (jnp.zeros((bsz, k - 1, w), x.dtype) if state is None
           else state.conv.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    u = sum(xp[:, i:i + t] * p["conv_w"].astype(dtype)[i] for i in range(k))
    u = u + p["conv_b"].astype(dtype)
    new_conv = xp[:, t:t + k - 1] if t >= k - 1 else jnp.concatenate([pad[:, t:], x], axis=1)

    log_a, gated = _gates(p, u, dtype)                        # (B,T,W) fp32

    # associative scan:  h_t = a_t h_{t-1} + b_t  ==  (a, b) ∘ (a', b')
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq = jnp.exp(log_a)
    b_seq = gated
    if state is not None:
        b_seq = b_seq.at[:, 0].add(a_seq[:, 0] * state.h.astype(jnp.float32))
    _, h_seq = jax.lax.associative_scan(combine, (a_seq, b_seq), axis=1)
    h_final = h_seq[:, -1]

    out = (h_seq * y).astype(dtype) @ p["w_out"].astype(dtype)
    return out.astype(xin.dtype), RGLRUState(
        h_final, new_conv, (state.length if state is not None else 0) + t)


def rglru_decode_step(p: dict, x_t: jnp.ndarray, state: RGLRUState, arch: ArchConfig
                      ) -> Tuple[jnp.ndarray, RGLRUState]:
    cfg = arch.rglru
    dtype = jnp.dtype(arch.dtype)
    bsz = x_t.shape[0]
    x = x_t.astype(dtype) @ p["w_x"].astype(dtype)            # (B,1,W)
    y = jax.nn.gelu((x_t.astype(dtype) @ p["w_y"].astype(dtype)).astype(jnp.float32))
    win = jnp.concatenate([state.conv.astype(x.dtype), x], axis=1)     # (B,K,W)
    u = jnp.einsum("bkw,kw->bw", win, p["conv_w"].astype(dtype)) + p["conv_b"].astype(dtype)
    log_a, gated = _gates(p, u[:, None], dtype)
    h = jnp.exp(log_a[:, 0]) * state.h.astype(jnp.float32) + gated[:, 0]
    out = ((h[:, None] * y).astype(dtype) @ p["w_out"].astype(dtype)).astype(x_t.dtype)
    return out, RGLRUState(h, win[:, 1:], state.length + 1)


def init_rglru_state(batch: int, d_model: int, cfg: RGLRUConfig) -> RGLRUState:
    w = cfg.lru_width or d_model
    return RGLRUState(
        jnp.zeros((batch, w), jnp.float32),
        jnp.zeros((batch, cfg.conv_kernel - 1, w), jnp.float32),
        jnp.zeros((batch,), jnp.int32),   # per-lane position (continuous batching)
    )
