"""Mamba-2 / SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: within a chunk the recurrence is computed as a masked
matmul (the "attention" dual form); chunk states are carried by a scan.
Attention-free: no KV cache — decode carries a fixed-size (H, Dh, N) state +
a (K-1)-deep conv buffer.  DMS is inapplicable here (documented in DESIGN.md
§Arch-applicability); the block exists so the framework covers the assigned
``mamba2-2.7b`` architecture and the long-context comparisons.

TP note: projections are stored as separate matrices (w_z/w_x/w_b/w_c/w_dt)
rather than one fused in_proj so each shards cleanly on the ``model`` axis
(head-parallel) without GSPMD halo exchanges at the concat boundaries.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, SSMConfig
from repro.core.kv_cache import _tree_dataclass
from repro.models.layers import dense_init


@_tree_dataclass
class SSDState:
    ssm: jnp.ndarray      # (B, H, Dh, N)
    conv_x: jnp.ndarray   # (B, K-1, d_inner)
    conv_b: jnp.ndarray   # (B, K-1, G*N)
    conv_c: jnp.ndarray   # (B, K-1, G*N)
    length: jnp.ndarray


def init_ssd(key, d_model: int, cfg: SSMConfig) -> dict:
    ks = jax.random.split(key, 8)
    di = cfg.d_inner(d_model)
    nh = cfg.num_heads(d_model)
    g, n = cfg.n_groups, cfg.d_state
    return {
        "w_z": dense_init(ks[0], d_model, di),
        "w_x": dense_init(ks[1], d_model, di),
        "w_b": dense_init(ks[2], d_model, g * n),
        "w_c": dense_init(ks[3], d_model, g * n),
        "w_dt": dense_init(ks[4], d_model, nh),
        "conv_x_w": jax.random.normal(ks[5], (cfg.conv_kernel, di), jnp.float32) * 0.1,
        "conv_x_b": jnp.zeros((di,), jnp.float32),
        "conv_b_w": jax.random.normal(ks[6], (cfg.conv_kernel, g * n), jnp.float32) * 0.1,
        "conv_b_b": jnp.zeros((g * n,), jnp.float32),
        "conv_c_w": jax.random.normal(ks[7], (cfg.conv_kernel, g * n), jnp.float32) * 0.1,
        "conv_c_b": jnp.zeros((g * n,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(jax.random.fold_in(key, 9), di, d_model),
    }


def _causal_conv(x, w, b, prev, t):
    """Depthwise causal conv.  x: (B,T,C); w: (K,C); prev: (B,K-1,C) history."""
    k = w.shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + t] * w.astype(x.dtype)[i] for i in range(k))
    y = jax.nn.silu(y + b.astype(x.dtype))
    new_prev = xp[:, t:t + k - 1] if t >= k - 1 else jnp.concatenate(
        [prev.astype(x.dtype)[:, t:], x], axis=1)
    return y, new_prev


def _gated_norm(y, z, scale, eps=1e-6):
    yz = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(yz * yz, axis=-1, keepdims=True)
    return (yz * jax.lax.rsqrt(var + eps) * scale).astype(y.dtype)


def ssd_forward(p: dict, xin: jnp.ndarray, arch: ArchConfig,
                state: Optional[SSDState] = None, use_kernel: bool = False,
                ) -> Tuple[jnp.ndarray, SSDState]:
    """Full-sequence SSD.  xin: (B, T, D).  Returns (y, final_state)."""
    cfg = arch.ssm
    dtype = jnp.dtype(arch.dtype)
    bsz, t, d_model = xin.shape
    di = cfg.d_inner(d_model)
    nh = cfg.num_heads(d_model)
    g, n, ph = cfg.n_groups, cfg.d_state, cfg.head_dim
    k = cfg.conv_kernel
    xd = xin.astype(dtype)

    z = xd @ p["w_z"].astype(dtype)
    x_in = xd @ p["w_x"].astype(dtype)
    b_in = xd @ p["w_b"].astype(dtype)
    c_in = xd @ p["w_c"].astype(dtype)
    dt = xd @ p["w_dt"].astype(dtype)

    def hist(name, ch):
        return (jnp.zeros((bsz, k - 1, ch), dtype) if state is None
                else getattr(state, name))

    x, new_cx = _causal_conv(x_in, p["conv_x_w"], p["conv_x_b"], hist("conv_x", di), t)
    bmat, new_cb = _causal_conv(b_in, p["conv_b_w"], p["conv_b_b"], hist("conv_b", g * n), t)
    cmat, new_cc = _causal_conv(c_in, p["conv_c_w"], p["conv_c_b"], hist("conv_c", g * n), t)

    x = x.reshape(bsz, t, nh, ph)
    bmat = bmat.reshape(bsz, t, g, n)
    cmat = cmat.reshape(bsz, t, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,T,H)
    a = -jnp.exp(p["a_log"])                                           # (H,)

    if use_kernel:
        from repro.kernels.ssd import ops as ssd_kops
        y, final = ssd_kops.ssd_chunked(
            x, dt, a, bmat, cmat, chunk=cfg.chunk_size,
            init_state=None if state is None else state.ssm)
    else:
        y, final = ssd_chunked_ref(
            x, dt, a, bmat, cmat, cfg.chunk_size,
            init_state=None if state is None else state.ssm)
    y = y + x.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = _gated_norm(y.reshape(bsz, t, di).astype(dtype), z, p["norm_scale"])
    out = (y.astype(dtype) @ p["w_out"].astype(dtype)).astype(xin.dtype)
    new_state = SSDState(final, new_cx, new_cb, new_cc,
                         (state.length if state is not None else 0) + t)
    return out, new_state


def ssd_chunked_ref(x, dt, a, bmat, cmat, q: int, init_state=None):
    """Chunked SSD reference.  x: (B,T,H,P); dt: (B,T,H); a: (H,);
    B/C: (B,T,G,N).  Returns (y (B,T,H,P) fp32, final_state (B,H,P,N))."""
    bsz, t, nh, ph = x.shape
    n = bmat.shape[-1]
    g = bmat.shape[2]
    if t % q:
        padlen = q - t % q
        x = jnp.pad(x, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padlen), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, padlen), (0, 0), (0, 0)))
    tt = x.shape[1]
    nc = tt // q
    rep = nh // g

    xc = x.reshape(bsz, nc, q, nh, ph).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, nh).astype(jnp.float32)
    bc = bmat.reshape(bsz, nc, q, g, n).astype(jnp.float32)
    cc = cmat.reshape(bsz, nc, q, g, n).astype(jnp.float32)
    bh = jnp.repeat(bc, rep, axis=3)                # (B,NC,Q,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    da = dtc * a[None, None, None, :]               # (B,NC,Q,H) log-decay
    cum = jnp.cumsum(da, axis=2)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,NC,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    scores = jnp.einsum("bcihn,bcjhn->bcijh", ch, bh) * l_mat
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dtc, xc)

    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)           # (B,NC,Q,H)
    chunk_state = jnp.einsum("bcjh,bcjh,bcjhn,bcjhp->bchpn",
                             decay_to_end, dtc, bh, xc)       # (B,NC,H,P,N)
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))                # (B,NC,H)

    def scan_fn(s, inp):
        cs, cd = inp
        return s * cd[..., None, None] + cs, s                # emit state BEFORE chunk

    s0 = (jnp.zeros((bsz, nh, ph, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, states_before = jax.lax.scan(
        scan_fn, s0,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    states_before = states_before.transpose(1, 0, 2, 3, 4)    # (B,NC,H,P,N)

    y_inter = jnp.einsum("bcihn,bchpn->bcihp", ch * jnp.exp(cum)[..., None], states_before)
    y = (y_intra + y_inter).reshape(bsz, tt, nh, ph)
    return y[:, :t], final


def ssd_decode_step(p: dict, x_t: jnp.ndarray, state: SSDState, arch: ArchConfig
                    ) -> Tuple[jnp.ndarray, SSDState]:
    """Single-token recurrent step.  x_t: (B, 1, D)."""
    cfg = arch.ssm
    dtype = jnp.dtype(arch.dtype)
    bsz, _, d_model = x_t.shape
    di = cfg.d_inner(d_model)
    nh = cfg.num_heads(d_model)
    g, n, ph = cfg.n_groups, cfg.d_state, cfg.head_dim
    xd = x_t.astype(dtype)

    z = xd @ p["w_z"].astype(dtype)
    x_in = xd @ p["w_x"].astype(dtype)
    b_in = xd @ p["w_b"].astype(dtype)
    c_in = xd @ p["w_c"].astype(dtype)
    dt = xd @ p["w_dt"].astype(dtype)

    x, new_cx = _causal_conv(x_in, p["conv_x_w"], p["conv_x_b"], state.conv_x, 1)
    bmat, new_cb = _causal_conv(b_in, p["conv_b_w"], p["conv_b_b"], state.conv_b, 1)
    cmat, new_cc = _causal_conv(c_in, p["conv_c_w"], p["conv_c_b"], state.conv_c, 1)

    x = x.reshape(bsz, nh, ph).astype(jnp.float32)
    bmat = jnp.repeat(bmat.reshape(bsz, g, n), nh // g, axis=1).astype(jnp.float32)
    cmat = jnp.repeat(cmat.reshape(bsz, g, n), nh // g, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None])
    s = state.ssm.astype(jnp.float32) * decay[..., None, None] + \
        jnp.einsum("bh,bhn,bhp->bhpn", dt, bmat, x)
    y = jnp.einsum("bhn,bhpn->bhp", cmat, s)
    y = y + x * p["d_skip"][None, :, None]
    y = _gated_norm(y.reshape(bsz, 1, di).astype(dtype), z, p["norm_scale"])
    out = (y.astype(dtype) @ p["w_out"].astype(dtype)).astype(x_t.dtype)
    return out, SSDState(s, new_cx, new_cb, new_cc, state.length + 1)


def init_ssd_state(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> SSDState:
    di = cfg.d_inner(d_model)
    nh = cfg.num_heads(d_model)
    gn = cfg.n_groups * cfg.d_state
    k1 = cfg.conv_kernel - 1
    return SSDState(
        jnp.zeros((batch, nh, cfg.head_dim, cfg.d_state), jnp.float32),
        jnp.zeros((batch, k1, di), dtype),
        jnp.zeros((batch, k1, gn), dtype),
        jnp.zeros((batch, k1, gn), dtype),
        jnp.zeros((batch,), jnp.int32),   # per-lane position (continuous batching)
    )
