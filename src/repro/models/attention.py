"""GQA attention with pluggable KV-cache policies; DMS is a first-class mode.

Three entry points:

* :func:`full_attention`  — full-sequence forward (training / prefill).  In
  DMS mode it extracts α from the borrowed query neuron, relaxes it with
  Gumbel-sigmoid (train) or binarises it (prefill), and applies the delayed-
  eviction mask.  Dispatches to the Pallas flash kernel when requested.
* :func:`decode_attention` — single-token decode against any cache class from
  :mod:`repro.core.kv_cache` / :mod:`repro.core.baselines`.
* :func:`attention_ref`    — the O(T²) masked-softmax oracle both paths and
  the kernels are tested against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dms as dms_lib
from repro.core import policy as policy_lib
from repro.core.config import ArchConfig, AttentionConfig
from repro.models.layers import apply_rope, dense_init, softcap

NEG_INF = dms_lib.NEG_INF


def init_attention(key, d_model: int, cfg: AttentionConfig) -> dict:
    ks = jax.random.split(key, 4)
    dh = cfg.head_dim
    return {
        "wq": dense_init(ks[0], d_model, cfg.num_heads * dh),
        "wk": dense_init(ks[1], d_model, cfg.num_kv_heads * dh),
        "wv": dense_init(ks[2], d_model, cfg.num_kv_heads * dh),
        "wo": dense_init(ks[3], cfg.num_heads * dh, d_model),
    }


def project_qkv(p: dict, x: jnp.ndarray, cfg: AttentionConfig, dtype):
    b, t, _ = x.shape
    xd = x.astype(dtype)
    q = (xd @ p["wq"].astype(dtype)).reshape(b, t, cfg.num_heads, cfg.head_dim)
    k = (xd @ p["wk"].astype(dtype)).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    v = (xd @ p["wv"].astype(dtype)).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def attention_ref(
    q: jnp.ndarray,           # (B, Tq, Hq, Dh)
    k: jnp.ndarray,           # (B, Tk, Hkv, Dh)
    v: jnp.ndarray,
    mask: Optional[jnp.ndarray],   # (B, Hkv, Tq, Tk) additive, or None
    logit_cap: Optional[float] = None,
) -> jnp.ndarray:
    """Masked-softmax GQA oracle.  fp32 statistics."""
    b, tq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, tq, hkv, g, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * (dh ** -0.5)
    scores = softcap(scores, logit_cap)
    if mask is not None:
        scores = scores + mask[:, :, None].astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(b, tq, hq, dh).astype(q.dtype)


def attention_chunked(
    q: jnp.ndarray,           # (B, Tq, Hq, Dh)
    k: jnp.ndarray,           # (B, Tk, Hkv, Dh)
    v: jnp.ndarray,
    alpha: Optional[jnp.ndarray],   # (B, Hkv, Tk) or None
    *,
    dms_delay: int = 0,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    chunk_q: int = 2048,
    chunk_k: int = 2048,
) -> jnp.ndarray:
    """Flash-style chunked attention in pure JAX (online softmax, unrolled
    chunk loops).  Never materialises T×T — the live intermediate is
    (chunk_q × chunk_k).  Statically skips chunks dead by causality/window.
    This is the dry-run lowering path: same FLOPs/memory shape as the Pallas
    kernel, but expressible to XLA's cost model (loops unrolled, not scanned).
    """
    b, tq, hq, dh = q.shape
    tk = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    cq, ck = min(chunk_q, tq), min(chunk_k, tk)
    nq, nk = -(-tq // cq), -(-tk // ck)
    scale = dh ** -0.5
    qg = q.reshape(b, tq, hkv, g, dh)
    log_surv = (dms_lib.eviction_log_survival(alpha) if alpha is not None else None)

    out_rows = []
    for qi in range(nq):
        q0, q1 = qi * cq, min((qi + 1) * cq, tq)
        qc = qg[:, q0:q1].astype(k.dtype)
        m = jnp.full((b, hkv, g, q1 - q0), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, g, q1 - q0), jnp.float32)
        acc = jnp.zeros((b, hkv, g, q1 - q0, dh), jnp.float32)
        for ki in range(nk):
            k0, k1 = ki * ck, min((ki + 1) * ck, tk)
            if causal and k0 > q1 - 1:
                continue                                   # static causal skip
            if window is not None and k1 - 1 < q0 - window + 1:
                continue                                   # static window skip
            kc = k[:, k0:k1]
            vc = v[:, k0:k1]
            # bf16 operands / fp32 accumulation (MXU semantics — no converts)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if logit_cap is not None:
                s = logit_cap * jnp.tanh(s / logit_cap)
            ids_q = jnp.arange(q0, q1)[:, None]
            ids_k = jnp.arange(k0, k1)[None, :]
            if log_surv is not None and dms_delay > 0:
                zone = (ids_q - ids_k) >= dms_delay
                s = s + jnp.where(zone[None, None, None],
                                  log_surv[:, :, None, None, k0:k1], 0.0)
            dead = jnp.zeros_like(s, bool)
            if causal:
                dead |= (ids_k > ids_q)[None, None, None]
            if window is not None:
                dead |= (ids_q - ids_k >= window)[None, None, None]
            s = jnp.where(dead, NEG_INF, s)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = corr * l + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            m = m_new
        l = jnp.where(l <= 0.0, 1.0, l)
        out_rows.append((acc / l[..., None]).transpose(0, 3, 1, 2, 4))
    out = jnp.concatenate(out_rows, axis=1)               # (B, Tq, Hkv, G, Dh)
    return out.reshape(b, tq, hq, dh).astype(q.dtype)


def attention_chunked_scan(
    q, k, v, alpha, *, dms_delay: int = 0, causal: bool = True,
    window: Optional[int] = None, logit_cap: Optional[float] = None,
    chunk_q: int = 1024, chunk_k: int = 1024,
) -> jnp.ndarray:
    """Same math as :func:`attention_chunked` but with ``lax.scan`` over both
    chunk loops — sequential by construction, so buffer liveness (and thus the
    dry-run memory pass) reflects a TPU-style schedule.  Used only where
    memory realism matters; the unrolled variant feeds the FLOP analysis."""
    b, tq, hq, dh = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    cq, ck = min(chunk_q, tq), min(chunk_k, tk)
    nq, nk = -(-tq // cq), -(-tk // ck)
    tqp, tkp = nq * cq, nk * ck
    scale = dh ** -0.5
    qp = jnp.pad(q, ((0, 0), (0, tqp - tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tkp - tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tkp - tk), (0, 0), (0, 0)))
    log_surv = (dms_lib.eviction_log_survival(alpha) if alpha is not None else None)
    if log_surv is not None:
        log_surv = jnp.pad(log_surv, ((0, 0), (0, 0), (0, tkp - tk)),
                           constant_values=NEG_INF)
        ls_blk = log_surv.reshape(b, hkv, nk, ck).transpose(2, 0, 1, 3)
    else:
        ls_blk = jnp.zeros((nk, b, hkv, ck), jnp.float32)
    qb = qp.reshape(b, nq, cq, hkv, g, dh).transpose(1, 0, 3, 4, 2, 5)  # (nq,B,H,G,cq,D)
    kb = kp.reshape(b, nk, ck, hkv, dh).transpose(1, 0, 3, 2, 4)        # (nk,B,H,ck,D)
    vb = vp.reshape(b, nk, ck, hkv, dh).transpose(1, 0, 3, 2, 4)

    def q_step(_, qx):
        qi, qc = qx

        def k_step(carry, kx):
            m, l, acc = carry
            ki, kc, vc, ls = kx
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if logit_cap is not None:
                s = logit_cap * jnp.tanh(s / logit_cap)
            ids_q = qi * cq + jnp.arange(cq)[:, None]
            ids_k = ki * ck + jnp.arange(ck)[None, :]
            if dms_delay > 0:
                zone = (ids_q - ids_k) >= dms_delay
                s = s + jnp.where(zone[None, None, None],
                                  ls[:, :, None, None, :], 0.0)
            dead = (ids_k >= tk)
            if causal:
                dead = dead | (ids_k > ids_q)
            if window is not None:
                dead = dead | (ids_q - ids_k >= window)
            s = jnp.where(dead[None, None, None], NEG_INF, s)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = corr * l + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((b, hkv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0),
            (jnp.arange(nk), kb, vb, ls_blk))
        l = jnp.where(l <= 0.0, 1.0, l)
        out = (acc / l[..., None]).astype(q.dtype)          # (B,H,G,cq,D)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    # (nq, B, H, G, cq, D) -> (B, nq, cq, H, G, D) -> (B, Tq, Hq, D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, tqp, hq, dh)
    return out[:, :tq]


def _causal_mask(tq: int, tk: int, q_offset: int = 0) -> jnp.ndarray:
    i = jnp.arange(tq)[:, None] + q_offset
    j = jnp.arange(tk)[None, :]
    return jnp.where(j <= i, 0.0, NEG_INF)


def _window_mask(tq: int, tk: int, window: int, q_offset: int = 0) -> jnp.ndarray:
    i = jnp.arange(tq)[:, None] + q_offset
    j = jnp.arange(tk)[None, :]
    return jnp.where((i - j) < window, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# full-sequence forward
# ---------------------------------------------------------------------------


def full_attention(
    p: dict,
    x: jnp.ndarray,
    cfg: AttentionConfig,
    arch: ArchConfig,
    *,
    layer_window: Optional[int] = None,
    mode: str = "vanilla",           # vanilla | dms_train | dms_eval | dms_phase1
    dms_rng: Optional[jax.Array] = None,
    positions: Optional[jnp.ndarray] = None,
    neuron_scale: float = 0.0,
    use_kernel: bool = False,
    attn_impl: Optional[str] = None,     # 'ref' | 'chunked' | 'kernel'
    collect_kv: bool = False,
    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,   # cross-attn
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Full-sequence attention; returns (output (B,T,D), aux).

    aux keys: alpha_sum / alpha_count (DMS loss), alpha (relaxed or binary),
    and optionally post-RoPE k, v + retained map for cache construction.
    """
    dtype = jnp.dtype(arch.dtype)
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)
    q_raw, k, v = project_qkv(p, x, cfg, dtype)
    if kv_override is not None:
        k, v = kv_override

    aux: Dict[str, Any] = {}
    alpha = None
    dms = arch.dms
    if mode == "dms_train" and dms.enabled:
        alpha, q_raw = dms_lib.train_alphas(q_raw, cfg.num_kv_heads, dms, dms_rng)
        aux["alpha_sum"] = jnp.sum(alpha)
        # static python float: alpha.size is shape-derived — materializing it
        # as a traced f32 scalar per layer per step is exactly what the
        # literal-materialize lint (repro.analysis) flags
        aux["alpha_count"] = float(alpha.size)
    elif mode == "dms_eval" and dms.enabled:
        alpha_bin, q_raw = dms_lib.infer_alphas(q_raw, cfg.num_kv_heads, dms)
        alpha = alpha_bin.astype(jnp.float32)
        aux["alpha_bin"] = alpha_bin
        aux["alpha_sum"] = jnp.sum(alpha)
        aux["alpha_count"] = float(alpha.size)     # static (see above)
    elif mode == "dms_phase1" and dms.enabled:
        # phase-1 retrofit: gradually zero the borrowed neuron, no masking yet
        q_raw = dms_lib.zero_borrowed_neuron(q_raw, cfg.num_kv_heads, neuron_scale)

    if cfg.rope != "none":
        rope_pos = positions
        if cfg.rope == "mrope" and positions.ndim == 1:
            rope_pos = jnp.broadcast_to(positions, (3,) + positions.shape)
        q = apply_rope(q_raw, rope_pos, cfg.rope_theta, cfg.rope, cfg.mrope_sections)
        k = apply_rope(k, rope_pos, cfg.rope_theta, cfg.rope, cfg.mrope_sections) \
            if kv_override is None else k
    else:
        q = q_raw

    window = layer_window if layer_window is not None else cfg.window
    impl = attn_impl or ("kernel" if use_kernel else "ref")

    if impl == "kernel" and kv_override is None:
        from repro.kernels.dms_attention import ops as kops
        out = kops.dms_flash_attention(
            q, k, v, alpha,
            window=window, dms_window=dms.window if (alpha is not None) else 0,
            causal=cfg.causal, logit_cap=cfg.logit_softcap,
            immediate=dms.immediate_eviction,
        )
    elif impl in ("chunked", "chunked_scan") and kv_override is None:
        delay = (1 if dms.immediate_eviction else dms.window) if alpha is not None else 0
        if impl == "chunked_scan":
            out = attention_chunked_scan(
                q, k, v, alpha, dms_delay=delay, causal=cfg.causal,
                window=window, logit_cap=cfg.logit_softcap)
        else:
            chunk = max(2048, t // 8)  # bound unrolled chunk pairs (compile time)
            out = attention_chunked(
                q, k, v, alpha, dms_delay=delay, causal=cfg.causal,
                window=window, logit_cap=cfg.logit_softcap,
                chunk_q=chunk, chunk_k=chunk)
    else:
        mask = None
        if cfg.causal:
            mask = _causal_mask(t, k.shape[1])
        if window is not None:
            wm = _window_mask(t, k.shape[1], window)
            mask = wm if mask is None else mask + wm
        if mask is not None:
            mask = jnp.broadcast_to(mask[None, None], (b, cfg.num_kv_heads, t, k.shape[1]))
        if alpha is not None:
            dmask = dms_lib.build_dms_mask(
                alpha, positions if positions.ndim == 1 else jnp.arange(t),
                jnp.arange(k.shape[1]), dms, causal=False)
            mask = dmask if mask is None else mask + dmask
        out = attention_ref(q, k, v, mask, cfg.logit_softcap)

    y = out.reshape(b, t, cfg.num_heads * cfg.head_dim) @ p["wo"].astype(dtype)

    if collect_kv:
        aux["k_rope"] = k.transpose(0, 2, 1, 3)    # (B, Hkv, T, Dh)
        aux["v"] = v.transpose(0, 2, 1, 3)
        if "alpha_bin" in aux:
            aux["retained"] = dms_lib.retained_after_prefill(aux["alpha_bin"], t, dms)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_attention(
    p: dict,
    x_t: jnp.ndarray,              # (B, 1, D)
    cache: Any,
    cfg: AttentionConfig,
    arch: ArchConfig,
    *,
    layer_window: Optional[int] = None,
    pos_t: Optional[jnp.ndarray] = None,   # scalar int32 OR per-lane (B,)
    use_kernel: bool = False,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
    active: Optional[jnp.ndarray] = None,  # (B,) scheduler live-lane mask
) -> Tuple[jnp.ndarray, Any, Dict[str, Any]]:
    """One decode step against a :class:`repro.core.policy.PolicyCache`.

    All policy behaviour (cache update, visibility, eviction, budget
    accounting) is dispatched through the KVPolicy registry keyed by the
    cache's static policy name — this function contains no per-policy code.

    ``pos_t`` may be a scalar (lockstep batch) or a per-lane (B,) vector:
    continuous batching runs lanes at different sequence positions (staggered
    admission / chunked prefill), so RoPE and window masking are per lane.

    Returns (output (B,1,D), new_cache, aux).  aux["live_tokens"] feeds the
    hyper-scaling peak-memory axis; aux["reads_tokens"] the KV-reads axis
    (the two differ for reads-sparse policies like Quest).
    """
    dtype = jnp.dtype(arch.dtype)
    b = x_t.shape[0]
    dms = arch.dms
    q_raw, k_new, v_new = project_qkv(p, x_t, cfg, dtype)
    if pos_t is None:
        pos_t = _cache_length(cache)                      # (B,) per lane
    pos_lane = jnp.broadcast_to(jnp.asarray(pos_t, jnp.int32), (b,))
    pos_arr = pos_lane[:, None]                           # (B, 1) for RoPE

    # cache is a PolicyCache (or None for encoder-memory cross-attention);
    # its static policy name is the only dispatch key
    pol = None if cache is None else policy_lib.get_policy(cache.policy)

    alpha_bin = None
    if pol is not None and pol.alpha_mode == "dms" and dms.enabled:
        alpha_bin, q_raw = dms_lib.infer_alphas(q_raw, cfg.num_kv_heads, dms)
        alpha_bin = alpha_bin[..., 0]                     # (B, Hkv)
    elif pol is not None and pol.alpha_mode == "always":
        logits = dms_lib.alpha_logits_from_q(q_raw, cfg.num_kv_heads, dms.logit_bias)
        alpha_bin = dms_lib.binary_alpha(logits)[..., 0]
        q_raw = dms_lib.zero_borrowed_neuron(q_raw, cfg.num_kv_heads)

    if cfg.rope != "none":
        rpos = (jnp.broadcast_to(pos_arr[None], (3, b, 1))
                if cfg.rope == "mrope" else pos_arr)
        q = apply_rope(q_raw, rpos, cfg.rope_theta, cfg.rope, cfg.mrope_sections)
        k_new = apply_rope(k_new, rpos, cfg.rope_theta, cfg.rope, cfg.mrope_sections)
    else:
        q = q_raw

    k_new_c = k_new.transpose(0, 2, 1, 3)                 # (B, Hkv, 1, Dh)
    v_new_c = v_new.transpose(0, 2, 1, 3)

    aux: Dict[str, Any] = {}
    window = layer_window if layer_window is not None else cfg.window

    if cross_kv is not None:
        k_all, v_all, valid = cross_kv                    # encoder memory: no update
        out, _, _ = _masked_decode(
            q, policy_lib.AttendSpec(k_all, v_all, valid), None, cfg,
            use_kernel)
        y = out.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ p["wo"].astype(dtype)
        aux["live_tokens"] = jnp.sum(valid, axis=-1).mean(axis=-1)
        aux["reads_tokens"] = aux["live_tokens"]
        return y.astype(x_t.dtype), cache, aux

    if pol is None:
        raise TypeError(f"decode_attention needs a PolicyCache, got {type(cache)}")

    # Per-layer noise salt for stochastic policies (Keyformer): a param
    # scalar is distinct per layer (incl. across superblocks — params are
    # never broadcast) yet bit-identical between the kernel and reference
    # attention paths, so policy noise streams decorrelate across layers
    # WITHOUT forking on float-ulp differences in activations.
    pol_aux = {"alpha_bin": alpha_bin, "pos_t": pos_lane, "attn_cfg": cfg,
               "arch": arch, "dtype": dtype, "active": active,
               "layer_salt": jax.lax.bitcast_convert_type(
                   p["wo"].reshape(-1)[0].astype(jnp.float32), jnp.uint32)}
    inner, spec = pol.decode_update(cache.cache, q, k_new_c, v_new_c, pol_aux)
    out, w_group, impl = _masked_decode(
        q, spec, window if spec.positions is not None else None, cfg,
        use_kernel, pos_lane, need_weights=spec.needs_weights)
    if spec.needs_weights:
        inner = pol.post_attend(inner, w_group, active=active)
    cache = dataclasses.replace(cache, cache=inner)

    y = out.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ p["wo"].astype(dtype)
    metrics = pol.metrics(inner)
    aux["live_tokens"] = metrics["live_tokens"]
    aux["reads_tokens"] = metrics["reads_tokens"]
    # trace-time constant ("kernel" | "ref"): which attention implementation
    # this layer actually traced — decode_step aggregates it so a requested
    # kernel that silently fell back is loud in the step metrics
    aux["attn_impl"] = impl
    return y.astype(x_t.dtype), cache, aux


def _masked_decode(q, spec, window, cfg, use_kernel,
                   pos_t=None, need_weights=False):
    """q: (B,1,Hq,Dh); ``spec``: an :class:`repro.core.policy.AttendSpec`
    (k/v: (B,Hkv,P,Dh), visible: (B,Hkv,P) bool, optional block table);
    pos_t: per-lane (B,) current positions (or scalar).

    Local-window layers additionally hide slots with position <= t - window
    (a *subset* restriction of ``spec.visible``, so the spec's live-block
    table stays a valid cover — the kernel masks the hidden slots in-block).
    Returns (out (B,1,Hq,Dh), group-summed weights (B,Hkv,P) or None, and
    the implementation actually traced — the static string "kernel" | "ref").
    """
    k, v, valid, pos = spec.k, spec.v, spec.visible, spec.positions
    b, _, hq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    vis = valid
    if window is not None and pos is not None and pos_t is not None:
        ptl = jnp.broadcast_to(jnp.asarray(pos_t, jnp.int32), (b,))
        vis = vis & (pos > (ptl[:, None, None] - window))
    if use_kernel:
        from repro.kernels.dms_decode import ops as dkops
        res = dkops.dms_decode_attention(
            q, k, v, vis, block_tbl=spec.block_tbl, block_n=spec.block_n,
            block_p=spec.block_p or None, logit_cap=cfg.logit_softcap,
            pool_k=spec.pool_k, pool_v=spec.pool_v, phys=spec.phys,
            need_weights=need_weights)
        if need_weights:
            out, weights = res
            return out, weights, "kernel"
        return res, None, "kernel"
    # MXU-style mixed precision: bf16 operands, fp32 accumulation — the cache
    # is never converted/materialised in fp32 (that would double decode traffic)
    qg = q[:, 0].reshape(b, hkv, g, dh).astype(k.dtype)
    scores = jnp.einsum("bhgd,bhpd->bhgp", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5)
    scores = softcap(scores, cfg.logit_softcap)
    scores = jnp.where(vis[:, :, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgp,bhpd->bhgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, hq, dh).astype(q.dtype)
    return out, (jnp.sum(w, axis=2) if need_weights else None), "ref"


def _cache_length(cache) -> jnp.ndarray:
    return cache.length
