"""Deterministic, index-based, shardable data pipeline.

Every batch is a pure function of ``(seed, step, shard)`` — no iterator
state.  This is the straggler/fault-tolerance story: any worker can
recompute any shard of any step after a restart (no data-loader checkpoint
needed), and elastic re-sharding is just a different ``num_shards``.

Two sources:
* synthetic LM streams with controllable structure (used by tests, examples,
  and the retrofit benchmarks — see :mod:`repro.data.tasks` for reasoning
  tasks with verifiable answers), and
* a memory-mapped token-file source for real corpora.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic_lm"        # synthetic_lm | copy_task | token_file
    accum_steps: int = 1
    token_file: Optional[str] = None
    # synthetic stream structure: local n-gram correlations so models can
    # actually learn something (loss decreases)
    ngram_order: int = 3


def _rng_for(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard, 0xD5]))


def _synthetic_tokens(cfg: DataConfig, rng: np.random.Generator,
                      batch: int) -> np.ndarray:
    """Markov stream: token_t depends on token_{t-1} through a fixed mixing
    permutation, with noise — learnable but non-trivial."""
    v = cfg.vocab_size
    perm_rng = np.random.default_rng(cfg.seed + 1)
    perm = perm_rng.permutation(v)
    toks = np.empty((batch, cfg.seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, v, size=batch)
    noise = rng.random((batch, cfg.seq_len))
    rand_tok = rng.integers(0, v, size=(batch, cfg.seq_len))
    for t in range(1, cfg.seq_len + 1):
        follow = perm[toks[:, t - 1]]
        toks[:, t] = np.where(noise[:, t - 1] < 0.75, follow, rand_tok[:, t - 1])
    return toks


def _copy_tokens(cfg: DataConfig, rng: np.random.Generator, batch: int) -> np.ndarray:
    """needle/copy structure: first half random, second half repeats it —
    exercises long-range retrieval (the NIAH-style stress for DMS)."""
    v = cfg.vocab_size
    half = (cfg.seq_len + 1) // 2
    first = rng.integers(2, v, size=(batch, half))
    toks = np.concatenate([first, first], axis=1)[:, :cfg.seq_len + 1]
    return toks.astype(np.int32)


def make_batch(cfg: DataConfig, step: int, shard: int = 0,
               num_shards: int = 1) -> Dict[str, np.ndarray]:
    """Global batch for ``step`` (or one shard of it)."""
    assert cfg.global_batch % num_shards == 0
    b = cfg.global_batch // num_shards
    rng = _rng_for(cfg, step, shard)
    if cfg.kind == "copy_task":
        toks = _copy_tokens(cfg, rng, b)
    elif cfg.kind == "token_file" and cfg.token_file:
        data = np.memmap(cfg.token_file, dtype=np.int32, mode="r")
        n_windows = (len(data) - 1) // cfg.seq_len
        idx = rng.integers(0, n_windows, size=b)
        toks = np.stack([data[i * cfg.seq_len:(i + 1) * cfg.seq_len + 1]
                         for i in idx]).astype(np.int32)
    else:
        toks = _synthetic_tokens(cfg, rng, b)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.accum_steps > 1:
        k = cfg.accum_steps
        batch = {n: a.reshape(k, b // k, *a.shape[1:]) for n, a in batch.items()}
    return batch


def batch_iterator(cfg: DataConfig, start_step: int = 0, shard: int = 0,
                   num_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, step, shard, num_shards)
        step += 1
