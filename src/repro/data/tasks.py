"""Synthetic reasoning tasks with verifiable answers — the accuracy side of
the hyper-scaling benchmarks (stand-ins for AIME/GPQA/LiveCodeBench, which
need real checkpoints; see DESIGN.md §Changed assumptions).

Each task emits (prompt_tokens, answer_token(s)); a model solves it by
generating after the prompt.  Difficulty is controlled so tiny CPU-trainable
models show a real accuracy-vs-budget curve:

* ``chain_arith`` — mod-V addition chains: answer = (Σ operands) mod K.
  Longer chains need more intermediate reasoning; sampling W parallel chains
  + majority voting improves accuracy (parallel scaling), as in §5.1.
* ``needle`` — copy/retrieve a token planted earlier in context (NIAH-like,
  §5.2): stresses exactly what aggressive KV eviction can break.
* ``var_track`` — variable-chain tracking (RULER VT-like, §5.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

SEP, EQ, PAD = 0, 1, 2  # reserved token ids
FIRST_SYM = 3


@dataclass(frozen=True)
class TaskConfig:
    kind: str = "chain_arith"   # chain_arith | needle | var_track
    vocab_size: int = 64
    prompt_len: int = 48
    chain_len: int = 6          # reasoning "depth" knob
    modulus: int = 10
    seed: int = 0


def sample_problem(cfg: TaskConfig, rng: np.random.Generator) -> Tuple[np.ndarray, int]:
    v = cfg.vocab_size
    if cfg.kind == "needle":
        needle_pos = rng.integers(1, cfg.prompt_len - 4)
        key = rng.integers(FIRST_SYM, v)
        toks = rng.integers(FIRST_SYM, v, size=cfg.prompt_len)
        toks[needle_pos] = key
        toks[needle_pos - 1] = SEP          # marker before the needle
        toks[-2] = SEP                      # query marker
        toks[-1] = EQ
        return toks.astype(np.int32), int(key)
    if cfg.kind == "var_track":
        # chain: x0 = c; x1 = x0; ...; query final variable's value
        n_vars = cfg.chain_len
        names = rng.choice(np.arange(FIRST_SYM, FIRST_SYM + 20), n_vars, replace=False)
        value = rng.integers(FIRST_SYM + 20, min(v, FIRST_SYM + 20 + cfg.modulus))
        toks: List[int] = []
        toks += [int(names[0]), EQ, int(value), SEP]
        for i in range(1, n_vars):
            toks += [int(names[i]), EQ, int(names[i - 1]), SEP]
        toks += [int(names[-1]), EQ]
        arr = np.full(cfg.prompt_len, PAD, np.int32)
        arr[-len(toks):] = toks[-cfg.prompt_len:]
        return arr, int(value)
    # chain_arith
    ops = rng.integers(0, cfg.modulus, size=cfg.chain_len)
    ans = int(ops.sum() % cfg.modulus)
    toks: List[int] = []
    for o in ops:
        toks += [FIRST_SYM + int(o), SEP]
    toks += [EQ]
    arr = np.full(cfg.prompt_len, PAD, np.int32)
    arr[-len(toks):] = toks[-cfg.prompt_len:]
    return arr, FIRST_SYM + ans


def answer_token(cfg: TaskConfig, ans: int) -> int:
    return ans


def make_eval_set(cfg: TaskConfig, n: int) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(cfg.seed + 1234)
    prompts = np.stack([sample_problem(cfg, rng)[0] for _ in range(n)])
    rng = np.random.default_rng(cfg.seed + 1234)
    answers = np.array([sample_problem(cfg, rng)[1] for _ in range(n)], np.int32)
    return prompts, answers


def make_train_batch(cfg: TaskConfig, step: int, batch: int
                     ) -> Dict[str, np.ndarray]:
    """Supervised next-token data: prompt followed by the answer token."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    toks = np.empty((batch, cfg.prompt_len + 1), np.int32)
    for i in range(batch):
        p, a = sample_problem(cfg, rng)
        toks[i, :-1] = p
        toks[i, -1] = a
    x = toks[:, :-1]
    y = toks[:, 1:]
    mask = np.zeros_like(y, np.float32)
    mask[:, -1] = 1.0                       # loss on the answer position only
    return {"tokens": x, "labels": y, "loss_mask": mask}
