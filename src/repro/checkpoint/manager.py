"""Fault-tolerant, mesh-elastic checkpointing.

Design (1000+-node ready, no external deps):

* **Mesh-elastic format**: every array is saved as its *logical* (global)
  value in per-leaf ``.npy`` files + a JSON manifest (tree structure, dtypes,
  step).  Restore works on a *different* mesh/pod count — shardings are
  re-applied by the caller via ``jax.device_put`` with the current rules.
  (At real 1000-node scale each host would write only its owned shards with
  the same manifest; the single-process container writes full arrays.)
* **Atomicity**: writes go to ``step_N.tmp/`` then ``os.rename`` — a crash
  mid-write never corrupts the latest checkpoint.
* **Async**: ``save(..., blocking=False)`` hands the host-transferred arrays
  to a writer thread so the train loop continues.
* **Retention**: keep-last-k + optional keep-every (milestones).
* **Auto-resume**: ``latest_step`` / ``restore`` pick up after preemption.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "\x1d"


def _flatten_with_names(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(
            str(getattr(p, "name", getattr(p, "key", getattr(p, "idx", p))))
            for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3,
                 keep_every: Optional[int] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.keep_every = keep_every
        self._thread: Optional[threading.Thread] = None

    # -- writing -------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = True) -> None:
        named = _flatten_with_names(tree)
        # device -> host before handing to the writer thread
        host = [(n, np.asarray(x)) for n, x in named]
        treedef = jax.tree_util.tree_structure(tree)
        if self._thread is not None:
            self._thread.join()
            self._thread = None

        def write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": [], "extra": extra or {},
                        "treedef": str(treedef)}
            for i, (name, arr) in enumerate(host):
                fn = f"leaf_{i:05d}.npy"
                np.save(tmp / fn, arr)
                manifest["leaves"].append(
                    {"name": name, "file": fn, "dtype": str(arr.dtype),
                     "shape": list(arr.shape)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        keep = set(steps[-self.keep_last:])
        if self.keep_every:
            keep |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- reading -------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int, Dict]:
        """Restore into the structure of ``like``; optionally device_put with
        ``shardings`` (a matching pytree of NamedShardings) — this is where
        elastic re-sharding happens."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_name = {m["name"]: m for m in manifest["leaves"]}
        named = _flatten_with_names(like)
        leaves = []
        for name, leaf in named:
            m = by_name[name]
            arr = np.load(d / m["file"])
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {leaf.shape}")
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, step, manifest.get("extra", {})
