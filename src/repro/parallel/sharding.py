"""Logical-axis sharding rules: DP / TP / EP / sequence(context) parallelism.

All rules are *adaptive*: a dimension is put on the ``model`` axis only when
it divides evenly (GSPMD tolerates uneven shardings but pads — we avoid that
except for MoE expert counts, where padding ≤ tp-1 experts is the standard
trade-off and noted in EXPERIMENTS.md).

Conventions (Megatron-style TP on the fused projection column dims):
  * embed (V, D)             → (model, None)   vocab-parallel
  * attn wq/wk/wv (D, H·Dh)  → (None, model)   head-parallel (fallback: repl.)
  * attn wo (H·Dh, D)        → (model, None)
  * mlp w_gate/up (D, F)     → (None, model);  w_down (F, D) → (model, None)
  * MoE experts (E, D, F)    → (model, None, None)   expert-parallel
  * SSD / RG-LRU channel dims → model (head-parallel recurrence)
  * batch dims               → ("pod", "data") (or ("data",) single-pod)
  * long-context decode (B=1) → KV length on "data" (context parallelism)

Stacked-superblock leading axes are never sharded.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.config import ArchConfig
from repro.launch.mesh import batch_axes


def _div(n: int, by: int) -> bool:
    return n % by == 0 and n >= by


def _model_if(dim: int, tp: int, allow_uneven: bool = False) -> Optional[str]:
    if _div(dim, tp) or (allow_uneven and dim > 1):
        return "model"
    return None


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...], arch: ArchConfig,
               tp: int) -> P:
    """PartitionSpec for one parameter identified by its tree path."""
    name = path[-1]
    inside_blocks = "blocks" in path or "enc_blocks" in path
    lead = (None,) if inside_blocks else ()         # stacked superblock dim

    def spec(*dims):
        return P(*lead, *dims)

    if name in ("embed", "lm_head"):
        v_dim = shape[0] if name == "embed" else shape[1]
        if name == "embed":
            return P(_model_if(shape[0], tp), None)
        return P(None, _model_if(shape[1], tp))
    if name in ("scale", "bias", "a_log", "d_skip", "dt_bias", "lam",
                "norm_scale", "b_gate_r", "b_gate_i",
                "conv_x_b", "conv_b_b", "conv_c_b", "conv_b"):
        # small vectors: shard the channel dim when it divides (ssd/rglru), else repl.
        if name in ("norm_scale", "lam", "b_gate_r", "b_gate_i"):
            return spec(_model_if(shape[-1], tp))
        if name in ("a_log", "d_skip", "dt_bias"):
            return spec(_model_if(shape[-1], tp))
        return spec(*([None] * (len(shape) - len(lead))))
    if name in ("wq", "wk", "wv"):
        return spec(None, _model_if(shape[-1], tp))
    if name == "wo":
        return spec(_model_if(shape[-2], tp), None)
    if name in ("w_gate", "w_up", "w_down", "router"):
        moe = arch.mlp is not None and arch.mlp.moe is not None
        nd = len(shape) - len(lead)
        if moe and nd == 3:                          # (E, D, F) / (E, F, D)
            e = shape[len(lead)]
            if e % tp == 0:                          # expert parallelism
                return spec("model", None, None)
            # E not divisible (e.g. 40 experts on 16): Megatron TP inside
            # each expert instead — shard the ffn dim
            if name == "w_down":
                return spec(None, _model_if(shape[-2], tp), None)
            return spec(None, None, _model_if(shape[-1], tp))
        if name == "router":
            return spec(None, None)
        if name == "w_down":
            return spec(_model_if(shape[-2], tp), None)
        return spec(None, _model_if(shape[-1], tp))
    # SSD projections
    if name in ("w_z", "w_x", "w_b", "w_c", "w_dt"):
        if name == "w_x" and "rglru" in path:
            return spec(None, _model_if(shape[-1], tp))
        return spec(None, _model_if(shape[-1], tp))
    if name in ("conv_x_w", "conv_b_w", "conv_c_w", "conv_w"):
        return spec(None, _model_if(shape[-1], tp))
    if name == "w_out":
        return spec(_model_if(shape[-2], tp), None)
    if name == "w_y":
        return spec(None, _model_if(shape[-1], tp))
    if name in ("w_gate_r", "w_gate_i"):
        return spec(None, _model_if(shape[-1], tp))
    # default: replicate
    return spec(*([None] * (len(shape) - len(lead))))


def param_shardings(params_shape: Any, arch: ArchConfig, mesh,
                    tp: Optional[int] = None) -> Any:
    """``tp`` overrides the tensor-parallel degree: tp=1 turns the model
    axis into extra data parallelism (the right plan for models that fit
    per-device — removes every activation all-reduce)."""
    tp = mesh.shape["model"] if tp is None else tp

    def one(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "name", str(p))) for p in path)
        spec = param_spec(keys, leaf.shape, arch, tp) if tp > 1 else \
            P(*([None] * len(leaf.shape)))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# activations / batches
# ---------------------------------------------------------------------------


def data_spec(mesh, batch: int, extra_dims: int = 1,
              batch_over_model: bool = False) -> P:
    """Batch on the data axes when divisible, else replicated."""
    ba = batch_axes(mesh) + (("model",) if batch_over_model else ())
    total = 1
    for a in ba:
        total *= mesh.shape[a]
    lead = ba if batch % total == 0 else None
    return P(lead, *([None] * extra_dims))


def batch_shardings(mesh, batch_tree: Any, microbatched: bool = False,
                    batch_over_model: bool = False) -> Any:
    """Batch dim on the data axes; with ``microbatched`` inputs (K, B/K, ...)
    the accumulation dim K stays unsharded and B/K carries data parallelism.
    ``batch_over_model`` adds the model axis to the batch axes (tp=1 plan)."""
    def one(leaf):
        if len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        if microbatched and len(leaf.shape) >= 2:
            spec = data_spec(mesh, leaf.shape[1], len(leaf.shape) - 2,
                             batch_over_model)
            return NamedSharding(mesh, P(None, *spec))
        return NamedSharding(mesh, data_spec(mesh, leaf.shape[0],
                                             len(leaf.shape) - 1,
                                             batch_over_model))
    return jax.tree_util.tree_map(one, batch_tree)


# ---------------------------------------------------------------------------
# KV / decode-state shardings
# ---------------------------------------------------------------------------


def cache_spec_with_rule(path: Tuple[str, ...], shape: Tuple[int, ...], mesh,
                         batch: int, arch: ArchConfig) -> Tuple[str, P]:
    """(rule name, PartitionSpec) for one decode-state leaf (stacked over
    superblocks: dim 0).

    Layouts: k/v (L,B,H,P,Dh); slot metadata/masks (L,B,H,P); rings
    (L,B,H,w); per-lane lengths (L,B); ssd state (L,B,H,Dh,N); conv buffers
    (L,B,K-1,C); rglru h (L,B,W); paged pool pages (L,NPOOL,bp,Dh) with
    refcounts (L,NPOOL) and scalar counters (L,); page maps (L,B,H,NB).

    Every leaf the decode state can contain must hit a *named* rule here —
    ``repro.analysis.contracts.check_sharding_coverage`` (the CI audit)
    flags any leaf answered by the "fallback" rule, so adding cache state
    forces an explicit sharding decision instead of silent replication.
    """
    tp = mesh.shape["model"]
    ba = batch_axes(mesh)
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]
    bspec = ba if batch % dp == 0 else None
    name = path[-1] if path else ""
    nd = len(shape)

    # paged block-pool leaves: pages are *shared mutable state* across every
    # lane mapping them (CoW fork, event-masked writes), so they cannot ride
    # the batch axes — deliberately replicated until multi-device pjit
    # serving lands (ROADMAP "multi-device serving"; pages would shard over
    # a dedicated pool axis with phys-aware routing, not over lanes).
    if "pool" in path:
        return "pool-replicated", P(*([None] * nd))
    if nd <= 1:
        return "low-rank", P(*([None] * nd))

    def slot_specs(h, p):
        """(head_spec, slot_spec): TP on heads when divisible; otherwise
        split-KV over 'model'; context parallelism over 'data' (or both) when
        the batch can't shard."""
        hspec = _model_if(h, tp)
        dsz = mesh.shape["data"]
        if bspec is None and hspec is None and _div(p, dsz * tp):
            return None, ("data", "model")
        if bspec is None and _div(p, dsz):
            return hspec, "data"
        if hspec is None and _div(p, tp):
            return None, "model"
        return hspec, None

    # block-table leaves (docs/kernels.md): per-(lane, head) NB-sized index
    # state — head-sharded like the arena metadata they describe, table
    # entries replicated (NB is small and consumed via scalar prefetch).
    # Matched by path so BlockTable.pos never falls into the arena-slot
    # "pos" rule below (its NB axis must stay in lockstep with tbl/count —
    # insert/evict mix them elementwise every step).
    if "blocks" in path:
        if nd == 4:                        # count / tbl / pos: (L,B,H,NB)
            return "block-table", P(None, bspec, _model_if(shape[2], tp),
                                    None)
        return "block-table", P(None, bspec, _model_if(shape[2], tp))
    # per-cache page map (L,B,H,NB): logical-block → pool-page indices —
    # lane-owned like the block table it translates, entries replicated.
    if name == "phys" and nd == 4:
        return "page-map", P(None, bspec, _model_if(shape[2], tp), None)
    if name in ("k", "v") and nd == 5:
        hspec, pspec = slot_specs(shape[2], shape[3])
        return "kv-arena", P(None, bspec, hspec, pspec, None)
    # per-slot metadata/masks aligned with the arena slot axis (pos/valid
    # rings, H2O mass, DMC weights, masked-DMS retained/alpha, Keyformer
    # scores) — sharded exactly like the slots they annotate.
    if name in ("pos", "valid", "free_ring", "acc", "z", "retained",
                "alpha", "score") and nd == 4:
        hspec, pspec = slot_specs(shape[2], shape[3])
        return "slot-meta", P(None, bspec, hspec, pspec)
    if name in ("kmin", "kmax") and nd == 5:
        return "quest-pages", P(None, bspec, _model_if(shape[2], tp), None,
                                None)
    if name in ("pending_slot", "pending_alpha") and nd == 4:
        return "pending-ring", P(None, bspec, _model_if(shape[2], tp), None)
    if name in ("free_head", "free_count", "overflowed", "count") and nd == 3:
        return "slot-scalars", P(None, bspec, _model_if(shape[2], tp))
    # per-lane scalars (L,B): lengths and Keyformer's per-step content salt —
    # lanes advance independently under continuous batching: batch-sharded,
    # nothing else to decide.
    if name in ("length", "salt") and nd == 2:
        return "lane-length", P(None, bspec)
    if name == "ssm" and nd == 5:
        return "ssd-state", P(None, bspec, _model_if(shape[2], tp), None,
                              None)
    if name in ("conv_x", "conv_b", "conv_c") and nd == 4:
        return "ssd-conv", P(None, bspec, None, _model_if(shape[3], tp))
    if name == "h" and nd == 3:                      # rglru state (L,B,W)
        return "rglru-state", P(None, bspec, _model_if(shape[2], tp))
    if name == "conv" and nd == 4:
        return "rglru-conv", P(None, bspec, None, _model_if(shape[3], tp))
    # fallback: batch on dim1 if present
    return "fallback", P(None, bspec, *([None] * (nd - 2)))


def cache_spec(path: Tuple[str, ...], shape: Tuple[int, ...], mesh,
               batch: int, arch: ArchConfig) -> P:
    return cache_spec_with_rule(path, shape, mesh, batch, arch)[1]


def cache_shardings(cache_shape: Any, mesh, batch: int, arch: ArchConfig) -> Any:
    def one(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "name", str(p))) for p in path)
        return NamedSharding(mesh, cache_spec(keys, leaf.shape, mesh, batch, arch))
    return jax.tree_util.tree_map_with_path(one, cache_shape)


def opt_shardings(params_shape: Any, arch: ArchConfig, mesh,
                  tp: Optional[int] = None) -> Any:
    """ZeRO-1: optimizer moments + fp32 master additionally sharded over the
    data axes on the largest still-unsharded divisible dim.  GSPMD then emits
    reduce-scatter(grads) → sharded update → all-gather(params), the
    memory-optimal schedule at 1000+ nodes.  With tp=1 (dp-only plan) the
    model axis joins the ZeRO shard axes."""
    from repro.optim.adamw import AdamWState
    dp_only = tp == 1
    tp = mesh.shape["model"] if not dp_only else 1
    ba = batch_axes(mesh) + (("model",) if dp_only else ())
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]

    def upgrade(path, leaf):
        keys = tuple(getattr(p, "key", getattr(p, "name", str(p))) for p in path)
        # strip the AdamWState prefix ('mu'/'nu'/'master') from the path
        keys = tuple(k for k in keys if k not in ("mu", "nu", "master"))
        spec = (list(param_spec(keys, leaf.shape, arch, tp)) if tp > 1
                else [None] * len(leaf.shape))
        while len(spec) < len(leaf.shape):
            spec.append(None)
        for i, (dim, s) in enumerate(zip(leaf.shape, spec)):
            if s is None and dim % dp == 0 and dim >= dp:
                spec[i] = ba if len(ba) > 1 else ba[0]
                break
        return NamedSharding(mesh, P(*spec))

    def shard_tree(tree):
        return jax.tree_util.tree_map_with_path(upgrade, tree)

    params_like = params_shape

    mu = shard_tree(params_like)
    nu = shard_tree(params_like)
    master = shard_tree(params_like)
    return AdamWState(step=NamedSharding(mesh, P()), mu=mu, nu=nu, master=master)


def prefill_out_shardings(out_shape: Any, mesh, arch: ArchConfig) -> Any:
    """Prefill returns (last logits (B, V), per-layer KV pytree (L,B,H,T,Dh)
    (+ retained maps)).  Shard batch on the data axes, kv-heads on model."""
    tp = mesh.shape["model"]
    ba = batch_axes(mesh)
    dp = 1
    for a in ba:
        dp *= mesh.shape[a]

    def one(leaf):
        shp = leaf.shape
        if len(shp) == 2:                       # logits (B, V)
            return NamedSharding(mesh, P(ba if shp[0] % dp == 0 else None,
                                         _model_if(shp[1], tp)))
        if len(shp) >= 4:                       # (L, B, H, T[, Dh])
            bspec = ba if shp[1] % dp == 0 else None
            hspec = _model_if(shp[2], tp)
            rest = [None] * (len(shp) - 3)
            return NamedSharding(mesh, P(None, bspec, hspec, *rest))
        return NamedSharding(mesh, P(*([None] * len(shp))))

    return jax.tree_util.tree_map(one, out_shape)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def tree_replicated(tree_shape: Any, mesh) -> Any:
    return jax.tree_util.tree_map(lambda _: replicated(mesh), tree_shape)
