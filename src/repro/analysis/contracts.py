"""Contract checkers: KVPolicy lifecycle, tree invariance, sharding coverage.

These are not jaxpr lints — they check the *interfaces* the decode path is
built on: every registered policy implements the full lifecycle protocol
with pytree leaf shapes/dtypes invariant across a decode step, and every
decode-state leaf maps to an explicit rule in ``parallel/sharding.py``
(a new cache field silently falling through to the generic fallback is how
multi-device serving rots — see ROADMAP "multi-device serving").
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.passes import Finding


def _avals(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), jax.eval_shape(lambda: leaf)
             if not hasattr(leaf, "shape") else leaf)
            for path, leaf in flat]


def _leaf_paths(tree) -> List[Tuple[str, Tuple[str, ...], Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = tuple(getattr(p, "key", getattr(p, "name", str(p)))
                     for p in path)
        out.append((jax.tree_util.keystr(path), keys, leaf))
    return out


def check_tree_invariance(fn: Callable, tree: Any, *args,
                          path: str = "") -> List[Finding]:
    """Assert ``fn(tree, *args)`` returns a pytree with identical structure
    and leaf shapes/dtypes (traced via ``eval_shape`` — nothing runs).

    This is the jit-stability contract of ``decode_step``: a state leaf that
    changes aval across a step retraces every caller and breaks ``scan``
    carries."""
    try:
        out = jax.eval_shape(fn, tree, *args)
    except Exception as e:  # noqa: BLE001 — the failure IS the finding
        return [Finding("error", "tree-state",
                        f"step function failed to trace: {e!r}", path=path)]
    t_in = jax.tree_util.tree_structure(tree)
    t_out = jax.tree_util.tree_structure(out)
    if t_in != t_out:
        return [Finding("error", "tree-state",
                        f"pytree structure changed across step: "
                        f"{t_in} -> {t_out}", path=path)]
    findings: List[Finding] = []
    for (pi, a), (_, b) in zip(_avals(tree), _avals(out)):
        if a.shape != b.shape or a.dtype != b.dtype:
            findings.append(Finding(
                "error", "tree-state",
                f"leaf aval changed across step: "
                f"{a.dtype}{list(a.shape)} -> {b.dtype}{list(b.shape)}",
                path=f"{path}{pi}"))
    return findings


# ---------------------------------------------------------------------------
# KVPolicy lifecycle
# ---------------------------------------------------------------------------


def check_policy_lifecycle(name: str, arch, cfg, *, batch: int = 2,
                           max_len: int = 16,
                           dtype=None) -> List[Finding]:
    """Exercise the full KVPolicy lifecycle for one registered policy on a
    tiny cache: init → decode_update (avals invariant) → post_attend →
    fork/gather/reclaim → export/import prefix roundtrip → metrics /
    peak_bytes.  Any hook that raises, or any step that changes the cache
    avals, is a finding."""
    from repro.core import policy as policy_lib
    pol = policy_lib.get_policy(name)
    dtype = dtype or jnp.dtype(arch.dtype)
    a = arch.attn
    path = f"policy:{name}"
    findings: List[Finding] = []

    def bad(hook: str, e: Exception) -> None:
        findings.append(Finding("error", "policy-protocol",
                                f"{hook} failed: {e!r}", path=path))

    try:
        cache = pol.init_cache(arch, batch, max_len, cfg,
                               layer_window=None, dtype=dtype)
        fresh = pol.init_cache(arch, batch, max_len, cfg,
                               layer_window=None, dtype=dtype)
    except Exception as e:  # noqa: BLE001
        bad("init_cache", e)
        return findings

    q = jnp.zeros((batch, 1, a.num_heads, a.head_dim), dtype)
    kn = jnp.zeros((batch, a.num_kv_heads, 1, a.head_dim), dtype)
    aux = {"alpha_bin": jnp.zeros((batch, a.num_kv_heads), bool),
           "pos_t": jnp.zeros((batch,), jnp.int32), "attn_cfg": a,
           "arch": arch, "dtype": dtype, "active": None}
    spec = None
    try:
        stepped, spec = pol.decode_update(cache, q, kn, kn, aux)
        findings += check_tree_invariance(
            lambda c: pol.decode_update(c, q, kn, kn, aux)[0], cache,
            path=f"{path}/decode_update")
    except Exception as e:  # noqa: BLE001
        bad("decode_update", e)
        stepped = cache
    if spec is not None and spec.needs_weights:
        try:
            w = jnp.zeros((batch, a.num_kv_heads, spec.k.shape[2]),
                          jnp.float32)
            findings += check_tree_invariance(
                lambda c: pol.post_attend(c, w), stepped,
                path=f"{path}/post_attend")
        except Exception as e:  # noqa: BLE001
            bad("post_attend", e)

    src = jnp.arange(batch, dtype=jnp.int32)
    mask = jnp.zeros((batch,), bool)
    for hook, fn in (
        ("fork_cache", lambda c: pol.gather_cache(
            pol.fork_cache(c, 1, axis=0), src, axis=0)),
        ("gather_cache", lambda c: pol.gather_cache(c, src, axis=0)),
        ("reclaim_cache", lambda c: pol.reclaim_cache(c, mask, fresh,
                                                      axis=0)),
        ("prefix-roundtrip", lambda c: pol.import_prefix(
            c, pol.export_prefix(c, jnp.int32(0), axis=0), jnp.int32(0),
            axis=0)),
    ):
        try:
            findings += check_tree_invariance(fn, stepped,
                                              path=f"{path}/{hook}")
        except Exception as e:  # noqa: BLE001
            bad(hook, e)

    try:
        m = pol.metrics(stepped)
        for key in ("live_tokens", "reads_tokens", "peak_bytes"):
            if key not in m:
                findings.append(Finding(
                    "error", "policy-protocol",
                    f"metrics() missing required key {key!r}", path=path))
        for key in ("live_tokens", "reads_tokens"):
            if key in m and np.shape(m[key]) != (batch,):
                findings.append(Finding(
                    "error", "policy-protocol",
                    f"metrics()[{key!r}] must be per-lane (B,), got "
                    f"{np.shape(m[key])}", path=path))
    except Exception as e:  # noqa: BLE001
        bad("metrics", e)
    try:
        pb = pol.peak_bytes(stepped)
        if not isinstance(pb, int) or pb <= 0:
            findings.append(Finding(
                "error", "policy-protocol",
                f"peak_bytes() must be a positive static int, got {pb!r}",
                path=path))
    except Exception as e:  # noqa: BLE001
        bad("peak_bytes", e)
    return findings


# ---------------------------------------------------------------------------
# sharding coverage
# ---------------------------------------------------------------------------


def check_sharding_coverage(state: Any, mesh, batch: int, arch,
                            allow: Tuple[str, ...] = ()) -> List[Finding]:
    """Every decode-state leaf must hit a *named* rule in
    ``parallel/sharding.py`` — a leaf answered by the generic fallback means
    someone added cache state without deciding how it shards (it would
    silently batch-shard or replicate under pjit).  ``allow`` lists leaf
    names for which the fallback is an explicit, commented decision."""
    from repro.parallel import sharding
    findings: List[Finding] = []
    for pstr, keys, leaf in _leaf_paths(state):
        if not hasattr(leaf, "shape"):
            continue
        rule, _ = sharding.cache_spec_with_rule(keys, leaf.shape, mesh,
                                                batch, arch)
        name = keys[-1] if keys else ""
        if rule == "fallback" and name not in allow:
            findings.append(Finding(
                "error", "sharding-coverage",
                f"leaf {name!r} {list(leaf.shape)} has no explicit sharding "
                "rule (generic fallback would silently batch-shard dim 1)",
                path=pstr))
    return findings
