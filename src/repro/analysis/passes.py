"""Finding type, pass registry, and the decode-path traffic lints.

A *pass* is a function ``(jaxpr, ctx) -> iterable[Finding]`` registered under
a rule name; :func:`run_passes` runs every registered pass (or a subset) over
one traced entry point and applies the allowlist.  Passes see the fully
recursed eqn stream (:func:`repro.analysis.jaxpr.walk_eqns`), so ops hiding
inside ``scan``/``cond``/``pjit`` bodies are linted like top-level ops.

Severity policy (docs/analysis.md):

* ``error`` — a known-pathological traffic pattern on the decode/fork/reclaim
  path (full-arena copy, arena-sized recast, KV upcast, whole-arena gather in
  table mode).  Always gates the audit.
* ``warn``  — suspicious but occasionally legitimate (a scalar float
  returned from a traced step).  Gates the audit unless allowlisted.
* ``info``  — an allowlisted finding, kept visible in reports, never gates.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr import out_elems, trace_jaxpr, walk_eqns


@dataclasses.dataclass(frozen=True)
class Finding:
    """One audit finding, anchored to a traced eqn or a pytree leaf."""

    severity: str          # "error" | "warn" | "info"
    rule: str              # registered pass / checker name
    message: str
    eqn: str = ""          # offending primitive + shape summary ("" = tree-level)
    nbytes: int = 0        # bytes the offending op materializes (0 if n/a)
    path: str = ""         # entry point or pytree path the finding anchors to

    def __str__(self) -> str:
        loc = f" [{self.path}]" if self.path else ""
        op = f" {self.eqn}" if self.eqn else ""
        nb = f" ({self.nbytes} B)" if self.nbytes else ""
        return f"{self.severity}:{self.rule}{loc}{op}{nb} — {self.message}"


@dataclasses.dataclass(frozen=True)
class LintContext:
    """Per-entry-point lint parameters.

    ``arena_elems`` is the element count of the smallest fully-provisioned
    KV arena reachable from the entry point: any op materializing that many
    elements (or more) is touching a whole arena, which the block-table
    contract forbids on the step path.  ``table_mode`` is True when auditing
    the block-table/kernel path, where even a *gather* over the provisioned
    arena indicates the wrapper re-materializing table order.
    """

    arena_elems: int
    table_mode: bool = False
    allow: Tuple[str, ...] = ()        # rule names allowlisted for this entry


_PASSES: Dict[str, Callable] = {}


def register_pass(name: str):
    """Register ``fn(jaxpr, ctx) -> iterable[Finding]`` under ``name``."""
    def deco(fn):
        if name in _PASSES:
            raise ValueError(f"duplicate analysis pass {name!r}")
        _PASSES[name] = fn
        return fn
    return deco


def available_passes() -> Tuple[str, ...]:
    return tuple(sorted(_PASSES))


def _eqn_str(eqn) -> str:
    outs = ",".join(f"{v.aval.dtype}{list(v.aval.shape)}" for v in eqn.outvars
                    if hasattr(v.aval, "shape"))
    return f"{eqn.primitive.name}->{outs}"


def _out_nbytes(eqn) -> int:
    return sum(int(np.prod(v.aval.shape)) * v.aval.dtype.itemsize
               for v in eqn.outvars if hasattr(v.aval, "shape"))


def run_passes(fn_or_jaxpr, ctx: LintContext, *args,
               passes: Optional[Iterable[str]] = None,
               path: str = "") -> List[Finding]:
    """Run lint passes over one entry point (callable + example args, or an
    already-traced jaxpr).  Allowlisted rules are downgraded to ``info``."""
    jaxpr = (fn_or_jaxpr if not callable(fn_or_jaxpr)
             else trace_jaxpr(fn_or_jaxpr, *args))
    names = tuple(passes) if passes is not None else available_passes()
    out: List[Finding] = []
    for name in names:
        for f in _PASSES[name](jaxpr, ctx):
            if f.rule in ctx.allow:
                f = dataclasses.replace(
                    f, severity="info",
                    message=f.message + " (allowlisted)")
            out.append(dataclasses.replace(f, path=f.path or path))
    return out


def gating(findings: Iterable[Finding]) -> List[Finding]:
    """The findings that fail an audit (everything not downgraded to info)."""
    return [f for f in findings if f.severity in ("error", "warn")]


# ---------------------------------------------------------------------------
# traffic lints
# ---------------------------------------------------------------------------


@register_pass("arena-pad")
def _arena_pad(jaxpr, ctx):
    """Full-arena ``pad``/``concatenate`` on the step path: the seed wrapper
    re-padded the whole provisioned arena every step of every layer — the
    copy the block-table layout exists to remove (docs/kernels.md)."""
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name in ("pad", "concatenate") \
                and out_elems(eqn) >= ctx.arena_elems:
            yield Finding("error", "arena-pad",
                          "arena-sized copy materialized on a step path",
                          eqn=_eqn_str(eqn), nbytes=_out_nbytes(eqn))


@register_pass("arena-cast")
def _arena_cast(jaxpr, ctx):
    """Arena-sized ``convert_element_type`` of integer/bool metadata (the
    seed's per-step ``valid.astype(int32)`` recast of the whole bitmap)."""
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name == "convert_element_type" \
                and out_elems(eqn) >= ctx.arena_elems \
                and not jnp.issubdtype(eqn.invars[0].aval.dtype, jnp.floating):
            yield Finding("error", "arena-cast",
                          "arena-sized metadata recast on a step path",
                          eqn=_eqn_str(eqn), nbytes=_out_nbytes(eqn))


@register_pass("kv-upcast")
def _kv_upcast(jaxpr, ctx):
    """Arena-sized dtype *upcast* of a floating KV leaf (e.g. bf16 → f32).

    Accumulating in f32 is correct — but via ``preferred_element_type`` on
    the dot, never by converting the cache itself: an arena-sized upcast
    doubles both the HBM read and the materialized footprint per step.
    Downcasts (DMC's f32 accumulators → model dtype) are by design.
    """
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = eqn.invars[0].aval.dtype
        dst = eqn.outvars[0].aval.dtype
        if jnp.issubdtype(src, jnp.floating) \
                and jnp.issubdtype(dst, jnp.floating) \
                and dst.itemsize > src.itemsize \
                and out_elems(eqn) >= ctx.arena_elems:
            yield Finding("error", "kv-upcast",
                          f"KV arena upcast {src} -> {dst} on a step path",
                          eqn=_eqn_str(eqn), nbytes=_out_nbytes(eqn))


@register_pass("arena-gather")
def _arena_gather(jaxpr, ctx):
    """In table mode, ``gather``/``dynamic_slice`` whose *operand* is the
    whole provisioned arena: the kernel consumes the arena in place through
    the scalar-prefetched block table, so a step-path gather over it means
    the wrapper is re-materializing table order (the dead-block-DMA pitfall
    reintroduced one level up).

    Rank-<3 operands are exempt: KV arenas and page pools are always ≥3-D
    ((B,H,S,Dh) / (NPOOL,bp,Dh)), while per-token lookups into big 2-D
    tables (the vocab embedding) are the normal decode front-end."""
    if not ctx.table_mode:
        return
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name not in ("gather", "dynamic_slice"):
            continue
        op = eqn.invars[0].aval
        if hasattr(op, "shape") and len(op.shape) >= 3 \
                and int(np.prod(op.shape)) >= ctx.arena_elems \
                and jnp.issubdtype(op.dtype, jnp.floating):
            yield Finding("error", "arena-gather",
                          "gather/slice over the whole provisioned arena "
                          "in table mode",
                          eqn=_eqn_str(eqn), nbytes=_out_nbytes(eqn))


@register_pass("ref-fallback")
def _ref_fallback(jaxpr, ctx):
    """In table (kernel) mode, the decode step must trace the Pallas decode
    kernel — a policy that requested ``use_kernel`` but traced the reference
    einsum instead used to be a *silent* fallback (the pre-weights-out
    ``needs_weights`` bypass), lying about HBM traffic for every score-based
    policy.  Two signals, both gating:

    * no ``pallas_call`` anywhere in the step program — attention fell back
      wholesale;
    * a ``dot_general`` with ≥2 batch dims over an arena-sized float operand
      — the reference ``bhgd,bhpd->bhgp`` score einsum streaming the whole
      provisioned arena (param matmuls have 0 batch dims; Quest's page
      scoring has small sub-arena operands — neither trips this).
    """
    if not ctx.table_mode:
        return
    saw_kernel = False
    for eqn in walk_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "pallas_call":
            saw_kernel = True
            continue
        if name != "dot_general":
            continue
        batch_dims = eqn.params["dimension_numbers"][1]
        if len(batch_dims[0]) < 2:
            continue
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape") \
                    and jnp.issubdtype(aval.dtype, jnp.floating) \
                    and int(np.prod(aval.shape)) >= ctx.arena_elems:
                yield Finding("error", "ref-fallback",
                              "reference attention einsum traced where the "
                              "kernel was requested",
                              eqn=_eqn_str(eqn), nbytes=_out_nbytes(eqn))
                break
    if not saw_kernel:
        yield Finding("error", "ref-fallback",
                      "no pallas_call in the decode program in kernel mode "
                      "— attention silently fell back to the reference path")


@register_pass("scalar-output")
def _scalar_output(jaxpr, ctx):
    """Size-1 float *outputs* of the traced step (e.g. the old
    ``aux["alpha_count"] = jnp.asarray(alpha.size, jnp.float32)``):
    shape-derived bookkeeping is static — returning it as a device scalar
    allocates a tiny array per step and invites a ``.item()`` host sync
    downstream.  Return a Python float, or allowlist with a comment (a
    genuine in-graph reduction that must live on device).

    Top-level outvars only: scalar intermediates inside scan/cond bodies
    (attention scales, carry counters) are fused away by XLA and fine."""
    for v in jaxpr.outvars:
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape") \
                and int(np.prod(aval.shape)) == 1 \
                and jnp.issubdtype(aval.dtype, jnp.floating):
            yield Finding("warn", "scalar-output",
                          "scalar float returned from a traced step "
                          "(static bookkeeping should be a host value)",
                          eqn=f"outvar {aval.dtype}{list(aval.shape)}")
