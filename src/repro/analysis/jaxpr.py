"""Shared jaxpr walking and traffic counting.

This is the machinery `benchmarks/decode_path.py` and
`benchmarks/paged_arena.py` grew independently; it lives here so the lint
passes, the benchmarks, and the tests all count the same ops the same way.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def walk_eqns(jaxpr) -> Iterator[Any]:
    """Yield every eqn in ``jaxpr``, recursing through the sub-jaxprs hiding
    in eqn params (``scan``/``cond``/``while``/``pjit``/``custom_vjp``/...)."""
    from jax.extend.core import ClosedJaxpr, Jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                    val, is_leaf=lambda x: isinstance(x, (Jaxpr, ClosedJaxpr))):
                if isinstance(sub, ClosedJaxpr):
                    yield from walk_eqns(sub.jaxpr)
                elif isinstance(sub, Jaxpr):
                    yield from walk_eqns(sub)


def trace_jaxpr(fn: Callable, *args, **kwargs):
    """``jax.make_jaxpr`` of an entry point, unwrapped to the raw Jaxpr."""
    return jax.make_jaxpr(fn, **kwargs)(*args).jaxpr


def dce(jaxpr):
    """Dead-code-eliminate a jaxpr so lints see what XLA will actually run.

    ``make_jaxpr`` keeps every traced eqn — e.g. the reference-path dense
    pool gather a paged cache builds alongside the kernel path (DCE'd in
    compilation when the kernel consumes the pool directly).  Linting the
    un-DCE'd program would flag traffic that never executes."""
    from jax._src.interpreters import partial_eval as pe
    new_jaxpr, _ = pe.dce_jaxpr(jaxpr, [True] * len(jaxpr.outvars))
    return new_jaxpr


def out_elems(eqn) -> int:
    """Largest output element count of one eqn (0 for token-only outputs)."""
    sizes = [int(np.prod(v.aval.shape)) for v in eqn.outvars
             if hasattr(v.aval, "shape")]
    return max(sizes) if sizes else 0


def count_arena_copies(fn: Callable, *args, arena_elems: int) -> Dict[str, int]:
    """Count full-arena copy ops in ``fn``'s jaxpr: ``pad``/``concatenate``
    whose output is arena-sized or larger (the seed wrapper's per-step
    re-pad), and ``convert_element_type`` on arena-sized *integer/bool*
    operands (the seed's ``valid.astype(int32)`` recast).  The block-table
    step path must show zero of each."""
    jaxpr = trace_jaxpr(fn, *args)
    pads = casts = 0
    for eqn in walk_eqns(jaxpr):
        big = out_elems(eqn) >= arena_elems
        if eqn.primitive.name in ("pad", "concatenate") and big:
            pads += 1
        elif eqn.primitive.name == "convert_element_type" and big and \
                not jnp.issubdtype(eqn.invars[0].aval.dtype, jnp.floating):
            casts += 1
    return {"arena_pad_copies": pads, "valid_recasts": casts}


def count_big_float_ops(jaxpr, min_elems: int) -> int:
    """Float ops with ≥ ``min_elems`` output elements = actual K/V bytes
    moving.  Integer metadata at any size is deliberately not counted (e.g.
    the paged pool's refcount recompute builds a pool-squared int32 one-hot
    — bookkeeping, not arena traffic)."""
    return sum(
        1 for eqn in walk_eqns(jaxpr)
        for v in eqn.outvars
        if hasattr(v.aval, "shape")
        and jnp.issubdtype(v.aval.dtype, jnp.floating)
        and int(np.prod(v.aval.shape)) >= min_elems)
