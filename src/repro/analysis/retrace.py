"""Retrace sentinel: exact compile budgets over a set of named jits.

The serving contract (docs/serving.md) is that the engine compiles each of
its jits once per *signature* — one chunk step per (num_lanes, chunk), one
reset per (b, ml) — and that admission order, prompt lengths, fork widths,
and EOS timing never retrace.  The sentinel pins that: it snapshots each
jit's compile-cache size on entry and, on exit, turns any compile beyond the
declared budget into a :class:`Finding`.

Usage::

    with RetraceSentinel(engine_jits(eng), budget=1) as sentinel:
        ...  # drive a mixed scheduler trace
    assert not sentinel.findings(), sentinel.compiles

``budget`` may be an int (applied to every jit), a dict of per-name budgets,
or an *exact* expectation via ``exact=`` (a compile count that must match
exactly — catching both retraces and silently-dead entry points).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Union

from repro.analysis.passes import Finding


def engine_jits(engine) -> Dict[str, Any]:
    """The compile-budgeted jits an :class:`repro.serving.engine.Engine`
    owns (its schedulers share them, so budgets span scheduler instances)."""
    return {
        "chunk": engine._chunk_jit,
        "gather": engine._gather_jit,
        "reset": engine._reset_jit,
        "prefill": engine._prefill_jit,
        "export": engine._export_jit,
        "import": engine._import_jit,
    }


def scheduler_jits(scheduler) -> Dict[str, Any]:
    """Same, for a bare :class:`repro.serving.scheduler.Scheduler`."""
    return {
        "chunk": scheduler._chunk_jit,
        "gather": scheduler._gather_jit,
        "reset": scheduler._reset_jit,
        "export": scheduler._export_jit,
        "import": scheduler._import_jit,
    }


class RetraceSentinel:
    """Context manager asserting a compile budget for a traced region."""

    def __init__(self, jits: Dict[str, Any],
                 budget: Union[int, Dict[str, int], None] = None,
                 exact: Optional[Dict[str, int]] = None):
        for name, fn in jits.items():
            if not hasattr(fn, "_cache_size"):
                raise TypeError(f"{name!r} is not a jitted function")
        self._jits = dict(jits)
        self._budget = budget
        self._exact = exact
        self._start: Dict[str, int] = {}
        #: compiles observed inside the region, per jit name (set on exit)
        self.compiles: Dict[str, int] = {}

    def __enter__(self) -> "RetraceSentinel":
        self._start = {n: f._cache_size() for n, f in self._jits.items()}
        return self

    def __exit__(self, *exc) -> None:
        self.compiles = {n: f._cache_size() - self._start[n]
                         for n, f in self._jits.items()}
        return None

    def findings(self) -> List[Finding]:
        """Budget violations as gating findings (empty = within budget)."""
        out: List[Finding] = []
        for name, n in self.compiles.items():
            if self._exact is not None and name in self._exact \
                    and n != self._exact[name]:
                out.append(Finding(
                    "error", "retrace",
                    f"expected exactly {self._exact[name]} compile(s), "
                    f"saw {n}", path=name))
                continue
            cap = (self._budget.get(name) if isinstance(self._budget, dict)
                   else self._budget)
            if cap is not None and n > cap:
                out.append(Finding(
                    "error", "retrace",
                    f"compile budget {cap} exceeded: {n} compiles "
                    "(a static argument is varying per call)", path=name))
        return out
