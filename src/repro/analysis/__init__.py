"""Tracing-time program auditor for the decode path.

The repo's hardest-won invariants — no full-arena pad/cast per decode step,
zero pool-sized ops in a CoW fork, one compile per (lanes, chunk) signature,
no host sync inside the decode loop — used to live as ad-hoc jaxpr walkers
inside two benchmarks, or nowhere.  This package makes them first-class
static checks that run on the *traced* program, before anything executes:

* :mod:`repro.analysis.jaxpr` — the shared jaxpr walker and traffic
  counters the benchmarks now import instead of reimplementing.
* :mod:`repro.analysis.passes` — :class:`Finding`, the pass registry, and
  the traffic-lint passes (arena pads/casts, KV upcasts, arena gathers,
  device-scalar outputs).
* :mod:`repro.analysis.retrace` — :class:`RetraceSentinel`, an exact
  compile-budget assertion over a set of named jits.
* :mod:`repro.analysis.hostsync` — :class:`HostSyncTripwire` and the
  :func:`sanctioned` region marker for deliberate device→host transfers.
* :mod:`repro.analysis.contracts` — KVPolicy lifecycle / tree-invariance /
  sharding-coverage checkers.
* ``python -m repro.analysis.audit`` — the CI gate: sweeps every registered
  policy × {ref, kernel} × {fixed, paged} and exits nonzero on any finding.

See docs/analysis.md for the pass catalog and how to add a pass.
"""
from repro.analysis.contracts import (check_policy_lifecycle,
                                      check_sharding_coverage,
                                      check_tree_invariance)
from repro.analysis.hostsync import HostSyncTripwire, sanctioned
from repro.analysis.jaxpr import (count_arena_copies, count_big_float_ops,
                                  walk_eqns)
from repro.analysis.passes import (Finding, LintContext, available_passes,
                                   register_pass, run_passes)
from repro.analysis.retrace import RetraceSentinel

__all__ = [
    "Finding", "LintContext", "register_pass", "available_passes",
    "run_passes", "walk_eqns", "count_arena_copies", "count_big_float_ops",
    "RetraceSentinel", "HostSyncTripwire", "sanctioned",
    "check_policy_lifecycle", "check_sharding_coverage",
    "check_tree_invariance",
]
