"""``python -m repro.analysis.audit`` — the static-analysis CI gate.

Sweeps every registered KV policy × {ref, kernel} × {fixed, paged}:

* traffic lints over the traced (and DCE'd) decode / fork / reclaim jaxprs
  (full-arena pads/casts, KV upcasts, whole-arena gathers in table mode,
  literal materialization, and — in kernel mode — the ``ref-fallback`` lint
  proving the decode program actually traced the Pallas kernel rather than
  the reference einsum);
* tree-state invariance of ``decode_step`` (leaf avals stable across steps);
* the KVPolicy lifecycle contract per policy;
* sharding-rule coverage of every decode-state leaf.

Then drives real mini scheduler traces under the retrace sentinel (exactly
one chunk compile) and the host-sync tripwire (no unsanctioned d2h): a
mixed-length width-2-fork trace, a forced preempt→resume round-trip, and a
generated burst workload through the SLO overload ladder (shed +
width-throttle coverage — the control projections are host arithmetic and
must add zero syncs/compiles).

Exits nonzero on any gating finding.  Intentional exceptions are declared
in ``ALLOW`` below with a comment — see docs/analysis.md for the policy.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts
from repro.analysis.hostsync import HostSyncTripwire
from repro.analysis.jaxpr import dce, trace_jaxpr
from repro.analysis.passes import Finding, LintContext, gating, run_passes
from repro.analysis.retrace import RetraceSentinel, engine_jits
from repro.configs import get_smoke
from repro.core import policy as policy_lib
from repro.core.config import KVPolicyConfig
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm

B, MAX_LEN, BLOCK_P = 2, 32, 8

#: rule allowlist per entry-point kind, with the reason it is intentional.
#: (An allowlisted rule is reported as info and does not gate.)
ALLOW: Dict[str, Tuple[str, ...]] = {
    "decode": (),
    "fork": (
        # the FIXED-arena fork legitimately gathers whole per-lane arenas
        # (that is the copy the paged CoW fork removes — the contrast is
        # pinned by benchmarks/paged_arena.py, so it must stay visible
        # there, not fail the audit here)
        "arena-pad",
    ),
    "reclaim": (),
    # preempt-snapshot/resume entry points: a preemption must move ONE
    # lane's state, never a full arena — any arena-sized pad/cast/gather in
    # these programs means eviction copies scale with the pool, not the lane
    "export": (),
    "import": (),
}

#: leaf names where the sharding fallback is an explicit decision.
SHARDING_ALLOW: Tuple[str, ...] = ()


def tiny_arch():
    arch = get_smoke("qwen-r1-1.5b")
    return dataclasses.replace(
        arch, dms=dataclasses.replace(arch.dms, window=4, target_cr=4.0,
                                      steps_per_cr_unit=5))


def policy_cfg(policy: str, paged: bool) -> KVPolicyConfig:
    return KVPolicyConfig(kind=policy, cr=2.0, window=4, block_p=BLOCK_P,
                          paged=paged, quest_page_size=BLOCK_P,
                          quest_top_pages=2)


def _arena_elems(state) -> int:
    """Smallest fully-provisioned KV arena in the state: any op at this many
    elements (or more) touches a whole arena."""
    sizes = []
    for pc in policy_lib.iter_policy_caches(state):
        pool = getattr(pc.cache, "pool", None)
        arr = pool.k if pool is not None else pc.cache.k
        sizes.append(int(np.prod(arr.shape)))
    return min(sizes)


def audit_combo(arch, params, policy: str, paged: bool,
                use_kernel: bool) -> List[Finding]:
    """Traffic lints for one (policy, layout, path) combo."""
    cfg = policy_cfg(policy, paged)
    state = tfm.init_decode_state(arch, B, MAX_LEN, cfg)
    elems = _arena_elems(state)
    tag = f"{policy}/{'paged' if paged else 'fixed'}" \
          f"/{'kernel' if use_kernel else 'ref'}"
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    act = jnp.ones((B,), bool)
    src = jnp.zeros((B,), jnp.int32)
    mask = jnp.zeros((B,), bool)

    findings: List[Finding] = []

    def lint(kind: str, fn, *args, table_mode: bool = False):
        jaxpr = dce(trace_jaxpr(fn, *args))
        ctx = LintContext(arena_elems=elems, table_mode=table_mode,
                          allow=ALLOW.get(kind, ()))
        findings.extend(run_passes(jaxpr, ctx, path=f"{tag}/{kind}"))

    lint("decode",
         lambda s, t, p, a: tfm.decode_step(params, t, s, arch, p,
                                            use_kernel=use_kernel, active=a),
         state, tok, pos, act, table_mode=use_kernel)
    if not use_kernel:       # fork/reclaim/tree checks are kernel-independent
        lint("fork", tfm.gather_lanes, state, src)
        fresh = tfm.init_decode_state(arch, B, MAX_LEN, cfg)
        lint("reclaim", tfm.reclaim_lanes, state, mask, fresh)
        # preempt snapshot/resume programs (scheduler._preempt/_resume)
        lane = jnp.zeros((), jnp.int32)
        lint("export", tfm.export_lane_state, state, lane)
        snap = jax.eval_shape(tfm.export_lane_state, state, lane)
        snap = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), snap)
        lint("import", tfm.import_lane_state, state, snap, lane)
        findings.extend(contracts.check_tree_invariance(
            lambda s: tfm.decode_step(params, tok, s, arch, pos,
                                      active=act)[1],
            state, path=f"{tag}/decode "))
    return findings


def audit_contracts(arch, policy: str, paged: bool) -> List[Finding]:
    cfg = policy_cfg(policy, paged)
    findings = contracts.check_policy_lifecycle(
        policy, arch, cfg, batch=B, max_len=MAX_LEN)
    mesh = make_local_mesh()
    state = jax.eval_shape(
        lambda: tfm.init_decode_state(arch, B, MAX_LEN, cfg))
    findings += contracts.check_sharding_coverage(
        state, mesh, B, arch, allow=SHARDING_ALLOW)
    return [dataclasses.replace(
        f, path=f"{policy}/{'paged' if paged else 'fixed'} {f.path}")
        for f in findings]


def audit_scheduler(arch, params, paged: bool) -> List[Finding]:
    """Drive a real mini trace under the retrace sentinel + host-sync
    tripwire: mixed prompt lengths, a width-2 fork, budget exhaustion."""
    from repro.serving.engine import Engine
    from repro.serving.scheduler import Request

    cfg = policy_cfg("dms", paged)
    eng = Engine(arch, params, cfg, chunk=4)
    sched = eng.scheduler(num_lanes=3, max_len=MAX_LEN)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 50, size=n).astype(np.int32)
               for n in (3, 7, 5)]
    sched.submit(Request(uid=0, prompt=prompts[0], max_new=4))
    sched.submit(Request(uid=1, prompt=prompts[1], max_new=3, width=2,
                         arrival=1))
    sched.submit(Request(uid=2, prompt=prompts[2], max_new=4, arrival=3))
    with RetraceSentinel(engine_jits(eng),
                         exact={"chunk": 1},
                         budget={"gather": 1, "reset": 1, "prefill": 0,
                                 "export": 0, "import": 0}) as sentinel, \
            HostSyncTripwire() as tripwire:
        results = sched.run()
    tag = f"scheduler/{'paged' if paged else 'fixed'}"
    findings = [dataclasses.replace(f, path=f"{tag}:{f.path}")
                for f in sentinel.findings() + tripwire.violations()]
    if len(results) != 3:
        findings.append(Finding("error", "scheduler",
                                f"expected 3 results, got {len(results)}",
                                path=tag))
    return findings


def audit_preempt(arch, params, paged: bool) -> List[Finding]:
    """Drive a forced preempt→resume round-trip under the retrace sentinel
    and host-sync tripwire: the snapshot/resume path must compile its
    export/import programs exactly once, never retrace the chunk fn, and
    read back device state only at sanctioned boundaries
    (``preempt-snapshot`` / ``pool-pressure`` / ``tick-boundary``)."""
    from repro.serving.engine import Engine
    from repro.serving.faults import Fault, FaultPlan
    from repro.serving.scheduler import Request

    cfg = policy_cfg("dms", paged)
    eng = Engine(arch, params, cfg, chunk=4)
    plan = FaultPlan([Fault("preempt", tick=1, lane=0)])
    sched = eng.scheduler(num_lanes=2, max_len=MAX_LEN, faults=plan)
    prompt = np.random.default_rng(1).integers(
        1, 50, size=7).astype(np.int32)
    sched.submit(Request(uid=0, prompt=prompt, max_new=4))
    with RetraceSentinel(engine_jits(eng),
                         exact={"chunk": 1},
                         budget={"gather": 0, "reset": 1, "prefill": 0,
                                 "export": 1, "import": 1}) as sentinel, \
            HostSyncTripwire() as tripwire:
        results = sched.run()
    tag = f"preempt/{'paged' if paged else 'fixed'}"
    findings = [dataclasses.replace(f, path=f"{tag}:{f.path}")
                for f in sentinel.findings() + tripwire.violations()]
    if (len(results) != 1 or results[0].status != "ok"
            or results[0].preempt_count != 1):
        findings.append(Finding(
            "error", "scheduler",
            f"expected 1 ok result with preempt_count=1, got "
            f"{[(r.status, r.preempt_count) for r in results]}", path=tag))
    return findings


def audit_slo(arch, params, paged: bool) -> List[Finding]:
    """Drive a generated burst workload through the SLO overload ladder
    under the retrace sentinel + host-sync tripwire: the shed and
    width-throttle projections are pure host arithmetic, so an overloaded
    controlled trace must compile the chunk fn exactly once and add ZERO
    device syncs beyond the sanctioned tick boundary."""
    from repro.serving import workload
    from repro.serving.engine import Engine
    from repro.serving.scheduler import SLOSpec

    cfg = policy_cfg("dms", paged)
    eng = Engine(arch, params, cfg, chunk=4)
    spec = workload.WorkloadSpec(
        vocab=50, max_len=MAX_LEN, prompt_len=(6, 10), max_new=(3, 6),
        widths=(1, 2), deadline=10)
    reqs = workload.burst_trace(0, 8, rate=2.0, on_ticks=3, off_ticks=5,
                                spec=spec)
    slo = SLOSpec(ttft_ticks=5, max_queue=4, min_width=1, cooldown_ticks=4)
    sched = eng.scheduler(num_lanes=2, max_len=MAX_LEN, slo=slo)
    for r in reqs:
        sched.submit(r)
    with RetraceSentinel(engine_jits(eng),
                         exact={"chunk": 1},
                         budget={"gather": 1, "reset": 1, "prefill": 0,
                                 "export": 0, "import": 0}) as sentinel, \
            HostSyncTripwire() as tripwire:
        results = sched.run()
    tag = f"slo/{'paged' if paged else 'fixed'}"
    findings = [dataclasses.replace(f, path=f"{tag}:{f.path}")
                for f in sentinel.findings() + tripwire.violations()]
    life = sched.lifecycle_stats()
    # the trace must actually exercise the ladder, or the sync/compile
    # guarantee above is vacuous
    if len(results) != len(reqs) or life["shed"] < 1 \
            or life["degraded"] < 1:
        findings.append(Finding(
            "error", "scheduler",
            f"SLO trace lost coverage: {len(results)}/{len(reqs)} results, "
            f"shed={life['shed']}, degraded={life['degraded']} "
            "(need >=1 each)", path=tag))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policies", default=None,
                    help="comma-separated subset (default: all registered)")
    ap.add_argument("--skip-scheduler", action="store_true",
                    help="jaxpr/contract passes only (no execution)")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-level (allowlisted) findings")
    args = ap.parse_args(argv)

    arch = tiny_arch()
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    policies = (tuple(args.policies.split(","))
                if args.policies else policy_lib.available_policies())

    findings: List[Finding] = []
    for policy in policies:
        for paged in (False, True):
            for use_kernel in (False, True):
                findings += audit_combo(arch, params, policy, paged,
                                        use_kernel)
            findings += audit_contracts(arch, policy, paged)
            print(f"  audited {policy}/{'paged' if paged else 'fixed'} "
                  f"(ref+kernel)", flush=True)
    if not args.skip_scheduler:
        for paged in (False, True):
            findings += audit_scheduler(arch, params, paged)
            print(f"  audited scheduler/{'paged' if paged else 'fixed'}",
                  flush=True)
            findings += audit_preempt(arch, params, paged)
            print(f"  audited preempt/{'paged' if paged else 'fixed'}",
                  flush=True)
            findings += audit_slo(arch, params, paged)
            print(f"  audited slo/{'paged' if paged else 'fixed'}",
                  flush=True)

    bad = gating(findings)
    shown = findings if args.verbose else bad
    for f in shown:
        print(f)
    n_info = sum(1 for f in findings if f.severity == "info")
    print(f"audit: {len(bad)} gating finding(s), {n_info} allowlisted, "
          f"{len(policies)} policies x {{ref,kernel}} x {{fixed,paged}}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
