"""Host-sync tripwire: flag device→host transfers inside a decode region.

A single stray ``np.asarray``/``.item()``/``jax.device_get`` inside the
decode loop serializes the host against the device every step — the classic
silent 10× serving regression.  The scheduler's design syncs the host
exactly once per *chunk* (the tick-boundary handoff of sampled tokens) and
the prefix cache demotes snapshots device→host lazily; everything else on
the decode path must stay on device.

Two pieces:

* :func:`sanctioned` — a zero-cost region marker wrapped around the code
  sites where a d2h transfer is *by design* (the scheduler's tick boundary,
  the prefix cache's lazy demotion).  Unarmed, it costs a list push/pop.
* :class:`HostSyncTripwire` — a context manager that, while armed, hooks
  ``np.asarray``/``np.array`` (on CPU, numpy reads jax arrays through the
  C buffer protocol, so the interception must happen at the numpy entry
  point — ``ArrayImpl.__array__`` alone would never fire), plus
  ``ArrayImpl.__array__``, ``ArrayImpl.item`` and ``jax.device_get``, and
  records every transfer with the innermost repo frame that caused it.
  Transfers inside a sanctioned region whose tag is in the allowlist are
  recorded as ``info``; everything else is a gating finding.
"""
from __future__ import annotations

import contextlib
import sys
from typing import List, Optional, Tuple

from repro.analysis.passes import Finding

#: sanctioned tags armed tripwires permit by default: the scheduler's
#: once-per-chunk host handoff, the prefix cache's lazy d2h demotion, the
#: scheduler's free-page readback when preemption is armed, and the
#: preempt-snapshot d2h (same funnel as prefix demotion).  The fault
#: injector's own readbacks tag as "fault-inject" and are deliberately NOT
#: allowed here: injection is a test-harness act, never a serving path.
DEFAULT_ALLOW = ("tick-boundary", "prefix-demote", "pool-pressure",
                 "preempt-snapshot")

_SANCTIONED: List[str] = []          # active sanctioned-region tag stack
_ACTIVE: List["HostSyncTripwire"] = []   # armed tripwire stack
_PATCHED: List[Tuple] = []           # (owner, name, original) for unpatching
_IN_EVENT = [False]                  # reentrancy guard (device_get → __array__)


@contextlib.contextmanager
def sanctioned(tag: str):
    """Mark a deliberate device→host transfer site (see DEFAULT_ALLOW)."""
    _SANCTIONED.append(tag)
    try:
        yield
    finally:
        _SANCTIONED.pop()


def _origin() -> str:
    """Innermost non-jax, non-analysis frame that triggered the transfer."""
    f = sys._getframe(2)
    fallback = ""
    while f is not None:
        fn = f.f_code.co_filename
        if "repro/analysis" not in fn and "/jax/" not in fn \
                and "/jax_" not in fn and "numpy" not in fn:
            loc = f"{fn.rsplit('/', 1)[-1]}:{f.f_code.co_name}:{f.f_lineno}"
            if "/repro/" in fn or "/src/" in fn:
                return loc
            if not fallback:
                fallback = loc
        f = f.f_back
    return fallback or "<unknown>"


def _record(kind: str) -> None:
    if not _ACTIVE or _IN_EVENT[0]:
        return
    _IN_EVENT[0] = True
    try:
        tag = _SANCTIONED[-1] if _SANCTIONED else None
        origin = _origin()
        for tw in _ACTIVE:
            tw._observe(kind, tag, origin)
    finally:
        _IN_EVENT[0] = False


def _patch() -> None:
    import jax
    import numpy as np
    from jax._src.array import ArrayImpl

    orig_array = ArrayImpl.__array__
    orig_item = ArrayImpl.item
    orig_get = jax.device_get
    orig_np_asarray = np.asarray
    orig_np_array = np.array

    def traced_array(self, *a, **kw):
        _record("__array__")
        return orig_array(self, *a, **kw)

    def _np_wrapper(kind, orig):
        def wrapped(a=None, *rest, **kw):
            if isinstance(a, ArrayImpl) or (
                    isinstance(a, (list, tuple))
                    and any(isinstance(x, ArrayImpl) for x in a)):
                _record(kind)
            return orig(a, *rest, **kw)
        return wrapped

    def traced_item(self, *a, **kw):
        _record(".item()")
        _IN_EVENT[0] = True          # item() may sync via __array__ inside
        try:
            return orig_item(self, *a, **kw)
        finally:
            _IN_EVENT[0] = False

    def traced_get(x):
        _record("device_get")
        _IN_EVENT[0] = True          # attribute the inner __array__ to us
        try:
            return orig_get(x)
        finally:
            _IN_EVENT[0] = False

    ArrayImpl.__array__ = traced_array
    ArrayImpl.item = traced_item
    jax.device_get = traced_get
    np.asarray = _np_wrapper("np.asarray", orig_np_asarray)
    np.array = _np_wrapper("np.array", orig_np_array)
    _PATCHED.extend([(ArrayImpl, "__array__", orig_array),
                     (ArrayImpl, "item", orig_item),
                     (jax, "device_get", orig_get),
                     (np, "asarray", orig_np_asarray),
                     (np, "array", orig_np_array)])


def _unpatch() -> None:
    while _PATCHED:
        owner, name, orig = _PATCHED.pop()
        setattr(owner, name, orig)


class HostSyncTripwire:
    """Arm the d2h hooks for a region that must not sync the host."""

    def __init__(self, allow: Tuple[str, ...] = DEFAULT_ALLOW):
        self.allow = tuple(allow)
        #: every observed transfer: (kind, sanctioned tag or None, origin)
        self.events: List[Tuple[str, Optional[str], str]] = []

    def _observe(self, kind: str, tag: Optional[str], origin: str) -> None:
        self.events.append((kind, tag, origin))

    def __enter__(self) -> "HostSyncTripwire":
        if not _ACTIVE:
            _patch()
        _ACTIVE.append(self)
        return self

    def __exit__(self, *exc) -> None:
        _ACTIVE.remove(self)
        if not _ACTIVE:
            _unpatch()
        return None

    def findings(self) -> List[Finding]:
        out: List[Finding] = []
        for kind, tag, origin in self.events:
            if tag in self.allow:
                out.append(Finding("info", "host-sync",
                                   f"sanctioned d2h ({tag}) via {kind}",
                                   path=origin))
            else:
                where = f"sanctioned({tag})" if tag else "unsanctioned"
                out.append(Finding("error", "host-sync",
                                   f"{where} device→host transfer via {kind} "
                                   "inside a decode region",
                                   path=origin))
        return out

    def violations(self) -> List[Finding]:
        return [f for f in self.findings() if f.severity == "error"]
