"""Property tests: random scheduler traces vs the solo-run oracle.

For ANY trace of requests (mixed prompt lengths, hyperscale widths, EOS
positions, submit ticks) the continuous-batching scheduler must:

* complete every request (no starvation, no deadlock, no lost lanes),
* conserve the lane arena (after the run every lane is idle, unowned and
  reset — nothing leaks across the trace),
* meter every request EXACTLY as a solo run of that request on a fresh
  arena would (per-lane independence: co-residents never pollute each
  other's tokens or budget axes), so per-request meters sum to the
  lockstep oracle's totals.

The checker is plain code shared by two drivers: a seeded deterministic
test (always runs, also under the no-hypothesis shim) and a hypothesis
``@given`` fuzzer (runs when hypothesis is installed; degrades to a skip
via ``tests/_hypothesis_compat``).

The chaos half of the file turns the same oracle discipline on the failure
path: random :class:`~repro.serving.faults.FaultPlan` schedules (pool
shrinkage, CoW storms, NaN logits, clock stalls, forced preemptions) against
random traces, on both fixed and paged arenas, asserting the four serving
robustness invariants — termination, lane+pool conservation
(``ref == recount(phys) + ghost``), a definite status per request, and fault
isolation (every ``ok`` request is token-equal to its solo oracle, which
also makes preempt→resume round-trips bitwise).
"""
import dataclasses

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke
from repro.core import block_pool, policy as policy_lib
from repro.core.config import KVPolicyConfig
from repro.core.policy import available_policies
from repro.models import transformer as tfm
from repro.serving.engine import Engine
from repro.serving.faults import Fault, FaultPlan
from repro.serving.scheduler import Request

NUM_LANES = 3
MAX_LEN = 24
CHUNK = 4

_CTX = {}


def _prime(arch, params) -> None:
    """Bind the module's shared engine to the session tiny model.  One engine
    for every example: the chunk/reset/gather jits compile once per (lanes,
    chunk) and are shared across all trace and oracle runs."""
    if "eng" not in _CTX:
        _CTX["arch"] = arch
        _CTX["params"] = params
        _CTX["eng"] = Engine(arch, params,
                             KVPolicyConfig(kind="dms", cr=2.0,
                                            window=arch.dms.window),
                             chunk=CHUNK)


def _engine() -> Engine:
    if "eng" not in _CTX:
        # fuzz driver ran without the seeded tests (it cannot take pytest
        # fixtures under the no-hypothesis shim): build the model directly
        arch = get_smoke("qwen-r1-1.5b")
        arch = dataclasses.replace(
            arch, dms=dataclasses.replace(arch.dms, window=4, target_cr=4.0))
        _prime(arch, tfm.init_model(jax.random.PRNGKey(0), arch))
    return _CTX["eng"]


def _prompt(n, seed):
    vocab = _CTX["arch"].vocab_size
    return np.random.default_rng(seed).integers(
        3, vocab, size=(n,)).astype(np.int32)


def _solo(eng, req: Request):
    """The oracle: the same request alone on a fresh width-sized arena."""
    sched = eng.scheduler(num_lanes=req.width, max_len=MAX_LEN)
    sched.submit(dataclasses.replace(req, arrival=0))
    return sched.run()[0]


def check_trace(spec):
    """spec: list of (plen, width, max_new, arrival, eos_pos|None) tuples."""
    eng = _engine()
    reqs = []
    for i, (plen, width, max_new, arrival, eos_pos) in enumerate(spec):
        req = Request(uid=i, prompt=_prompt(plen, seed=1000 + i),
                      max_new=max_new, width=width, arrival=arrival)
        if eos_pos is not None:
            # pick a token the request actually emits, so EOS early-exit
            # genuinely triggers (same eos in oracle and trace)
            free = _solo(eng, req)
            chain = free.tokens[0][:int(free.lengths[0])]
            req = dataclasses.replace(
                req, eos_id=int(chain[min(eos_pos, len(chain) - 1)]))
        reqs.append(req)

    sched = eng.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN)
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}

    # 1. every request completes, within budget
    assert sorted(results) == list(range(len(reqs)))
    for r in reqs:
        got = results[r.uid]
        assert got.tokens.shape == (r.width, r.max_new)
        assert all(1 <= int(l) <= r.max_new for l in got.lengths)
        if r.eos_id is None:
            assert all(int(l) == r.max_new for l in got.lengths)

    # 2. lane accounting conserves the arena: every lane idle + reset
    assert not sched.queue and not sched.active_reqs
    assert all(o is None for o in sched.owner)
    assert not sched.decoding.any() and not sched.finished.any()
    assert (sched.pos == 0).all()
    for pc in policy_lib.iter_policy_caches(sched.state):
        live = np.asarray(pc.cache.retained_tokens())
        assert (live == 0).all(), "reclaimed lane arena not empty"

    # 3. per-request meters + tokens == the solo oracle, exactly
    tot = {"pre": 0.0, "dec": 0.0, "gen": 0}
    oracle_tot = {"pre": 0.0, "dec": 0.0, "gen": 0}
    for r in reqs:
        got, ref = results[r.uid], _solo(eng, r)
        np.testing.assert_array_equal(got.tokens, ref.tokens, err_msg=str(r.uid))
        np.testing.assert_array_equal(got.lengths, ref.lengths)
        assert got.prefill_meter.kv_reads == pytest.approx(
            ref.prefill_meter.kv_reads), r.uid
        assert got.decode_meter.kv_reads == pytest.approx(
            ref.decode_meter.kv_reads), r.uid
        assert got.decode_meter.generated_tokens == \
            ref.decode_meter.generated_tokens, r.uid
        tot["pre"] += got.prefill_meter.kv_reads
        tot["dec"] += got.decode_meter.kv_reads
        tot["gen"] += got.meter.generated_tokens
        oracle_tot["pre"] += ref.prefill_meter.kv_reads
        oracle_tot["dec"] += ref.decode_meter.kv_reads
        oracle_tot["gen"] += ref.meter.generated_tokens
    assert tot == pytest.approx(oracle_tot)


def _spec_from_rng(rng, n):
    spec = []
    for _ in range(n):
        max_new = int(rng.integers(1, 7))
        plen = int(rng.integers(1, MAX_LEN - max_new))
        width = int(rng.integers(1, NUM_LANES + 1))
        arrival = int(rng.integers(0, 7))
        eos_pos = int(rng.integers(0, max_new)) if rng.random() < 0.5 else None
        spec.append((plen, width, max_new, arrival, eos_pos))
    return spec


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_trace_matches_solo_oracle_seeded(seed, tiny_arch, tiny_params):
    """Deterministic driver — runs in every environment, shim included.
    Reuses the session tiny model from conftest (the fuzz driver below can't
    take fixtures under the shim, so it primes itself only when run alone)."""
    _prime(tiny_arch, tiny_params)
    rng = np.random.default_rng(seed)
    check_trace(_spec_from_rng(rng, n=int(rng.integers(2, 5))))


_req_strategy = st.tuples(
    st.integers(min_value=1, max_value=16),       # plen (<= MAX_LEN - max_new)
    st.integers(min_value=1, max_value=NUM_LANES),  # width
    st.integers(min_value=1, max_value=6),        # max_new
    st.integers(min_value=0, max_value=6),        # arrival tick
    st.one_of(st.none(), st.integers(min_value=0, max_value=5)),  # eos pos
)


@settings(max_examples=10, deadline=None)
@given(st.lists(_req_strategy, min_size=1, max_size=5))
def test_random_trace_matches_solo_oracle_fuzzed(spec):
    """Hypothesis driver: same checker, adversarially-shrunk traces."""
    spec = [(min(plen, MAX_LEN - max_new - 1) or 1, width, max_new, arr, eos)
            for (plen, width, max_new, arr, eos) in spec]
    check_trace(spec)


# -- chaos: fault injection vs the robustness invariants ---------------------

# policy sample for the chaos fuzz (the bitwise preempt→resume sweep below
# covers the full registry); engines are cached per (kind, paged) so every
# seed/fuzz example reuses the compiled chunk/export/import jits
CHAOS_POLICIES = ("dms", "tova", "quest")
POOL_BLOCKS = 12
_CHAOS = {}


def _chaos_engine(kind, paged):
    key = (kind, paged)
    if key not in _CHAOS:
        _engine()                      # make sure _CTX carries arch + params
        arch = _CTX["arch"]
        cfg = KVPolicyConfig(kind=kind, cr=2.0, budget=12,
                             window=arch.dms.window, quest_page_size=4,
                             paged=paged, block_p=8,
                             pool_blocks=POOL_BLOCKS if paged else None)
        _CHAOS[key] = Engine(arch, _CTX["params"], cfg, chunk=CHUNK)
    return _CHAOS[key]


def _solo_chaos(eng, req: Request):
    """Fault-free oracle on the same engine and lane count (shared jits)."""
    sched = eng.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN)
    sched.submit(dataclasses.replace(req, arrival=0, deadline=None))
    return sched.run()[0]


def check_chaos(seed, paged, kind):
    """One chaos episode: a seeded request trace under a seeded FaultPlan."""
    eng = _chaos_engine(kind, paged)
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    prompt=_prompt(int(rng.integers(4, 13)),
                                   seed=2000 + 10 * seed + i),
                    max_new=int(rng.integers(3, 8)),
                    arrival=int(rng.integers(0, 5)), deadline=40)
            for i in range(3)]
    plan = FaultPlan.random(seed, lanes=NUM_LANES, paged=paged)

    sched = eng.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN, faults=plan)
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}   # invariant 1: terminates

    # invariant 2: exactly one result per request, with a definite status
    assert sorted(results) == [0, 1, 2]
    for uid, got in results.items():
        assert got.status in ("ok", "failed", "timeout"), (uid, got.status)

    # invariant 3: conservation — lanes idle + reset, pool refcounts exactly
    # the recount of live mappings plus the injector's ghost ledger, and the
    # exhausted latch never survives the run
    assert not sched.queue and not sched.active_reqs
    assert all(o is None for o in sched.owner)
    assert not sched.decoding.any() and not sched.finished.any()
    pooled = [pc for pc in policy_lib.iter_policy_caches(sched.state)
              if getattr(pc.cache, "pool", None) is not None]
    for idx, pc in enumerate(pooled):
        pool = pc.cache.pool
        want = np.asarray(block_pool.recount(pc.cache.phys,
                                             pool.ref.shape[-1]))
        ghost = plan.ghosts.get(idx)
        if ghost is not None:
            want = want + ghost
        np.testing.assert_array_equal(np.asarray(pool.ref), want,
                                      err_msg=f"pool {idx} refcount leak")
        assert not bool(np.asarray(pool.exhausted).any())

    # invariant 4: fault isolation — every ok request (preempted or not) is
    # bitwise what its solo run produces; a token from a poisoned chunk or a
    # dropped-write lane must never have reached a result
    for r in reqs:
        got = results[r.uid]
        if got.status != "ok":
            continue
        ref = _solo_chaos(eng, r)
        np.testing.assert_array_equal(got.tokens, ref.tokens,
                                      err_msg=f"uid {r.uid} diverged")
        np.testing.assert_array_equal(got.lengths, ref.lengths)


@pytest.mark.parametrize("paged", [False, True], ids=["fixed", "paged"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_faults_keep_invariants_seeded(seed, paged, tiny_arch,
                                             tiny_params):
    """Deterministic chaos driver — runs in every environment."""
    _prime(tiny_arch, tiny_params)
    check_chaos(seed, paged, CHAOS_POLICIES[seed % len(CHAOS_POLICIES)])


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6), st.booleans(),
       st.sampled_from(CHAOS_POLICIES))
def test_chaos_faults_keep_invariants_fuzzed(seed, paged, kind):
    """Hypothesis chaos driver: same invariants, adversarial seeds."""
    check_chaos(seed, paged, kind)


# -- bitwise preempt→resume, full policy registry ----------------------------


@pytest.mark.parametrize("paged", [False, True], ids=["fixed", "paged"])
@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_preempt_resume_bitwise_per_policy(kind, paged, tiny_arch,
                                           tiny_params):
    """Acceptance: for every registry policy, on fixed and paged arenas, a
    request force-preempted mid-prefill (tick 1) AND mid-decode (tick 5)
    resumes from its host snapshot and finishes bitwise-identical to an
    undisturbed run — zero re-prefill, greedy decode carries no RNG."""
    _prime(tiny_arch, tiny_params)
    eng = _chaos_engine(kind, paged)
    req = Request(uid=0, prompt=_prompt(9, seed=77), max_new=6)
    oracle = _solo_chaos(eng, req)

    plan = FaultPlan([Fault("preempt", tick=1, lane=0),
                      Fault("preempt", tick=5, lane=0)])
    sched = eng.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN, faults=plan)
    sched.submit(req)
    got = sched.run()[0]

    assert got.status == "ok"
    assert got.preempt_count == 2, plan.log
    np.testing.assert_array_equal(got.tokens, oracle.tokens, err_msg=kind)
    np.testing.assert_array_equal(got.lengths, oracle.lengths)
    assert sched.lifecycle_stats() == {
        "preemptions": 2, "resumes": 2, "completed": 1,
        "failures": 0, "timeouts": 0, "rejected": 0, "shed": 0,
        "degraded": 0}


@pytest.mark.parametrize("paged", [False, True], ids=["fixed", "paged"])
def test_preempt_resume_bitwise_hyperscale_width(paged, tiny_arch,
                                                 tiny_params):
    """A width-2 hyperscale request preempts as a unit (both lanes snapshot,
    both resume) and still matches its undisturbed fork bitwise."""
    _prime(tiny_arch, tiny_params)
    eng = _chaos_engine("dms", paged)
    req = Request(uid=0, prompt=_prompt(8, seed=78), max_new=5, width=2)
    oracle = _solo_chaos(eng, req)

    plan = FaultPlan([Fault("preempt", tick=2, lane=0)])
    sched = eng.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN, faults=plan)
    sched.submit(req)
    got = sched.run()[0]

    assert got.status == "ok" and got.preempt_count == 1
    np.testing.assert_array_equal(got.tokens, oracle.tokens)
    np.testing.assert_array_equal(got.lengths, oracle.lengths)


# -- chaos under bursty overload + SLO control --------------------------------


def check_burst_chaos(seed, paged):
    """Fault isolation when everything lands at once: a bursty workload
    trace (repro.serving.workload), a FaultPlan drawn *near the burst
    arrivals* (the ``arrivals`` hook — stalls/preempts overlap in-flight
    requests instead of idle ticks), AND the SLO ladder armed (shed +
    width-throttle live alongside the fault injector).  Invariants: the run
    terminates, every request has a definite status (now including
    ``rejected``), lanes conserve, shed requests burned zero prefill, and
    every ``ok`` request — degraded or not — is token-equal to its solo
    oracle at the width it was actually served."""
    from repro.serving import workload
    from repro.serving.scheduler import SLOSpec

    eng = _chaos_engine("dms", paged)
    spec = workload.WorkloadSpec(
        vocab=_CTX["arch"].vocab_size, max_len=MAX_LEN - 4,
        prompt_len=(4, 10), max_new=(3, 6), widths=(1, 2), deadline=40)
    reqs = workload.burst_trace(seed, 4, rate=1.5, on_ticks=3, off_ticks=4,
                                spec=spec)
    plan = FaultPlan.random(seed, lanes=NUM_LANES, paged=paged,
                            arrivals=[r.arrival for r in reqs])
    slo = SLOSpec(ttft_ticks=20, min_width=1, cooldown_ticks=3)

    sched = eng.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN, faults=plan,
                          slo=slo)
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}   # terminates

    assert sorted(results) == [r.uid for r in reqs]
    for uid, got in results.items():
        assert got.status in ("ok", "failed", "timeout", "rejected"), \
            (uid, got.status)
        if got.status == "rejected":
            assert got.admitted_tick == -1
            assert got.prefill_meter.kv_reads == 0

    assert not sched.queue and not sched.active_reqs
    assert all(o is None for o in sched.owner)

    for r in reqs:
        got = results[r.uid]
        if got.status != "ok":
            continue
        served_w = len(got.lengths)
        assert got.degraded == (served_w < r.width)
        ref = _solo_chaos(eng, dataclasses.replace(r, width=served_w))
        np.testing.assert_array_equal(got.tokens, ref.tokens,
                                      err_msg=f"uid {r.uid} diverged")
        np.testing.assert_array_equal(got.lengths, ref.lengths)


@pytest.mark.parametrize("paged", [False, True], ids=["fixed", "paged"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_burst_chaos_with_slo_keeps_isolation_seeded(seed, paged, tiny_arch,
                                                     tiny_params):
    """Deterministic burst-chaos driver — runs in every environment."""
    _prime(tiny_arch, tiny_params)
    check_burst_chaos(seed, paged)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6), st.booleans())
def test_burst_chaos_with_slo_keeps_isolation_fuzzed(seed, paged):
    """Hypothesis burst-chaos driver: same invariants, adversarial seeds."""
    check_burst_chaos(seed, paged)


def test_faultplan_random_arrivals_hook_targets_bursts():
    """The ``arrivals`` hook: every drawn fault tick lands within the jitter
    window of some arrival, and omitting the hook replays the legacy
    uniform draw bit-identically (same seed, same plan)."""
    from repro.serving import workload

    arr = workload.burst_arrivals(3, 20, rate=2.0, on_ticks=3, off_ticks=9)
    for seed in range(5):
        plan = FaultPlan.random(seed, lanes=2, arrivals=arr)
        for f in plan.faults:
            assert any(a <= f.tick <= a + 2 for a in arr) or f.tick == 1, \
                (f.kind, f.tick)
        a = FaultPlan.random(seed, lanes=2)
        b = FaultPlan.random(seed, lanes=2)
        assert [(f.kind, f.tick, f.lane, f.blocks, f.duration, f.release)
                for f in a.faults] == \
               [(f.kind, f.tick, f.lane, f.blocks, f.duration, f.release)
                for f in b.faults]
