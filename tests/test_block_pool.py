"""Paged KV block pool: allocator invariants + pooled/fixed bitwise parity.

Three contracts are pinned here (docs/serving.md, "Paged KV block pool"):

* **bitwise parity** — for all 9 registry policies, the pool-backed cache
  driven through identical decode / fork / reclaim / prefix-import traces
  produces bit-identical attention outputs to the fixed-arena path, on both
  the masked-softmax reference and the block-table kernel.  Garbage in
  unmapped pages is masked to exact zeros, and the shared BlockTable gives
  both layouts the same accumulation order.
* **allocator invariants** — under random step/fork/reclaim/export-import
  traces (seeded driver always; hypothesis fuzz when installed):
  refcounts == mapping multiplicity, block conservation
  (allocated + free == pool), no page double-mapped within a (lane, head),
  a logical block is mapped iff it holds a live slot, incremental tables
  only index owned pages, and CoW refcounts reach zero exactly at reclaim.
* **byte-budget admission** — a pool sized for one worst-case lane forces
  the scheduler to serialize two requests (second admission waits for the
  first lane's pages), and both still complete token-exact.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import block_pool, policy as policy_lib
from repro.core.config import KVPolicyConfig
from repro.core.kv_cache import SlotDMSCache, pack_dense
from repro.models.attention import _masked_decode

BP = 8

ALL_POLICIES = ["vanilla", "window", "dms", "dms_masked", "tova", "h2o",
                "quest", "dmc", "keyformer"]


# -- paired fixed/pooled drivers --------------------------------------------


def _pair_caches(tiny_arch, kind, batch=2, max_len=40, dtype="float32"):
    """One policy cache in each layout, identically configured."""
    arch = dataclasses.replace(tiny_arch, dtype=dtype)
    base = dict(kind=kind, cr=2.0, window=arch.dms.window, block_p=BP,
                quest_page_size=BP)
    pc_f = policy_lib.init_policy_cache(arch, batch, max_len,
                                        KVPolicyConfig(**base))
    pc_p = policy_lib.init_policy_cache(arch, batch, max_len,
                                        KVPolicyConfig(**base, paged=True))
    pol = policy_lib.get_policy(pc_f.policy)
    assert pc_p.cache.pool is not None, kind
    return arch, pol, pc_f.cache, pc_p.cache


def _step_pair(pol, arch, cf, cp, key, i, batch=2):
    """Advance both layouts one decode token with the SAME random stream."""
    a = arch.attn
    dt = jnp.dtype(arch.dtype)
    key, k1, k2, k3, k4 = jax.random.split(key, 5)
    q = jax.random.normal(k1, (batch, 1, a.num_heads, a.head_dim), dt)
    k_new = jax.random.normal(k2, (batch, a.num_kv_heads, 1, a.head_dim), dt)
    v_new = jax.random.normal(k3, (batch, a.num_kv_heads, 1, a.head_dim), dt)
    aux = {"alpha_bin": jax.random.bernoulli(k4, 0.5, (batch, a.num_kv_heads)),
           "pos_t": jnp.full((batch,), i, jnp.int32),
           "attn_cfg": a, "arch": arch, "dtype": dt}
    cf, sf = pol.decode_update(cf, q, k_new, v_new, dict(aux))
    cp, sp = pol.decode_update(cp, q, k_new, v_new, dict(aux))
    if sf.needs_weights:
        w = jax.random.uniform(k4, sf.visible.shape, jnp.float32)
        cf = pol.post_attend(cf, jnp.where(sf.visible, w, 0.0))
        cp = pol.post_attend(cp, jnp.where(sp.visible, w, 0.0))
    return key, cf, cp, sf, sp, q


def _assert_spec_parity(sf, sp, q, acfg):
    """Pooled attention output must be BITWISE equal to fixed-arena, on both
    the reference and the kernel path (dead slots mask to exact 0.0, same
    table order => same accumulation order)."""
    np.testing.assert_array_equal(np.asarray(sf.visible),
                                  np.asarray(sp.visible))
    for use_kernel in (False, True):
        of, _, _ = _masked_decode(q, sf, None, acfg, use_kernel=use_kernel)
        op, _, _ = _masked_decode(q, sp, None, acfg, use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(of), np.asarray(op),
                                      err_msg=f"use_kernel={use_kernel}")


# -- per-policy bitwise parity: decode / fork / reclaim ----------------------


@pytest.mark.parametrize("kind", ALL_POLICIES)
def test_pooled_decode_bitwise_parity(tiny_arch, kind):
    arch, pol, cf, cp = _pair_caches(tiny_arch, kind)
    key = jax.random.PRNGKey(21)
    for i in range(18):
        key, cf, cp, sf, sp, q = _step_pair(pol, arch, cf, cp, key, i)
        if i in (8, 17):
            _assert_spec_parity(sf, sp, q, arch.attn)


@pytest.mark.parametrize("kind", ALL_POLICIES)
def test_pooled_fork_reclaim_bitwise_parity(tiny_arch, kind):
    arch, pol, cf, cp = _pair_caches(tiny_arch, kind)
    key = jax.random.PRNGKey(33)
    for i in range(10):
        key, cf, cp, sf, sp, q = _step_pair(pol, arch, cf, cp, key, i)
    # width-2 shared-prefill fork: both lanes continue from lane 0.  The
    # pooled fork shares pages (CoW); divergent steps afterwards must still
    # match the fixed fork bit for bit.
    src = jnp.zeros((2,), jnp.int32)
    cf = pol.gather_cache(cf, src, axis=0)
    cp = pol.gather_cache(cp, src, axis=0)
    assert int(np.asarray(jnp.sum(cp.pool.ref > 1))) > 0, \
        "fork should leave shared (ref>1) pages"
    for i in range(10, 16):
        key, cf, cp, sf, sp, q = _step_pair(pol, arch, cf, cp, key, i)
    _assert_spec_parity(sf, sp, q, arch.attn)
    # reclaim lane 1 (EOS) against a pristine cache, keep decoding lane 0
    _, _, fresh_f, fresh_p = _pair_caches(tiny_arch, kind)
    mask = jnp.asarray([False, True])
    cf = pol.reclaim_cache(cf, mask, fresh_f)
    cp = pol.reclaim_cache(cp, mask, fresh_p)
    for i in range(16, 20):
        key, cf, cp, sf, sp, q = _step_pair(pol, arch, cf, cp, key, i)
    _assert_spec_parity(sf, sp, q, arch.attn)
    # full reclaim: every page returns to the free list
    _, _, fresh_f, fresh_p = _pair_caches(tiny_arch, kind)
    cp = pol.reclaim_cache(cp, jnp.ones((2,), bool), fresh_p)
    assert int(np.asarray(cp.pool.ref).sum()) == 0, kind
    assert int(np.asarray(cp.phys).max()) < 0, kind


@pytest.mark.parametrize("kind", ALL_POLICIES)
def test_pooled_prefix_roundtrip_bitwise_parity(tiny_arch, kind):
    arch, pol, cf, cp = _pair_caches(tiny_arch, kind)
    key = jax.random.PRNGKey(7)
    for i in range(12):
        key, cf, cp, sf, sp, q = _step_pair(pol, arch, cf, cp, key, i)
    snap_f = pol.export_prefix(cf, 0, axis=0)
    snap_p = pol.export_prefix(cp, 0, axis=0)
    # pooled exports densify to the SAME snapshot format the fixed path
    # produces (the prefix cache stores one layout)...
    assert (jax.tree_util.tree_structure(snap_f)
            == jax.tree_util.tree_structure(snap_p)), kind
    # ...and agree bit-for-bit on every live slot (fixed snapshots keep
    # stale bytes in dead slots; pooled never materialized them)
    vm = np.asarray(jnp.broadcast_to(snap_f.valid_mask(),
                                     snap_f.k.shape[:3]))[..., None]
    np.testing.assert_array_equal(np.asarray(snap_f.valid_mask()),
                                  np.asarray(snap_p.valid_mask()))
    for leaf_f, leaf_p in ((snap_f.k, snap_p.k), (snap_f.v, snap_p.v)):
        np.testing.assert_array_equal(
            np.where(vm, np.asarray(leaf_f), 0),
            np.where(vm, np.asarray(leaf_p), 0), err_msg=kind)
    # import into a pristine pair and keep decoding: still bitwise-equal
    _, _, nf, npc = _pair_caches(tiny_arch, kind)
    nf = pol.import_prefix(nf, snap_f, 1, axis=0)
    npc = pol.import_prefix(npc, snap_p, 1, axis=0)
    for i in range(12, 16):
        key, nf, npc, sf, sp, q = _step_pair(pol, arch, nf, npc, key, i)
    _assert_spec_parity(sf, sp, q, arch.attn)


def test_pack_dense_matches_fixed_arena(tiny_arch):
    """prefill import path: packing a warm fixed arena into the pool keeps
    every live slot and maps exactly the live blocks."""
    arch, pol, cf, _ = _pair_caches(tiny_arch, "dms")
    key = jax.random.PRNGKey(5)
    for i in range(14):
        key, cf, cf, _, _, _ = _step_pair(pol, arch, cf, cf, key, i)
    packed = pack_dense(cf)
    assert packed.pool is not None
    np.testing.assert_array_equal(np.asarray(packed.phys >= 0),
                                  np.asarray(packed.blocks.count > 0))
    vm = np.asarray(jnp.broadcast_to(cf.valid_mask(),
                                     cf.k.shape[:3]))[..., None]
    dk, dv = block_pool.dense_kv(packed.pool, packed.phys)
    np.testing.assert_array_equal(np.where(vm, np.asarray(cf.k), 0),
                                  np.where(vm, np.asarray(dk), 0))
    np.testing.assert_array_equal(np.where(vm, np.asarray(cf.v), 0),
                                  np.where(vm, np.asarray(dv), 0))
    _check_pool_invariants(packed)


# -- allocator invariants under random traces --------------------------------


def _check_pool_invariants(c, expect_live=True):
    pool, phys = c.pool, c.phys
    ref = np.asarray(pool.ref)
    ph = np.asarray(phys)
    # refcounts are exactly the multiplicity of the page in the mappings
    np.testing.assert_array_equal(
        ref, np.asarray(block_pool.recount(phys, pool.num_blocks)))
    # block conservation: every page is allocated xor free
    assert int((ref > 0).sum()) + int((ref == 0).sum()) == pool.num_blocks
    b, h, nb = ph.shape
    cnt = np.asarray(c.blocks.count)
    tbl = np.asarray(c.blocks.tbl)
    n = np.asarray(c.blocks.n)
    for bi in range(b):
        for hi in range(h):
            # no double-allocation: a (lane, head) never maps one page twice
            pages = ph[bi, hi][ph[bi, hi] >= 0]
            assert len(set(pages.tolist())) == len(pages), (bi, hi, pages)
            if expect_live:
                # incremental tables only index owned (mapped) pages
                for j in range(n[bi, hi]):
                    assert ph[bi, hi, tbl[bi, hi, j]] >= 0, (bi, hi, j)
    if expect_live:
        # on-demand lifetime: a logical block is mapped iff it holds >= 1
        # live slot — lane footprint IS its live blocks.  (Under pool
        # exhaustion a table block can legitimately lack a page: the write
        # was dropped, never corrupted — hence the gate.)
        np.testing.assert_array_equal(ph >= 0, cnt > 0)


def _lane_select0(mask, on_true, on_false):
    """transformer.lane_select's contract for a bare (batch-leading) cache:
    inactive lanes' per-lane leaves roll back wholesale, the shared
    BlockPool is kept unconditionally (its mutations were event-masked
    inside the step, so inactive lanes produced no events to roll back).
    A leaked event would surface as ref != recount(phys) right after."""
    def sel(a, b):
        if isinstance(a, block_pool.BlockPool):
            return a
        m = jnp.reshape(mask, (-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree_util.tree_map(
        sel, on_true, on_false,
        is_leaf=lambda x: isinstance(x, block_pool.BlockPool))


TRACE_OPS = ("step", "step", "step", "fork", "reclaim", "roundtrip")


def _run_trace(ops, seed):
    rng = np.random.default_rng(seed)
    b, h, slots, dh = 3, 2, 24, 8
    pol = policy_lib.get_policy("dms")

    def mk():
        return SlotDMSCache.init(b, h, slots, dh, window=3,
                                 dtype=jnp.float32, block_p=BP, paged=True)

    c = mk()
    for op in ops:
        if op == "step":
            k = jnp.asarray(rng.normal(size=(b, h, 1, dh)), jnp.float32)
            v = jnp.asarray(rng.normal(size=(b, h, 1, dh)), jnp.float32)
            alpha = jnp.asarray(rng.random((b, h)) < 0.6)
            active = jnp.asarray(rng.random(b) < 0.8)
            c = _lane_select0(active, c.step(k, v, alpha, active=active), c)
        elif op == "fork":
            src = jnp.asarray(rng.integers(0, b, size=b), jnp.int32)
            c = pol.gather_cache(c, src, axis=0)
        elif op == "reclaim":
            mask = jnp.asarray(rng.random(b) < 0.5)
            c = pol.reclaim_cache(c, mask, mk())
        else:  # roundtrip: export a lane, EOS it, import the prefix back
            lane = int(rng.integers(b))
            snap = pol.export_prefix(c, lane, axis=0)
            c = pol.reclaim_cache(c, jnp.asarray(np.arange(b) == lane), mk())
            c = pol.import_prefix(c, snap, lane, axis=0)
        _check_pool_invariants(c)
    assert not bool(np.asarray(c.pool.exhausted))
    # EOS everywhere: CoW refcounts reach zero exactly at reclaim
    c = pol.reclaim_cache(c, jnp.ones((b,), bool), mk())
    assert int(np.asarray(c.pool.ref).sum()) == 0
    assert int(np.asarray(c.phys).max()) < 0
    _check_pool_invariants(c)


def test_allocator_invariants_seeded_traces():
    rng = np.random.default_rng(42)
    for _ in range(4):
        ops = list(rng.choice(TRACE_OPS, size=20))
        _run_trace(ops, int(rng.integers(1 << 31)))


@given(st.lists(st.sampled_from(sorted(set(TRACE_OPS))), min_size=1,
                max_size=20),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_allocator_invariants_fuzz(ops, seed):
    _run_trace(list(ops), seed)


def test_pool_exhaustion_latches_without_corruption():
    """An undersized pool drops writes (never corrupts): the exhausted flag
    latches, refcounts stay consistent with the mappings."""
    b, h, dh = 2, 2, 8
    c = SlotDMSCache.init(b, h, 24, dh, window=3, dtype=jnp.float32,
                          block_p=BP, paged=True, pool_blocks=3)
    key = jax.random.PRNGKey(0)
    for _ in range(2 * BP):
        key, k1, k2 = jax.random.split(key, 3)
        c = c.step(jax.random.normal(k1, (b, h, 1, dh)),
                   jax.random.normal(k2, (b, h, 1, dh)),
                   jnp.zeros((b, h), bool))          # keep-all: fill fast
    assert bool(np.asarray(c.pool.exhausted))
    _check_pool_invariants(c, expect_live=False)
    assert int(np.asarray(c.pool.high_water)) <= 3


# -- observability -----------------------------------------------------------


def test_state_pool_stats(tiny_arch):
    arch, pol, cf, cp = _pair_caches(tiny_arch, "dms")
    key = jax.random.PRNGKey(11)
    for i in range(10):
        key, cf, cp, _, _, _ = _step_pair(pol, arch, cf, cp, key, i)
    pc = policy_lib.init_policy_cache(
        arch, 2, 40, KVPolicyConfig(kind="dms", cr=2.0, window=arch.dms.window,
                                    block_p=BP, paged=True))
    stats = policy_lib.state_pool_stats(dataclasses.replace(pc, cache=cp))
    assert stats is not None and stats["pools"] == 1
    for k in ("pool_blocks", "allocated_blocks", "free_blocks",
              "shared_blocks", "cow_copies", "high_water_blocks",
              "live_tokens", "mapped_entries", "fragmentation", "exhausted"):
        assert k in stats, k
    assert (stats["allocated_blocks"] + stats["free_blocks"]
            == stats["pool_blocks"])
    assert stats["live_tokens"] == int(np.asarray(cp.blocks.count).sum())
    assert 0.0 <= stats["fragmentation"] < 1.0
    assert not stats["exhausted"]
    # fixed-arena states expose no pool
    assert policy_lib.state_pool_stats(
        dataclasses.replace(pc, cache=cf)) is None


# -- serving end-to-end ------------------------------------------------------


def test_engine_paged_generate_token_parity(tiny_arch, tiny_params):
    """Full decode stack (scheduler, fork, kernels) over the pool is
    token-equal to the fixed-arena engine — reference and kernel paths."""
    from repro.serving.engine import Engine
    prompts = np.random.default_rng(9).integers(
        3, tiny_arch.vocab_size, size=(2, 11)).astype(np.int32)
    base = dict(kind="dms", cr=2.0, window=tiny_arch.dms.window)
    res_f = Engine(tiny_arch, tiny_params,
                   KVPolicyConfig(**base)).generate(prompts, 5)
    res_p = Engine(tiny_arch, tiny_params,
                   KVPolicyConfig(**base, paged=True)).generate(prompts, 5)
    res_fk = Engine(tiny_arch, tiny_params, KVPolicyConfig(**base),
                    use_kernel=True).generate(prompts, 5)
    res_pk = Engine(tiny_arch, tiny_params, KVPolicyConfig(**base, paged=True),
                    use_kernel=True).generate(prompts, 5)
    # layouts are compared within one attention implementation: kernel vs
    # reference are allclose-not-bitwise, so argmax may legitimately differ
    # BETWEEN implementations — but never between layouts
    np.testing.assert_array_equal(res_f.tokens, res_p.tokens)
    np.testing.assert_array_equal(res_fk.tokens, res_pk.tokens)


def test_scheduler_paged_fork_token_parity(tiny_arch, tiny_params):
    """Width-2 hyper-scaling request through the pooled scheduler: CoW fork
    plus divergent decode is token-equal to the fixed-arena scheduler."""
    from repro.serving.engine import Engine
    from repro.serving.scheduler import Request
    prompt = np.random.default_rng(4).integers(
        3, tiny_arch.vocab_size, size=(9,)).astype(np.int32)
    base = dict(kind="dms", cr=2.0, window=tiny_arch.dms.window)

    def run_one(policy):
        sched = Engine(tiny_arch, tiny_params, policy).scheduler(
            num_lanes=4, max_len=16)
        sched.submit(Request(uid=0, prompt=prompt, max_new=5, width=2))
        res = sched.run()[0]
        return res, sched

    res_f, _ = run_one(KVPolicyConfig(**base))
    res_p, sched_p = run_one(KVPolicyConfig(**base, paged=True))
    np.testing.assert_array_equal(res_f.tokens, res_p.tokens)
    stats = sched_p.pool_stats()
    assert stats is not None
    # every page was handed back when the request finished
    assert stats["allocated_blocks"] == 0
    assert stats["high_water_blocks"] > 0
    assert not stats["exhausted"]


def test_scheduler_pool_budget_serializes_admission(tiny_arch, tiny_params):
    """Admission is a real byte-budget decision: a pool sized for ONE
    worst-case lane makes two requests run back to back (never exhausting
    the pool), instead of being refused or corrupting each other."""
    from repro.serving.engine import Engine
    from repro.serving.scheduler import Request
    rng = np.random.default_rng(3)
    max_len = 12
    base = dict(kind="dms", cr=2.0, window=tiny_arch.dms.window)
    probe = Engine(tiny_arch, tiny_params, KVPolicyConfig(**base, paged=True))
    demand = probe.scheduler(num_lanes=2,
                             max_len=max_len)._lane_pool_demand(max_len)
    assert demand and all(d > 0 for d in demand)

    policy = KVPolicyConfig(**base, paged=True, pool_blocks=int(max(demand)))
    sched = Engine(tiny_arch, tiny_params, policy).scheduler(
        num_lanes=2, max_len=max_len)
    for i in range(2):
        prompt = rng.integers(3, tiny_arch.vocab_size,
                              size=(8,)).astype(np.int32)
        sched.submit(Request(uid=i, prompt=prompt, max_new=4))
    results = sched.run()
    assert len(results) == 2
    assert all(int(r.lengths.sum()) > 0 for r in results)
    ticks = sorted(r.admitted_tick for r in results)
    assert ticks[1] > ticks[0], "second request should wait for pool pages"
    stats = sched.pool_stats()
    assert stats is not None and not stats["exhausted"]
    assert stats["allocated_blocks"] == 0
