"""Unit + property tests for the DMS core (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import dms
from repro.core.config import DMSConfig


def test_alpha_logits_borrowed_neuron():
    """α logit = first neuron of the first query head of each group + bias."""
    b, t, hq, dh, hkv = 2, 5, 6, 4, 3
    q = jax.random.normal(jax.random.PRNGKey(0), (b, t, hq, dh))
    logits = dms.alpha_logits_from_q(q, hkv, bias=-5.0)
    assert logits.shape == (b, hkv, t)
    g = hq // hkv
    np.testing.assert_allclose(
        np.asarray(logits[0, 1, 3]), float(q[0, 3, g, 0]) - 5.0, rtol=1e-6)


def test_zero_borrowed_neuron_only_touches_first():
    b, t, hq, dh, hkv = 1, 3, 4, 4, 2
    q = jnp.ones((b, t, hq, dh))
    z = dms.zero_borrowed_neuron(q, hkv)
    z = np.asarray(z)
    assert (z[:, :, 0, 0] == 0).all() and (z[:, :, 2, 0] == 0).all()
    assert (z[:, :, 1, :] == 1).all() and (z[:, :, 0, 1:] == 1).all()


def test_neuron_phase1_scale():
    q = jnp.ones((1, 2, 2, 4))
    z = dms.zero_borrowed_neuron(q, 1, scale=0.25)
    assert float(z[0, 0, 0, 0]) == pytest.approx(0.25)


def test_gumbel_sigmoid_range_and_bias():
    logits = jnp.full((1000,), -5.0)
    a = dms.gumbel_sigmoid(logits, tau=0.3, rng=jax.random.PRNGKey(0))
    assert float(a.min()) >= 0.0 and float(a.max()) <= 1.0
    # b = -5 keeps alpha ~ 0 early in training (paper: prevents loss spikes)
    assert float(a.mean()) < 0.05


def test_gumbel_sigmoid_straight_through():
    logits = jnp.array([3.0, -3.0])
    a = dms.gumbel_sigmoid(logits, tau=0.3, rng=None, hard=True)
    np.testing.assert_array_equal(np.asarray(a), [1.0, 0.0])


def test_cr_schedule_linear_then_capped():
    cfg = DMSConfig(target_cr=8.0, steps_per_cr_unit=100)
    assert float(dms.cr_schedule(0, cfg)) == pytest.approx(1.0)
    assert float(dms.cr_schedule(100, cfg)) == pytest.approx(2.0)
    assert float(dms.cr_schedule(300, cfg)) == pytest.approx(4.0)
    assert float(dms.cr_schedule(700, cfg)) == pytest.approx(8.0)
    assert float(dms.cr_schedule(10_000, cfg)) == pytest.approx(8.0)
    # paper §5.3: CR4 by step 300, CR8 by step 700 with the 100-steps/unit rule


def test_aux_loss_one_sided():
    cfg = DMSConfig(target_cr=2.0, steps_per_cr_unit=1)
    # at step >= 1, target alpha = 0.5
    over = dms.aux_compression_loss(jnp.asarray(80.0), jnp.asarray(100.0), 10, cfg)
    under = dms.aux_compression_loss(jnp.asarray(20.0), jnp.asarray(100.0), 10, cfg)
    assert float(over) == 0.0            # compressing more than target: no penalty
    assert float(under) == pytest.approx(0.3)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(1, 20))
def test_mask_delay_semantics(w, t):
    """M[i,j] == log(1-α_j) iff i-j >= w; causal -inf above diagonal."""
    cfg = DMSConfig(window=w)
    alpha = jax.random.uniform(jax.random.PRNGKey(t), (1, 1, t), minval=0.0, maxval=0.9)
    m = np.asarray(dms.build_dms_mask(alpha, jnp.arange(t), jnp.arange(t), cfg))
    ls = np.log1p(-np.asarray(alpha))[0, 0]
    for i in range(t):
        for j in range(t):
            if j > i:
                assert m[0, 0, i, j] <= dms.NEG_INF / 2
            elif i - j >= w:
                assert m[0, 0, i, j] == pytest.approx(ls[j], rel=1e-5)
            else:
                assert m[0, 0, i, j] == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 8), st.integers(2, 24), st.integers(0, 100))
def test_retained_after_prefill_matches_stepwise(w, t, seed):
    """Prefill retained-set == replaying the same decisions step by step."""
    cfg = DMSConfig(window=w)
    alpha = np.asarray(
        jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (1, 1, t)))
    ret = np.asarray(dms.retained_after_prefill(jnp.asarray(alpha), t, cfg))[0, 0]
    # manual replay: token j is evicted when step j + w has been *written*
    live = np.ones(t, bool)
    for step in range(t):
        j = step - w
        if j >= 0 and alpha[0, 0, j]:
            live[j] = False
    np.testing.assert_array_equal(ret, live)


def test_immediate_eviction_mask():
    cfg = DMSConfig(window=8, immediate_eviction=True)
    alpha = jnp.full((1, 1, 6), 0.5)
    m = np.asarray(dms.build_dms_mask(alpha, jnp.arange(6), jnp.arange(6), cfg))
    assert m[0, 0, 3, 2] == pytest.approx(np.log1p(-0.5), rel=1e-5)  # i-j=1 already masked
    assert m[0, 0, 3, 3] == 0.0
