"""Cache semantics: slot-compacted DMS == masked reference; baselines."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import baselines
from repro.core.kv_cache import MaskedDMSCache, SlotDMSCache, VanillaCache


def _stream(seed, t, b=1, h=2, dh=4, p_evict=0.5):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    k = jax.random.normal(ks[0], (t, b, h, 1, dh))
    v = jax.random.normal(ks[1], (t, b, h, 1, dh))
    a = jax.random.bernoulli(ks[2], p_evict, (t, b, h))
    return k, v, a


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 6), st.integers(4, 24),
       st.floats(0.0, 0.95))
def test_slot_cache_equals_masked_cache(seed, w, t, p_evict):
    """Property: for any decision stream, the physically-compacted cache
    retains exactly the same (position) set as the masked oracle."""
    k, v, a = _stream(seed, t, p_evict=p_evict)
    mc = MaskedDMSCache.init(1, 2, t, 4, w)
    sc = SlotDMSCache.init(1, 2, t + 1, 4, w)     # ample arena: no overflow
    for i in range(t):
        mc = mc.step(k[i], v[i], a[i])
        sc = sc.step(k[i], v[i], a[i])
    assert (mc.retained_tokens() == sc.retained_tokens()).all()
    for b in range(1):
        for h in range(2):
            mpos = set(np.where(np.asarray(mc.valid_mask()[b, h]))[0].tolist())
            spos = set(np.asarray(sc.pos[b, h])[np.asarray(sc.valid[b, h])].tolist())
            assert mpos == spos
    assert not bool(sc.overflowed.any())


def test_slot_cache_kv_content_preserved():
    t, w = 12, 3
    k, v, a = _stream(7, t)
    sc = SlotDMSCache.init(1, 2, t + 1, 4, w)
    for i in range(t):
        sc = sc.step(k[i], v[i], a[i])
    for h in range(2):
        valid = np.asarray(sc.valid[0, h])
        pos = np.asarray(sc.pos[0, h])[valid]
        kv = np.asarray(sc.k[0, h])[valid]
        for p, row in zip(pos, kv):
            np.testing.assert_allclose(row, np.asarray(k[p, 0, h, 0]), rtol=1e-2)


def test_slot_cache_overflow_recycles_oldest():
    """Arena smaller than the stream with alpha=0: ring-buffer semantics."""
    t, p = 10, 4
    k, v, _ = _stream(3, t)
    a0 = jnp.zeros((t, 1, 2), bool)
    sc = SlotDMSCache.init(1, 2, p, 4, 2)
    for i in range(t):
        sc = sc.step(k[i], v[i], a0[i])
    assert bool(sc.overflowed.all())
    pos = np.sort(np.asarray(sc.pos[0, 0])[np.asarray(sc.valid[0, 0])])
    np.testing.assert_array_equal(pos, np.arange(t - p, t))   # newest P retained


def test_memory_saving_at_target_cr():
    """The provisioned arena is ~S/CR + w — the physical memory claim."""
    slots = SlotDMSCache.provision_slots(4096, cr=8.0, window=256)
    assert slots < 4096 * 0.2
    assert slots >= 4096 // 8 + 256


def test_vanilla_cache_append_and_mask():
    c = VanillaCache.init(2, 2, 8, 4)
    k = jnp.ones((2, 2, 3, 4))
    c = c.append(k, k)
    np.testing.assert_array_equal(np.asarray(c.length), [3, 3])  # per-lane
    m = np.asarray(c.valid_mask())[0, 0]
    np.testing.assert_array_equal(m, [1, 1, 1, 0, 0, 0, 0, 0])


def test_tova_evicts_lowest_weight():
    c = baselines.TOVACache.init(1, 1, budget=3 + 1, head_dim=2)
    k = jnp.ones((1, 1, 1, 2))
    for i in range(4):
        c = c.insert(k * i, k * i)
        w = jnp.ones((1, 1, 4))
        if i == 3:
            w = w.at[0, 0, 1].set(0.01)      # slot 1 = weakest
            c = c.evict(w)
        else:
            c = c.evict(w * 0 + jnp.arange(4) + 1.0)
    valid = np.asarray(c.valid[0, 0])
    assert valid.sum() == 3
    assert not valid[1]


def test_h2o_protects_recent_window():
    c = baselines.H2OCache.init(1, 1, budget=4 + 1, head_dim=2, recent_window=2)
    k = jnp.ones((1, 1, 1, 2))
    for i in range(6):
        c = c.insert(k, k)
        w = jnp.ones((1, 1, 5)) * 0.2
        c = c.evict(w)
    pos = np.asarray(c.pos[0, 0])[np.asarray(c.valid[0, 0])]
    # the two most recent tokens are always alive
    assert {4, 5}.issubset(set(pos.tolist()))


def test_quest_selects_relevant_pages():
    page, top = 4, 1
    c = baselines.QuestCache.init(1, 1, 16, 4, page, top)
    key = jax.random.PRNGKey(0)
    for i in range(16):
        val = jnp.ones((1, 1, 1, 4)) * (10.0 if 8 <= i < 12 else 0.1)
        c = c.append(val, val)
    q = jnp.ones((1, 1, 4))
    pages = np.asarray(c.select_pages(q))[0, 0]
    assert pages[2] and pages.sum() == 1          # page 2 = tokens 8..11
    # memory footprint is full (Quest trades memory for reads)
    assert int(c.retained_tokens()[0, 0]) == 16
    assert int(c.reads_per_step()[0]) == top * page


def test_dmc_merges_with_weighted_average():
    c = baselines.DMCCache.init(1, 1, 4, 2)
    one = jnp.ones((1, 1, 1, 2))
    c = c.step(one * 2.0, one * 2.0, jnp.zeros((1, 1), bool))   # append [2]
    c = c.step(one * 4.0, one * 4.0, jnp.ones((1, 1), bool))    # merge -> 3
    assert int(c.count[0, 0]) == 1
    np.testing.assert_allclose(np.asarray(c.k[0, 0, 0]), [3.0, 3.0], rtol=1e-6)
    c = c.step(one * 9.0, one * 9.0, jnp.zeros((1, 1), bool))   # append
    assert int(c.count[0, 0]) == 2


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 500))
def test_slot_cache_under_jit_and_scan(seed):
    """The cache must be scan/jit transparent (registered pytree)."""
    t, w = 8, 2
    k, v, a = _stream(seed, t)
    sc = SlotDMSCache.init(1, 2, t + 1, 4, w)

    def body(c, xs):
        kk, vv, aa = xs
        return c.step(kk, vv, aa), c.retained_tokens()

    final, _ = jax.jit(lambda c: jax.lax.scan(body, c, (k, v, a)))(sc)
    ref = sc
    for i in range(t):
        ref = ref.step(k[i], v[i], a[i])
    assert (final.retained_tokens() == ref.retained_tokens()).all()
