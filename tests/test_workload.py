"""Workload generators: seeded determinism, arrival-process shape, and the
multi-turn prefix-rehit property.

The determinism contract is the whole point of ``repro.serving.workload``:
same seed ⇒ bit-identical ``Request`` trace (uids, arrivals, prompts,
budgets, widths), so tests, benchmarks, and the chaos harness replay the
exact traffic they were calibrated on.  The checker is plain code shared by
a seeded deterministic driver and a hypothesis ``@given`` fuzzer (degrades
to a skip via ``tests/_hypothesis_compat``).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serving import workload
from repro.serving.workload import WorkloadSpec

SPEC = WorkloadSpec(vocab=64, max_len=24, prompt_len=(4, 10),
                    max_new=(2, 6), widths=(1, 2), eos_id=5, deadline=12)


def _trace_fields(reqs):
    return [(r.uid, r.arrival, r.max_new, r.width, r.eos_id, r.deadline,
             tuple(r.prompt.tolist())) for r in reqs]


def check_trace_contract(reqs, spec, n):
    """Every generator output obeys the submit contract and spec bounds."""
    assert len(reqs) == n
    assert [r.uid for r in reqs] == list(range(n))
    arrivals = [r.arrival for r in reqs]
    assert arrivals == sorted(arrivals), "traces are sorted by arrival"
    for r in reqs:
        assert spec.prompt_len[0] <= len(r.prompt) <= spec.prompt_len[1]
        assert spec.max_new[0] <= r.max_new <= spec.max_new[1]
        assert len(r.prompt) + r.max_new <= spec.max_len
        assert r.width in spec.widths
        assert r.prompt.dtype == np.int32
        assert (r.prompt >= 2).all() and (r.prompt < spec.vocab).all()
        if spec.eos_id is not None:
            assert not (r.prompt == spec.eos_id).any()


def check_determinism(make):
    """same seed ⇒ bit-identical trace; different seed ⇒ a distinct one."""
    a, b, c = make(7), make(7), make(8)
    assert _trace_fields(a) == _trace_fields(b)
    assert _trace_fields(a) != _trace_fields(c)


@pytest.mark.parametrize("gen", ["poisson", "burst"])
def test_trace_determinism_and_contract_seeded(gen):
    n = 12
    if gen == "poisson":
        def make(seed):
            return workload.poisson_trace(seed, n, rate=0.7, spec=SPEC)
    else:
        def make(seed):
            return workload.burst_trace(seed, n, rate=1.5, on_ticks=4,
                                        off_ticks=6, spec=SPEC)
    check_determinism(make)
    check_trace_contract(make(3), SPEC, n)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=1, max_value=20),
       st.floats(min_value=0.1, max_value=3.0))
def test_trace_determinism_and_contract_fuzzed(seed, n, rate):
    def make(s):
        return workload.poisson_trace(s, n, rate=rate, spec=SPEC)
    if n >= 2:        # a 1-request trace can collide across seeds
        check_determinism(make)
    check_trace_contract(make(seed), SPEC, n)


def test_burst_arrivals_respect_off_windows():
    """No arrival ever lands in an off window, and the within-burst offsets
    span the on window (it is a burst, not a point mass)."""
    on, off = 4, 8
    arr = workload.burst_arrivals(0, 200, rate=2.0, on_ticks=on,
                                  off_ticks=off)
    offsets = arr % (on + off)
    assert (offsets < on).all(), "arrival inside an off window"
    assert len(np.unique(offsets)) > 1
    assert len(np.unique(arr // (on + off))) > 1, "all in one burst"


def test_poisson_arrivals_rate_scales_span():
    """Higher rate compresses the same request count into fewer ticks."""
    slow = workload.poisson_arrivals(0, 100, 0.25)
    fast = workload.poisson_arrivals(0, 100, 2.5)
    assert slow[-1] > fast[-1] * 3
    assert (np.diff(slow) >= 0).all() and (np.diff(fast) >= 0).all()


def test_multi_turn_sessions_rehit_their_prefix():
    """Within a session, every turn's prompt starts with the previous turn's
    full prompt (the radix prefix-cache re-hit shape), and the previous
    turn's simulated reply is embedded right after it."""
    spec = WorkloadSpec(vocab=64, max_len=96, prompt_len=(4, 8),
                        max_new=(2, 4))
    reqs = workload.multi_turn_trace(0, sessions=3, turns=3, spec=spec)
    assert len(reqs) > 3
    assert [r.uid for r in reqs] == list(range(len(reqs)))
    # group turns by session: within a session prompts are strict prefix
    # extensions, so sorting by length recovers turn order
    by_head = {}
    for r in reqs:
        by_head.setdefault(tuple(r.prompt[:4].tolist()), []).append(r)
    multi = [sorted(v, key=lambda r: len(r.prompt))
             for v in by_head.values() if len(v) > 1]
    assert multi, "no session produced two turns"
    for turns in multi:
        for prev, nxt in zip(turns, turns[1:]):
            assert len(nxt.prompt) > len(prev.prompt)
            np.testing.assert_array_equal(
                nxt.prompt[:len(prev.prompt)], prev.prompt,
                err_msg="turn does not extend its session context")
            assert nxt.arrival > prev.arrival


def test_multi_turn_determinism():
    spec = WorkloadSpec(vocab=64, max_len=64, prompt_len=(4, 8),
                        max_new=(2, 4))

    def make(seed):
        return workload.multi_turn_trace(seed, sessions=2, turns=3,
                                         spec=spec)
    a, b, c = make(1), make(1), make(2)
    assert _trace_fields(a) == _trace_fields(b)
    assert _trace_fields(a) != _trace_fields(c)


def test_spec_validation():
    with pytest.raises(ValueError, match="prompt_len"):
        WorkloadSpec(vocab=64, max_len=24, prompt_len=(5, 4))
    with pytest.raises(ValueError, match="max_len"):
        WorkloadSpec(vocab=64, max_len=10, prompt_len=(4, 10),
                     max_new=(2, 6))
    with pytest.raises(ValueError, match="width_weights"):
        WorkloadSpec(vocab=64, max_len=24, widths=(1, 2),
                     width_weights=(1.0,))
    with pytest.raises(ValueError, match="rate"):
        workload.poisson_arrivals(0, 4, 0.0)


def test_trace_summary_offered_load():
    reqs = workload.burst_trace(0, 10, rate=1.5, on_ticks=4, off_ticks=6,
                                spec=SPEC)
    s = workload.trace_summary(reqs)
    assert s["requests"] == 10
    assert s["span_ticks"] >= 1
    assert s["prompt_tokens"] == sum(len(r.prompt) for r in reqs)
    assert s["max_new_tokens"] == sum(r.max_new * r.width for r in reqs)
    assert s["offered_tokens_per_tick"] == pytest.approx(
        (s["prompt_tokens"] + s["max_new_tokens"]) / s["span_ticks"])
    assert workload.trace_summary([])["requests"] == 0
