"""Continuous-batching scheduler: admission, chunked prefill, shared-prefill
fork, EOS reclamation, and honest per-request budget metering.

Acceptance criteria pinned here:
* W=4 hyperscale: forked shared prefill produces bitwise-identical first
  decode logits to W independent prefills, at ~4× lower prefill-phase reads.
* An EOS-at-step-k chain contributes zero KV reads after step k (the
  early-stopping batch regression).
* Staggered arrivals with mixed prompt lengths all complete, with
  per-request meters; lane reclaim is exact (a lane reused after EOS serves
  the next request identically to a fresh arena).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import KVPolicyConfig
from repro.core.hyperscale import ScalingConfig
from repro.core.policy import available_policies
from repro.models import transformer as tfm
from repro.serving.engine import Engine, answer_from_chain
from repro.serving.scheduler import Request


# tiny_arch / tiny_params come from tests/conftest.py (shared tiny model)


def _prompt(n, seed=0, vocab=512):
    return np.random.default_rng(seed).integers(3, vocab, size=(n,)).astype(np.int32)


def _run_until_hold(sched):
    """Drive a scheduler just past prefill: every admitted request holds its
    last prefill logits, no decode step has run yet."""
    sched._admit()
    results = []
    while any(r.hold_logits is None for r in sched.active_reqs):
        sched._tick(results)
    assert not results
    return {r.req.uid: np.array(r.hold_logits) for r in sched.active_reqs}


# -- shared-prefill fork ---------------------------------------------------


@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_fork_step0_logits_bitwise_match_tiled_prefill(tiny_arch, tiny_params,
                                                       kind):
    """Acceptance: for every registry policy, the shared prefill's step-0
    logits equal W independent (tiled) prefills bitwise — forked chains start
    from exactly the state W re-prefills would have built."""
    w, t0 = 4, 16
    prompt = _prompt(t0, seed=1, vocab=tiny_arch.vocab_size)
    eng = Engine(tiny_arch, tiny_params,
                 KVPolicyConfig(kind=kind, cr=2.0, budget=12,
                                window=tiny_arch.dms.window))

    shared = eng.scheduler(num_lanes=w, max_len=t0 + 8)
    shared.submit(Request(uid=0, prompt=prompt, max_new=8, width=w))
    fork_logits = _run_until_hold(shared)[0]

    tiled = eng.scheduler(num_lanes=w, max_len=t0 + 8)
    for i in range(w):
        tiled.submit(Request(uid=i, prompt=prompt, max_new=8))
    tiled_logits = _run_until_hold(tiled)

    for i in range(w):
        np.testing.assert_array_equal(fork_logits, tiled_logits[i]), kind


def test_fork_prefill_reads_drop_by_width(tiny_arch, tiny_params):
    """Acceptance: shared prefill meters ~W× fewer prefill-phase KV reads
    than W independent prefills of the same prompt."""
    w, t0 = 4, 16
    prompt = _prompt(t0, seed=2, vocab=tiny_arch.vocab_size)
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="dms", cr=2.0))

    res_fork = eng.hyperscale_generate(prompt, ScalingConfig(t0 + 6, w))
    res_tile = eng.generate(np.tile(prompt[None], (w, 1)), 6)
    fork_pre = res_fork.requests[0].prefill_meter.kv_reads
    tile_pre = sum(r.prefill_meter.kv_reads for r in res_tile.requests)
    assert fork_pre == pytest.approx(tile_pre / w)
    # and the generated chains are identical (greedy): the fork is exact
    np.testing.assert_array_equal(res_fork.tokens, res_tile.tokens)


def test_hyperscale_generate_uses_width_lanes(tiny_arch, tiny_params):
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="vanilla"))
    prompt = _prompt(10, seed=3, vocab=tiny_arch.vocab_size)
    res = eng.hyperscale_generate(prompt, ScalingConfig(16, 4))
    assert res.tokens.shape == (4, 6)
    assert res.meter.generated_tokens == 24


@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_fork_decode_state_equals_tiled_prefill_state(tiny_arch, tiny_params,
                                                      kind):
    """The standalone KVPolicy.fork_cache hook: prefill at B=1, fork the
    whole decode state to W — every leaf must equal the state W tiled
    prefills build (same contract the scheduler's lane gather relies on)."""
    w, t0 = 3, 10
    prompt = _prompt(t0, seed=8, vocab=tiny_arch.vocab_size)
    cfg = KVPolicyConfig(kind=kind, cr=2.0, budget=12,
                         window=tiny_arch.dms.window, quest_page_size=4)
    eng = Engine(tiny_arch, tiny_params, cfg)

    one = tfm.init_decode_state(tiny_arch, 1, t0 + 4, cfg)
    one = eng._prefill_jit(eng.params, jnp.asarray(prompt[None]), one, t=t0)
    forked = tfm.fork_decode_state(one, w)

    tiled = tfm.init_decode_state(tiny_arch, w, t0 + 4, cfg)
    tiled = eng._prefill_jit(eng.params,
                             jnp.asarray(np.tile(prompt[None], (w, 1))),
                             tiled, t=t0)

    f_l, f_tree = jax.tree_util.tree_flatten(forked)
    t_l, t_tree = jax.tree_util.tree_flatten(tiled)
    assert f_tree == t_tree
    for a, b in zip(f_l, t_l):
        assert a.shape == b.shape, kind
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=kind)


def test_concurrent_hyperscale_requests_do_not_deadlock(tiny_arch,
                                                        tiny_params):
    """Regression: greedy admission gave every width-W request one lane and
    left none for their forks — all held forever.  Admission must reserve
    fork capacity (sum of admitted widths <= num_lanes)."""
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="vanilla"))
    sched = eng.scheduler(num_lanes=4, max_len=20)
    for i in range(3):
        sched.submit(Request(uid=i,
                             prompt=_prompt(8, seed=20 + i,
                                            vocab=tiny_arch.vocab_size),
                             max_new=5, width=2))
    results = sched.run()
    assert sorted(r.uid for r in results) == [0, 1, 2]
    assert all(r.tokens.shape == (2, 5) for r in results)


def test_empty_prompt_is_rejected(tiny_arch, tiny_params):
    """Regression: a zero-length prompt never reached the hold transition
    and hung run() forever — reject it at submit."""
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="vanilla"))
    sched = eng.scheduler(num_lanes=1, max_len=16)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(uid=0, prompt=np.empty((0,), np.int32),
                             max_new=4))


# -- EOS handling ----------------------------------------------------------


def test_eos_batch_reads_less_than_nonstopping(tiny_arch, tiny_params):
    """Regression (the seed bug): finished chains kept decoding the full
    max_new and inflating the meter.  An early-stopping batch must meter
    strictly fewer kv_reads than a non-stopping one."""
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="vanilla"))
    prompts = np.stack([_prompt(12, seed=4, vocab=tiny_arch.vocab_size),
                        _prompt(12, seed=5, vocab=tiny_arch.vocab_size)])
    free = eng.generate(prompts, 10)
    eos = int(free.tokens[0, 2])          # token lane 0 emits at step 2
    stopped = eng.generate(prompts, 10, eos_id=eos)
    assert stopped.meter.kv_reads < free.meter.kv_reads
    r0 = stopped.requests[0]
    assert int(r0.lengths[0]) < 10        # actually stopped early
    # zero reads after step k: the stopped request's decode reads are capped
    # by its generated length, the free request decoded all 10
    assert r0.decode_meter.generated_tokens == int(r0.lengths[0])
    assert stopped.requests[0].decode_meter.kv_reads \
        < free.requests[0].decode_meter.kv_reads
    # the unfinished lane is unaffected by its neighbour stopping
    if int(stopped.requests[1].lengths[0]) == 10:
        np.testing.assert_array_equal(stopped.tokens[1], free.tokens[1])


def test_eos_lane_is_reclaimed_for_queued_request(tiny_arch, tiny_params):
    """More requests than lanes: lanes freed by completion are reused, and a
    request served on a reclaimed lane generates exactly what it would on a
    fresh arena (the reclaim hook resets the slot arena completely)."""
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="dms", cr=2.0))
    prompts = [_prompt(n, seed=10 + n, vocab=tiny_arch.vocab_size)
               for n in (9, 14, 6, 11)]
    sched = eng.scheduler(num_lanes=2, max_len=32)
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=5, arrival=i))
    results = {r.uid: r for r in sched.run()}
    assert sorted(results) == [0, 1, 2, 3]

    for i, p in enumerate(prompts):
        solo = eng.scheduler(num_lanes=1, max_len=32)
        solo.submit(Request(uid=0, prompt=p, max_new=5))
        np.testing.assert_array_equal(solo.run()[0].tokens,
                                      results[i].tokens, err_msg=str(i))


# -- mixed-arrival scheduling + per-request meters -------------------------


def test_staggered_mixed_length_requests_all_complete(tiny_arch, tiny_params):
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="window", cr=2.0))
    lens = [7, 19, 5, 13, 10]
    sched = eng.scheduler(num_lanes=3, max_len=40)
    for i, n in enumerate(lens):
        sched.submit(Request(
            uid=i, prompt=_prompt(n, seed=i, vocab=tiny_arch.vocab_size),
            max_new=6, arrival=2 * i))
    results = sorted(sched.run(), key=lambda r: r.uid)
    assert [r.uid for r in results] == list(range(len(lens)))
    for r in results:
        assert int(r.lengths[0]) == 6
        # per-request metering: prefill steps cover this prompt, decode
        # steps cover this generation — nobody pays for a neighbour
        assert r.prefill_meter.kv_reads > 0
        assert r.decode_meter.generated_tokens == 6
        assert np.isfinite(r.meter.kv_reads)
    # longer prompts must meter more prefill reads (per-request attribution)
    by_len = sorted(results, key=lambda r: lens[r.uid])
    pre = [r.prefill_meter.kv_reads for r in by_len]
    assert pre == sorted(pre)


def test_generate_meter_matches_lockstep_total(tiny_arch, tiny_params):
    """Without EOS, generate() keeps the lockstep contract: every chain
    decodes exactly max_new tokens and the merged meter covers all lanes."""
    eng = Engine(tiny_arch, tiny_params, KVPolicyConfig(kind="vanilla"))
    prompts = np.stack([_prompt(8, seed=6, vocab=tiny_arch.vocab_size)] * 3)
    res = eng.generate(prompts, 7)
    assert res.tokens.shape == (3, 7)
    assert res.meter.generated_tokens == 21
    assert res.meter.peak_tokens > 0 and res.meter.peak_bytes > 0


# -- answer_from_chain (satellite bugfix) ----------------------------------


def test_answer_from_chain_scans_for_eq_token():
    # answer follows the last "=" the chain emits
    assert answer_from_chain(np.array([5, 1, 9, 4]), eq_token=1) == 9
    assert answer_from_chain(np.array([3, 1, 7, 1, 8]), eq_token=1) == 8
    # no "=" anywhere -> first token (prompt already ended in "=")
    assert answer_from_chain(np.array([6, 2, 3]), eq_token=1) == 6
    # trailing "=" has no following token -> falls back to first token
    assert answer_from_chain(np.array([4, 1]), eq_token=1) == 4
    assert answer_from_chain(np.array([], dtype=np.int32)) is None


# -- failure semantics & preemption ----------------------------------------


def _fault_engine(tiny_arch, tiny_params, pool_blocks=8):
    """Paged engine with a deliberately tight pool: solo worst-case demand
    at max_len=24 is 6 pages/lane, so two lanes oversubscribe 8 pages."""
    return Engine(tiny_arch, tiny_params,
                  KVPolicyConfig(kind="dms", cr=2.0,
                                 window=tiny_arch.dms.window,
                                 paged=True, block_p=8,
                                 pool_blocks=pool_blocks),
                  chunk=4)


def _solo_tokens(eng, req):
    sched = eng.scheduler(num_lanes=2, max_len=24)
    sched.submit(req)
    return sched.run()[0].tokens


def test_oversubscribed_ignore_mode_corrupts_silently(tiny_arch, tiny_params):
    """Regression pin of the seed failure mode this PR fixes: with
    ``on_pressure="ignore"`` an oversubscribed decode exhausts the pool,
    drops writes, and emits WRONG tokens with status still "ok" — no error
    anywhere.  If this test ever fails because the divergence disappeared,
    the demonstration scenario needs retuning, not deletion."""
    eng = _fault_engine(tiny_arch, tiny_params)
    reqs = [Request(uid=i,
                    prompt=_prompt(10, seed=50 + i, vocab=tiny_arch.vocab_size),
                    max_new=8)
            for i in range(2)]
    solo = [_solo_tokens(eng, r) for r in reqs]

    sched = eng.scheduler(num_lanes=2, max_len=24, oversub=2.0,
                          on_pressure="ignore")
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}

    stats = sched.pool_stats()
    assert stats["exhausted"], "scenario no longer exhausts the pool"
    assert all(results[i].status == "ok" for i in range(2))
    assert any(not np.array_equal(results[i].tokens, solo[i])
               for i in range(2)), "dropped writes no longer corrupt tokens"


def test_oversubscribed_preempt_mode_absorbs_pressure(tiny_arch, tiny_params):
    """The fix: same oversubscribed trace under ``on_pressure="preempt"``
    preempts the youngest request ahead of exhaustion, resumes it from its
    snapshot, and every request finishes bitwise-correct."""
    eng = _fault_engine(tiny_arch, tiny_params)
    reqs = [Request(uid=i,
                    prompt=_prompt(10, seed=50 + i, vocab=tiny_arch.vocab_size),
                    max_new=8)
            for i in range(2)]
    solo = [_solo_tokens(eng, r) for r in reqs]

    sched = eng.scheduler(num_lanes=2, max_len=24, oversub=2.0,
                          on_pressure="preempt")
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}

    stats = sched.pool_stats()
    assert not stats["exhausted"]
    assert stats["lifecycle"]["preemptions"] > 0
    assert stats["lifecycle"]["resumes"] == stats["lifecycle"]["preemptions"]
    for i in range(2):
        assert results[i].status == "ok"
        np.testing.assert_array_equal(results[i].tokens, solo[i])
    # latency observability: preempted requests report end-to-end ticks
    assert all(results[i].latency_ticks > 0 for i in range(2))


def test_pool_exhausted_backstop_fails_instead_of_corrupting(tiny_arch,
                                                             tiny_params):
    """Defense-in-depth: if pressure relief somehow misses (here: disabled
    by hand), the tick-boundary exhaustion check must FAIL the affected
    requests rather than let a single corrupt token reach a result."""
    eng = _fault_engine(tiny_arch, tiny_params)
    reqs = [Request(uid=i,
                    prompt=_prompt(10, seed=50 + i, vocab=tiny_arch.vocab_size),
                    max_new=8)
            for i in range(2)]
    solo = [_solo_tokens(eng, r) for r in reqs]

    sched = eng.scheduler(num_lanes=2, max_len=24, oversub=2.0,
                          on_pressure="preempt")
    sched._relieve_pressure = lambda results: None   # corner the backstop
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}

    assert any(results[i].status == "failed" for i in range(2))
    for i in range(2):
        if results[i].status == "ok":
            np.testing.assert_array_equal(results[i].tokens, solo[i])
    # the latch was consumed at the boundary, not left to re-doom later work
    assert not sched.pool_stats()["exhausted"]


def test_deadline_timeouts_active_and_queued(tiny_arch, tiny_params):
    """Active requests past their deadline retire as "timeout" with partial
    output; queued requests expire without ever taking a lane."""
    eng = _fault_engine(tiny_arch, tiny_params)
    sched = eng.scheduler(num_lanes=1, max_len=24)
    sched.submit(Request(uid=0,
                         prompt=_prompt(8, seed=9, vocab=tiny_arch.vocab_size),
                         max_new=10, deadline=3))
    sched.submit(Request(uid=1,
                         prompt=_prompt(8, seed=9, vocab=tiny_arch.vocab_size),
                         max_new=2, deadline=1))
    results = {r.uid: r for r in sched.run()}

    assert results[0].status == "timeout"
    assert results[0].latency_ticks > 3      # the tick that tripped it
    assert results[1].status == "timeout"
    assert results[1].admitted_tick == -1    # expired while queued
    assert sched.lifecycle_stats()["timeouts"] == 2


def test_nan_tripwire_fails_lane_and_isolates_neighbours(tiny_arch,
                                                         tiny_params):
    """Poisoned logits on one lane fail THAT request at the tick boundary
    (no NaN-derived token ever reaches a result); the co-resident lane is
    untouched and finishes bitwise-equal to its solo run."""
    from repro.serving.faults import Fault, FaultPlan

    eng = _fault_engine(tiny_arch, tiny_params, pool_blocks=None)
    reqs = [Request(uid=i,
                    prompt=_prompt(8, seed=60 + i, vocab=tiny_arch.vocab_size),
                    max_new=6)
            for i in range(2)]
    solo = [_solo_tokens(eng, r) for r in reqs]

    plan = FaultPlan([Fault("nan_logits", tick=2, lane=0)])
    sched = eng.scheduler(num_lanes=2, max_len=24, faults=plan)
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}

    statuses = {uid: r.status for uid, r in results.items()}
    assert "failed" in statuses.values() and "ok" in statuses.values()
    ok_uid = next(u for u, s in statuses.items() if s == "ok")
    np.testing.assert_array_equal(results[ok_uid].tokens, solo[ok_uid])
    assert sched.lifecycle_stats()["failures"] == 1


def test_submit_rejects_unservable_request(tiny_arch, tiny_params):
    """Solo-fit invariant: a request whose worst-case pool demand exceeds
    the whole pool can never be served at ANY load — reject at submit, not
    after it wedges the arena."""
    eng = _fault_engine(tiny_arch, tiny_params)   # 8-page pool, 6 pages/lane
    sched = eng.scheduler(num_lanes=2, max_len=24)
    with pytest.raises(ValueError, match="pool"):
        # 18 tokens -> 6 pages/lane worst-case; width 2 -> 12 > 8-page pool
        sched.submit(Request(
            uid=0, prompt=_prompt(10, seed=3, vocab=tiny_arch.vocab_size),
            max_new=8, width=2))
    # the same shape at width 1 is servable (6 <= 8)
    sched.submit(Request(
        uid=1, prompt=_prompt(10, seed=3, vocab=tiny_arch.vocab_size),
        max_new=8))


# -- SLO & overload control --------------------------------------------------
# docs/serving.md "SLO & overload control" is the contract these tests pin.


def _plain_engine(tiny_arch, tiny_params):
    """Fixed-arena engine (no pool): SLO tests isolate the ladder from the
    preemption layer's pool pressure."""
    return Engine(tiny_arch, tiny_params,
                  KVPolicyConfig(kind="dms", cr=2.0,
                                 window=tiny_arch.dms.window),
                  chunk=4)


def test_deadline_boundary_exact_tick(tiny_arch, tiny_params):
    """Boundary pinning: the usable window is CLOSED — [arrival,
    arrival + deadline].  A request finishing exactly at arrival + deadline
    is "ok" (completion wins the tie); deadline - 1 times it out, and the
    timeout retires on the first doomed tick, arrival + deadline + 1 - 1 ==
    the post-increment boundary.  Both the active path (_tick) and the
    queued path (_expire_queued) use the same strict-> comparison; this test
    is the regression pin both cite."""
    eng = _plain_engine(tiny_arch, tiny_params)
    prompt = _prompt(8, seed=70, vocab=tiny_arch.vocab_size)
    # solo latency: 2 prefill ticks (plen 8 / chunk 4) + 1 decode tick
    sched = eng.scheduler(num_lanes=1, max_len=24)
    sched.submit(Request(uid=0, prompt=prompt, max_new=4))
    lat = sched.run()[0].latency_ticks

    # deadline == exact latency: completes ok AT the boundary tick
    sched = eng.scheduler(num_lanes=1, max_len=24)
    sched.submit(Request(uid=0, prompt=prompt, max_new=4, deadline=lat))
    res = sched.run()[0]
    assert res.status == "ok" and res.finished_tick == lat

    # deadline = lat - 1: the request completes at the first doomed
    # boundary (arrival + dl + 1) — a genuine tie, and completion wins it
    sched = eng.scheduler(num_lanes=1, max_len=24)
    sched.submit(Request(uid=0, prompt=prompt, max_new=4, deadline=lat - 1))
    res = sched.run()[0]
    assert res.status == "ok" and res.finished_tick == lat

    # deadline = lat - 2: the doomed boundary (dl + 1 = lat - 1) arrives
    # with the request still incomplete — timeout retires it THERE, not at
    # its would-be completion tick
    sched = eng.scheduler(num_lanes=1, max_len=24)
    sched.submit(Request(uid=0, prompt=prompt, max_new=4, deadline=lat - 2))
    res = sched.run()[0]
    assert res.status == "timeout"
    assert res.finished_tick == (lat - 2) + 1

    # queued path: a request that can never be admitted before its deadline
    # expires at arrival + deadline + 1 without taking a lane
    sched = eng.scheduler(num_lanes=1, max_len=24)
    sched.submit(Request(uid=0, prompt=prompt, max_new=8))
    sched.submit(Request(uid=1, prompt=prompt, max_new=4, deadline=1))
    res = {r.uid: r for r in sched.run()}[1]
    assert res.status == "timeout" and res.admitted_tick == -1
    assert res.finished_tick == 1 + 1


def test_bounded_queue_rejects_newest_arrivals(tiny_arch, tiny_params):
    """max_queue backpressure: when the live backlog exceeds the bound the
    NEWEST arrivals bounce with a definite "rejected" status and zero
    prefill reads; future arrivals in a preloaded trace never count."""
    from repro.serving.scheduler import SLOSpec

    eng = _plain_engine(tiny_arch, tiny_params)
    slo = SLOSpec(max_queue=1, shed=False, degrade_width=False)
    sched = eng.scheduler(num_lanes=1, max_len=24, slo=slo)
    prompt = _prompt(8, seed=71, vocab=tiny_arch.vocab_size)
    for i in range(3):
        sched.submit(Request(uid=i, prompt=prompt, max_new=4))
    # a FUTURE arrival: must not be bounced by today's backlog
    sched.submit(Request(uid=3, prompt=prompt, max_new=4, arrival=30))
    results = {r.uid: r for r in sched.run()}

    assert results[0].status == "ok"
    for uid in (1, 2):
        assert results[uid].status == "rejected", uid
        assert results[uid].admitted_tick == -1
        assert results[uid].prefill_meter.kv_reads == 0
    assert results[3].status == "ok"
    life = sched.lifecycle_stats()
    assert life["rejected"] == 2 and life["shed"] == 0
    assert sched.offered == 4


def test_shed_provably_doomed_request_zero_prefill(tiny_arch, tiny_params):
    """The shed rung: a queued request that provably cannot meet its
    deadline even if admitted this tick is rejected BEFORE its deadline
    passes and before it burns any prefill reads — unlike the uncontrolled
    scheduler, where the same request would be admitted, prefill, and time
    out."""
    from repro.serving.scheduler import SLOSpec

    eng = _plain_engine(tiny_arch, tiny_params)
    long = Request(uid=0,
                   prompt=_prompt(12, seed=72, vocab=tiny_arch.vocab_size),
                   max_new=10)
    # min service for uid 1: 3 prefill ticks (plen 12) + 2 decode ticks;
    # while uid 0 squats the single lane, ticks advance past the point where
    # arrival + deadline is still reachable
    doomed = Request(uid=1,
                     prompt=_prompt(12, seed=73,
                                    vocab=tiny_arch.vocab_size),
                     max_new=8, deadline=6)
    sched = eng.scheduler(num_lanes=1, max_len=24,
                          slo=SLOSpec(degrade_width=False))
    sched.submit(long)
    sched.submit(doomed)
    results = {r.uid: r for r in sched.run()}

    assert results[0].status == "ok"
    assert results[1].status == "rejected"
    assert results[1].admitted_tick == -1
    assert results[1].prefill_meter.kv_reads == 0
    # shed strictly before the deadline would have fired
    assert results[1].finished_tick <= doomed.deadline
    life = sched.lifecycle_stats()
    assert life["shed"] == 1 and life["timeouts"] == 0

    # uncontrolled: the same trace burns prefill on uid 1, then times it out
    sched = eng.scheduler(num_lanes=1, max_len=24)
    sched.submit(dataclasses.replace(long))
    sched.submit(dataclasses.replace(doomed))
    results = {r.uid: r for r in sched.run()}
    assert results[1].status == "timeout"


def test_width_degradation_token_equal_and_hysteresis(tiny_arch,
                                                      tiny_params):
    """The throttle rung: under a backlog that exceeds the arena, width-W
    requests are served at min_width with ``degraded`` set, and every
    degraded request is bitwise token-equal to a solo run AT THE SERVED
    width.  With headroom (calm trace) the throttle must be invisible:
    full width, no degraded flag."""
    from repro.serving.scheduler import SLOSpec

    eng = _plain_engine(tiny_arch, tiny_params)
    reqs = [Request(uid=i,
                    prompt=_prompt(8, seed=80 + i,
                                   vocab=tiny_arch.vocab_size),
                    max_new=4, width=2)
            for i in range(3)]

    slo = SLOSpec(min_width=1, cooldown_ticks=4)
    sched = eng.scheduler(num_lanes=2, max_len=24, slo=slo)
    for r in reqs:
        sched.submit(r)
    results = {r.uid: r for r in sched.run()}

    assert sched.lifecycle_stats()["degraded"] >= 1
    saw_degraded = False
    for r in reqs:
        got = results[r.uid]
        assert got.status == "ok"
        served_w = len(got.lengths)
        assert got.degraded == (served_w < r.width)
        saw_degraded |= got.degraded
        solo = eng.scheduler(num_lanes=2, max_len=24)
        solo.submit(dataclasses.replace(r, width=served_w, arrival=0))
        ref = solo.run()[0]
        np.testing.assert_array_equal(got.tokens, ref.tokens,
                                      err_msg=f"uid {r.uid}")
        np.testing.assert_array_equal(got.lengths, ref.lengths)
    assert saw_degraded

    # hysteresis recovery: the same width-2 request alone (no backlog) is
    # served at full width — the throttle disengages after the cooldown
    sched = eng.scheduler(num_lanes=2, max_len=24, slo=slo)
    sched.submit(dataclasses.replace(reqs[0]))
    res = sched.run()[0]
    assert not res.degraded and len(res.lengths) == 2
    assert sched.lifecycle_stats()["degraded"] == 0


def test_ttft_tpot_metering_and_slo_stats(tiny_arch, tiny_params):
    """TTFT = arrival -> first sampled token; TPOT = decode ticks per
    post-first token; slo_stats joins goodput, percentiles, timelines and
    lifecycle counters."""
    from repro.serving.scheduler import SLOSpec, slo_attained

    eng = _plain_engine(tiny_arch, tiny_params)
    slo = SLOSpec(ttft_ticks=4, tpot_ticks=1.0)
    sched = eng.scheduler(num_lanes=1, max_len=24, slo=slo)
    # plen 8 / chunk 4 -> 2 prefill ticks: first token samples at tick 2
    sched.submit(Request(uid=0,
                         prompt=_prompt(8, seed=90,
                                        vocab=tiny_arch.vocab_size),
                         max_new=6))
    res = sched.run()[0]

    assert res.first_token_tick == 2
    assert res.ttft_ticks == 2
    # 5 post-first tokens over (finished - first_token) decode ticks
    assert res.tpot_ticks == pytest.approx(
        (res.finished_tick - res.first_token_tick) / 5)
    assert slo_attained(res, slo)

    stats = sched.slo_stats()
    assert stats["offered"] == 1 and stats["goodput"] == 1.0
    assert stats["ttft"]["p50"] == 2.0
    # the solo request is admitted before the first timeline sample, so
    # the queue axis records an all-drained trace
    assert stats["queue_depth"]["max"] == 0
    assert 0.0 < stats["lane_util"] <= 1.0
    assert stats["lifecycle"]["completed"] == 1

    # a queued-forever request never samples: sentinel TTFT, not within SLO
    sched = eng.scheduler(num_lanes=1, max_len=24, slo=slo)
    sched.submit(Request(uid=0,
                         prompt=_prompt(8, seed=90,
                                        vocab=tiny_arch.vocab_size),
                         max_new=6))
    sched.submit(Request(uid=1,
                         prompt=_prompt(8, seed=91,
                                        vocab=tiny_arch.vocab_size),
                         max_new=4, deadline=1))
    results = {r.uid: r for r in sched.run()}
    assert results[1].ttft_ticks == -1
    assert not slo_attained(results[1], slo)
