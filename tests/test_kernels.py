"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dms_attention import ops as fops
from repro.kernels.dms_attention import ref as fref
from repro.kernels.dms_decode import ops as dops
from repro.kernels.dms_decode import ref as dref

SHAPES = [
    # (B, T, Hq, Hkv, Dh)
    (1, 16, 2, 1, 8),
    (2, 48, 4, 2, 16),
    (1, 64, 8, 2, 32),
    (2, 33, 6, 3, 8),       # non-divisible T (padding path)
    (1, 24, 8, 1, 16),      # deep GQA: 8 query heads share one kv head
    (1, 40, 12, 2, 8),      # 6:1 group ratio
    (1, 37, 4, 2, 16),      # odd T, no block divides it
    (2, 51, 6, 1, 8),       # odd T + MQA
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _inputs(shape, dtype, seed=0):
    b, t, hq, hkv, dh = shape
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, t, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, dh), dtype)
    alpha = jax.random.uniform(ks[3], (b, hkv, t), jnp.float32, 0.02, 0.9)
    return q, k, v, alpha


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_fwd_matches_ref(shape, dtype):
    q, k, v, alpha = _inputs(shape, dtype)
    out = fops.dms_flash_attention(q, k, v, alpha, dms_window=4,
                                   block_q=16, block_k=16)
    ref = fref.dms_attention_ref(q, k, v, jnp.log1p(-alpha), dms_window=4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,cap", [(None, None), (16, None), (None, 30.0),
                                        (8, 50.0)])
def test_flash_fwd_window_softcap(window, cap):
    q, k, v, alpha = _inputs((2, 48, 4, 2, 16), jnp.float32)
    out = fops.dms_flash_attention(q, k, v, alpha, dms_window=4, window=window,
                                   logit_cap=cap, block_q=16, block_k=16)
    ref = fref.dms_attention_ref(q, k, v, jnp.log1p(-alpha), dms_window=4,
                                 window=window, logit_cap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_vanilla_no_alpha():
    q, k, v, _ = _inputs((2, 32, 4, 2, 16), jnp.float32)
    out = fops.dms_flash_attention(q, k, v, None, block_q=16, block_k=16)
    ref = fref.dms_attention_ref(q, k, v, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("seed", [0, 1])
def test_flash_bwd_matches_autodiff(seed):
    q, k, v, alpha = _inputs((1, 32, 4, 2, 16), jnp.float32, seed)
    tgt = jax.random.normal(jax.random.PRNGKey(9), q.shape)

    def loss_k(q, k, v, a):
        o = fops.dms_flash_attention(q, k, v, a, dms_window=4,
                                     block_q=16, block_k=16)
        return jnp.sum(o * tgt)

    def loss_r(q, k, v, a):
        o = fref.dms_attention_ref(q, k, v, jnp.log1p(-a), dms_window=4)
        return jnp.sum(o * tgt)

    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3))(q, k, v, alpha)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3))(q, k, v, alpha)
    for name, a, b in zip("q k v alpha".split(), gk, gr):
        rel = float(jnp.max(jnp.abs(a - b))) / (float(jnp.max(jnp.abs(b))) + 1e-9)
        assert rel < 1e-4, (name, rel)


def test_flash_skip_blocks_binary_alpha():
    """Dead-block skipping must be exact for binarised decisions."""
    b, t, hq, hkv, dh = 1, 64, 2, 1, 8
    q, k, v, _ = _inputs((b, t, hq, hkv, dh), jnp.float32)
    alpha_bin = jnp.zeros((b, hkv, t), bool).at[:, :, 4:40].set(True)
    out = fops.dms_flash_attention_prefill(q, k, v, alpha_bin, dms_window=8,
                                           block_q=16, block_k=16)
    ls = jnp.maximum(jnp.log1p(-alpha_bin.astype(jnp.float32)), -1e30)
    ref = fref.dms_attention_ref(q, k, v, ls, dms_window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(2, 4, 2, 40, 16), (1, 8, 1, 100, 32),
                                   (3, 6, 3, 24, 8), (2, 8, 4, 17, 8),
                                   (1, 8, 1, 23, 16),    # deep GQA, odd P
                                   (2, 12, 2, 19, 8),    # 6:1 groups, odd P
                                   (1, 16, 2, 37, 32)])  # wide groups
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_kernel_matches_ref(shape, dtype):
    b, hq, hkv, p, dh = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (b, 1, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, hkv, p, dh), dtype)
    v = jax.random.normal(ks[2], (b, hkv, p, dh), dtype)
    valid = jax.random.bernoulli(ks[3], 0.6, (b, hkv, p)).at[:, :, 0].set(True)
    out = dops.dms_decode_attention(q, k, v, valid, block_p=16)
    ref = dref.dms_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_kernel_softcap():
    b, hq, hkv, p, dh = 1, 4, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (b, 1, hq, dh))
    k = jax.random.normal(ks[1], (b, hkv, p, dh))
    v = jax.random.normal(ks[2], (b, hkv, p, dh))
    valid = jnp.ones((b, hkv, p), bool)
    out = dops.dms_decode_attention(q, k, v, valid, logit_cap=30.0, block_p=16)
    ref = dref.dms_decode_ref(q, k, v, valid, logit_cap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_kernel_all_blocks_dead_but_one():
    """Block-level liveness: only one live slot far into the arena."""
    b, hq, hkv, p, dh = 1, 2, 1, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, dh))
    k = jax.random.normal(ks[1], (b, hkv, p, dh))
    v = jax.random.normal(ks[2], (b, hkv, p, dh))
    valid = jnp.zeros((b, hkv, p), bool).at[:, :, 50].set(True)
    out = dops.dms_decode_attention(q, k, v, valid, block_p=16)
    ref = dref.dms_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(2, 4, 2, 64, 16), (1, 8, 1, 96, 32),
                                   (2, 12, 2, 32, 8)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_decode_kernel_block_table_mode(shape, dtype):
    """Explicit block-table mode (the policy step path): fragmented valid,
    compacted table — same output as the oracle, no pad/derive in the
    wrapper."""
    from repro.core.kv_cache import BlockTable
    b, hq, hkv, p, dh = shape
    bp = 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (b, 1, hq, dh), dtype)
    k = jax.random.normal(ks[1], (b, hkv, p, dh), dtype)
    v = jax.random.normal(ks[2], (b, hkv, p, dh), dtype)
    valid = jax.random.bernoulli(ks[3], 0.4, (b, hkv, p)).at[:, :, 0].set(True)
    bt = BlockTable.from_valid(valid, bp)
    out = dops.dms_decode_attention(q, k, v, valid, block_tbl=bt.tbl,
                                    block_n=bt.n, block_p=bp)
    ref = dref.dms_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_kernel_partial_table_page_sparse():
    """A table listing only SOME live blocks (Quest top-k pages): the kernel
    must attend exactly to the listed blocks' visible slots."""
    from repro.core.kv_cache import BlockTable
    b, hq, hkv, p, dh, bp = 1, 4, 2, 64, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, dh))
    k = jax.random.normal(ks[1], (b, hkv, p, dh))
    v = jax.random.normal(ks[2], (b, hkv, p, dh))
    page_mask = jnp.zeros((b, hkv, p // bp), bool).at[:, :, ::2].set(True)
    vis = jnp.repeat(page_mask, bp, axis=2)           # selected pages only
    bt = BlockTable.from_valid(vis, bp)
    out = dops.dms_decode_attention(q, k, v, vis, block_tbl=bt.tbl,
                                    block_n=bt.n, block_p=bp)
    ref = dref.dms_decode_ref(q, k, v, vis)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_kernel_table_mode_rejects_unpadded():
    with pytest.raises(ValueError, match="not a multiple"):
        b, hkv, p, dh = 1, 1, 20, 8
        q = jnp.zeros((b, 1, 2, dh))
        k = jnp.zeros((b, hkv, p, dh))
        dops.dms_decode_attention(
            q, k, k, jnp.ones((b, hkv, p), bool),
            block_tbl=jnp.zeros((b, hkv, 2), jnp.int32),
            block_n=jnp.ones((b, hkv), jnp.int32), block_p=16)


def test_chunked_impls_match_kernel():
    """The dry-run lowering paths agree with the Pallas kernel."""
    from repro.models.attention import attention_chunked, attention_chunked_scan
    q, k, v, alpha = _inputs((2, 40, 4, 2, 16), jnp.float32)
    ker = fops.dms_flash_attention(q, k, v, alpha, dms_window=4,
                                   block_q=16, block_k=16)
    ch = attention_chunked(q, k, v, alpha, dms_delay=4, chunk_q=16, chunk_k=16)
    cs = attention_chunked_scan(q, k, v, alpha, dms_delay=4)
    np.testing.assert_allclose(np.asarray(ch), np.asarray(ker), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(ker), rtol=2e-5, atol=2e-5)


def test_scheduler_smoke_with_kernel(tiny_arch, tiny_params):
    """End-to-end: continuous-batching serve (chunked prefill + decode)
    through the Pallas decode kernel (interpret mode on CPU) — and greedy
    generations match the pure-jnp reference decode path."""
    from repro.core.config import KVPolicyConfig
    from repro.serving.engine import Engine

    prompts = np.random.default_rng(5).integers(
        3, tiny_arch.vocab_size, size=(2, 11)).astype(np.int32)
    cfg = KVPolicyConfig(kind="dms", cr=2.0, window=tiny_arch.dms.window)
    res_k = Engine(tiny_arch, tiny_params, cfg,
                   use_kernel=True).generate(prompts, 5)
    assert res_k.tokens.shape == (2, 5)
    assert np.isfinite(res_k.meter.kv_reads)
    assert res_k.meter.peak_tokens > 0
    res_r = Engine(tiny_arch, tiny_params, cfg).generate(prompts, 5)
    np.testing.assert_array_equal(res_k.tokens, res_r.tokens)
