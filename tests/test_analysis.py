"""Decode-path program auditor: every lint pass has a red (seeded-bad) and a
green (real-path) test, the contract checkers catch deliberately broken
implementations, and the retrace sentinel + host-sync tripwire hold over a
randomized mixed scheduler trace.

Acceptance criteria pinned here:
* Each traffic lint fires on a minimal reproduction of the pathology it
  names (seed-era re-pad, metadata recast, KV upcast, whole-arena gather,
  device-scalar bookkeeping) and stays silent on the healthy equivalent.
* The real decode/fork/reclaim entry points lint clean — the audit CLI's
  green sweep is not vacuous.
* A policy violating the lifecycle contract (aval drift, missing metrics)
  is caught by name; the registered nine all pass.
* A randomized scheduler trace (mixed prompt lengths, widths, arrivals,
  EOS) compiles the chunk step exactly once and never syncs the host
  outside the sanctioned tick boundary.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.analysis import contracts
from repro.analysis.hostsync import HostSyncTripwire, sanctioned
from repro.analysis.jaxpr import count_big_float_ops, dce, trace_jaxpr
from repro.analysis.passes import LintContext, gating, run_passes
from repro.analysis.retrace import RetraceSentinel, engine_jits
from repro.core.config import KVPolicyConfig
from repro.core.policy import _REGISTRY, available_policies, get_policy
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm
from repro.serving.engine import Engine
from repro.serving.scheduler import Request

ARENA = (2, 2, 16, 4)                       # (B, Hkv, S, Dh) toy arena
ELEMS = int(np.prod(ARENA))


def _findings(fn, *args, table_mode=False, passes=None):
    ctx = LintContext(arena_elems=ELEMS, table_mode=table_mode)
    return run_passes(fn, ctx, *args, passes=passes, path="test")


def _rules(findings):
    return sorted({f.rule for f in gating(findings)})


# -- traffic lints: red on the seeded pathology, green on the healthy twin --


class TestArenaPad:
    def test_red_per_step_repad(self):
        def step(arena, kn):
            # the seed wrapper: re-pad the whole arena every step
            return jnp.concatenate([arena[:, :, 1:], kn], axis=2)

        arena = jnp.zeros(ARENA)
        kn = jnp.zeros((2, 2, 1, 4))
        assert _rules(_findings(step, arena, kn)) == ["arena-pad"]

    def test_green_in_place_write(self):
        def step(arena, kn, pos):
            return jax.lax.dynamic_update_slice(arena, kn, (0, 0, pos, 0))

        arena = jnp.zeros(ARENA)
        assert not _findings(step, arena, jnp.zeros((2, 2, 1, 4)),
                             jnp.int32(3))


class TestArenaCast:
    def test_red_valid_bitmap_recast(self):
        def step(valid):
            # seed-era: astype(int32) of the whole validity bitmap per step
            return valid.astype(jnp.int32).sum(axis=-1)

        valid = jnp.zeros(ARENA, bool)
        assert _rules(_findings(step, valid)) == ["arena-cast"]

    def test_green_small_cast(self):
        def step(length):
            return length.astype(jnp.int32)

        assert not _findings(step, jnp.zeros((2,), jnp.int8))


class TestKVUpcast:
    def test_red_bf16_arena_to_f32(self):
        def step(arena):
            return arena.astype(jnp.float32) * 2.0

        assert _rules(_findings(step, jnp.zeros(ARENA, jnp.bfloat16))) \
            == ["kv-upcast"]

    def test_green_downcast_is_by_design(self):
        def step(acc):
            # DMC writes its f32 accumulators back at model dtype
            return acc.astype(jnp.bfloat16)

        assert not _findings(step, jnp.zeros(ARENA, jnp.float32))


class TestArenaGather:
    @staticmethod
    def _dense_rematerialize(arena, idx):
        # the wrapper re-materializing table order around the kernel
        return jnp.take(arena, idx, axis=2)

    def test_red_table_mode(self):
        # pass-scoped: a kernel-free toy program also (correctly) trips the
        # ref-fallback lint in table mode, exercised by its own tests below
        arena = jnp.zeros(ARENA)
        idx = jnp.arange(ARENA[2])
        got = _findings(self._dense_rematerialize, arena, idx,
                        table_mode=True, passes=["arena-gather"])
        assert _rules(got) == ["arena-gather"]

    def test_green_ref_mode_gathers_allowed(self):
        arena = jnp.zeros(ARENA)
        idx = jnp.arange(ARENA[2])
        assert not _findings(self._dense_rematerialize, arena, idx)

    def test_green_embedding_lookup_exempt(self):
        # per-token lookups into big 2-D tables are the decode front-end,
        # not arena traffic (rank-<3 exemption)
        embed = jnp.zeros((ELEMS * 2, 8))
        tok = jnp.zeros((2, 1), jnp.int32)
        assert not _findings(lambda e, t: e[t], embed, tok,
                             table_mode=True, passes=["arena-gather"])


class TestRefFallback:
    @staticmethod
    def _ref_attention(q, arena):
        # the reference bhgd,bhpd->bhgp score einsum over the whole arena
        return jnp.einsum("bhgd,bhpd->bhgp", q, arena)

    def test_red_reference_einsum_in_kernel_mode(self):
        q = jnp.zeros((2, 2, 2, 4))
        got = _findings(self._ref_attention, q, jnp.zeros(ARENA),
                        table_mode=True, passes=["ref-fallback"])
        assert _rules(got) == ["ref-fallback"]
        # both signals fire: the arena-sized score einsum itself, and the
        # absence of any pallas_call in the program
        assert len(gating(got)) == 2

    def test_green_ref_mode_is_silent(self):
        q = jnp.zeros((2, 2, 2, 4))
        assert not _findings(self._ref_attention, q, jnp.zeros(ARENA),
                             passes=["ref-fallback"])

    def test_param_matmul_not_flagged_as_einsum_fallback(self):
        # 0-batch-dim matmuls (the MLP/projection path) never trip the
        # einsum signal, however large — only the missing-kernel signal
        # remains for this (kernel-free) toy program
        w = jnp.zeros((ELEMS, 8))
        x = jnp.zeros((2, ELEMS))
        got = _findings(lambda x, w: x @ w, x, w, table_mode=True,
                        passes=["ref-fallback"])
        msgs = [f.message for f in gating(got)]
        assert msgs and all("no pallas_call" in m for m in msgs)

    def test_red_real_reference_decode_in_table_mode(self, tiny_arch,
                                                     tiny_params,
                                                     paged_state):
        # the actual pre-fix pathology: a decode program that traced the
        # reference einsum where the kernel was requested is caught
        cfg, state = paged_state
        elems = min(int(np.prod((pc.cache.pool.k if pc.cache.pool is not None
                                 else pc.cache.k).shape))
                    for pc in analysis_iter(state))
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2,), jnp.int32)
        jaxpr = dce(trace_jaxpr(
            lambda s: tfm.decode_step(tiny_params, tok, s, tiny_arch, pos,
                                      use_kernel=False), state))
        ctx = LintContext(arena_elems=elems, table_mode=True)
        got = run_passes(jaxpr, ctx, passes=("ref-fallback",))
        assert _rules(got) == ["ref-fallback"]


class TestScalarOutput:
    def test_red_device_scalar_bookkeeping(self):
        def step(arena):
            # the old aux["alpha_count"]: static size returned as f32[]
            return arena * 2.0, jnp.float32(arena.size) + arena.sum() * 0

        got = _findings(step, jnp.zeros(ARENA))
        assert _rules(got) == ["scalar-output"]

    def test_green_vector_metrics(self):
        def step(arena):
            return arena * 2.0, arena.sum(axis=(1, 2, 3))  # per-lane (B,)

        assert not _findings(step, jnp.zeros(ARENA))


def test_allowlist_downgrades_to_info():
    def step(arena):
        return jnp.concatenate([arena, arena], axis=2)

    ctx = LintContext(arena_elems=ELEMS, allow=("arena-pad",))
    got = run_passes(step, ctx, jnp.zeros(ARENA))
    assert got and not gating(got)
    assert all(f.severity == "info" for f in got)


# -- green on the real entry points (the audit sweep is not vacuous) --------


@pytest.fixture(scope="module")
def paged_state(tiny_arch):
    cfg = KVPolicyConfig(kind="dms", cr=2.0, window=4, block_p=8, paged=True)
    return cfg, tfm.init_decode_state(tiny_arch, 2, 32, cfg)


def test_decode_step_lints_clean(tiny_arch, tiny_params, paged_state):
    cfg, state = paged_state
    elems = min(int(np.prod((pc.cache.pool.k if pc.cache.pool is not None
                             else pc.cache.k).shape))
                for pc in analysis_iter(state))
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    for use_kernel in (False, True):
        jaxpr = dce(trace_jaxpr(
            lambda s: tfm.decode_step(tiny_params, tok, s, tiny_arch, pos,
                                      use_kernel=use_kernel), state))
        ctx = LintContext(arena_elems=elems, table_mode=use_kernel)
        assert not gating(run_passes(jaxpr, ctx)), use_kernel


def analysis_iter(state):
    from repro.core import policy as policy_lib
    return policy_lib.iter_policy_caches(state)


def test_fork_reclaim_lint_clean(tiny_arch, paged_state):
    cfg, state = paged_state
    elems = int(np.prod(next(iter(analysis_iter(state))).cache.pool.k.shape))
    ctx = LintContext(arena_elems=elems)
    src = jnp.zeros((2,), jnp.int32)
    assert not gating(run_passes(tfm.gather_lanes, ctx, state, src))
    fresh = tfm.init_decode_state(tiny_arch, 2, 32, cfg)
    assert not gating(run_passes(tfm.reclaim_lanes, ctx, state,
                                 jnp.zeros((2,), bool), fresh))


def test_shared_counters_match_benchmark_semantics():
    # the deduped counters still see through scan into sub-jaxprs
    def scanned_pad(arena):
        def body(c, _):
            return jnp.concatenate(
                [c[:, :, 1:], jnp.ones((2, 2, 1, 4))], axis=2), None
        return jax.lax.scan(body, arena, None, length=3)[0]

    arena = jnp.zeros(ARENA)
    got = analysis.count_arena_copies(scanned_pad, arena, arena_elems=ELEMS)
    assert got["arena_pad_copies"] == 1          # one eqn inside the body
    jaxpr = trace_jaxpr(scanned_pad, arena)
    assert count_big_float_ops(jaxpr, ELEMS) >= 1


# -- contract checkers ------------------------------------------------------


def test_tree_invariance_red_and_green():
    tree = {"k": jnp.zeros((2, 4), jnp.bfloat16), "n": jnp.int32(0)}
    assert not contracts.check_tree_invariance(lambda t: t, tree)
    got = contracts.check_tree_invariance(
        lambda t: {"k": t["k"].astype(jnp.float32), "n": t["n"]}, tree)
    assert _rules(got) == ["tree-state"]
    # structure drift is also a finding, not a crash
    got = contracts.check_tree_invariance(lambda t: {"k": t["k"]}, tree)
    assert _rules(got) == ["tree-state"]


def test_policy_lifecycle_green_all_registered(tiny_arch):
    for name in available_policies():
        cfg = KVPolicyConfig(kind=name, cr=2.0, window=4, block_p=8,
                             quest_page_size=8, quest_top_pages=2)
        got = contracts.check_policy_lifecycle(name, tiny_arch, cfg,
                                               batch=2, max_len=32)
        assert not got, (name, [str(f) for f in got])


def test_policy_lifecycle_red_aval_drift(tiny_arch):
    class Broken(type(get_policy("vanilla"))):
        def decode_update(self, cache, q, k_new, v_new, aux):
            cache, spec = super().decode_update(cache, q, k_new, v_new, aux)
            return jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float16), cache), spec

        def metrics(self, cache):
            return {"live_tokens": np.zeros(())}    # wrong shape + missing

    pol = Broken()
    pol.name = "broken-test"
    _REGISTRY["broken-test"] = pol
    try:
        cfg = KVPolicyConfig(kind="vanilla", cr=2.0, window=4)
        got = contracts.check_policy_lifecycle("broken-test", tiny_arch, cfg,
                                               batch=2, max_len=16)
    finally:
        del _REGISTRY["broken-test"]
    rules = _rules(got)
    assert rules == ["policy-protocol", "tree-state"]
    msgs = " ".join(f.message for f in got)
    assert "live_tokens" in msgs and "reads_tokens" in msgs


def test_sharding_coverage_red_unknown_leaf(tiny_arch):
    mesh = make_local_mesh()
    state = {"mystery_blob": jax.ShapeDtypeStruct((3, 2, 5, 7, 2),
                                                  jnp.float32)}
    got = contracts.check_sharding_coverage(state, mesh, 2, tiny_arch)
    assert _rules(got) == ["sharding-coverage"]
    assert not contracts.check_sharding_coverage(
        state, mesh, 2, tiny_arch, allow=("mystery_blob",))


def test_sharding_coverage_green_real_state(tiny_arch, paged_state):
    _, state = paged_state
    mesh = make_local_mesh()
    assert not contracts.check_sharding_coverage(state, mesh, 2, tiny_arch)


# -- retrace sentinel -------------------------------------------------------


def test_retrace_sentinel_red_shape_retrace():
    @jax.jit
    def f(x):
        return x * 2

    f(jnp.zeros((2,)))                           # warm outside the region
    with RetraceSentinel({"f": f}, exact={"f": 1}) as s:
        f(jnp.zeros((3,)))
        f(jnp.zeros((4,)))                       # second compile = retrace
    assert s.compiles == {"f": 2}
    assert _rules(s.findings()) == ["retrace"]


def test_retrace_sentinel_green_stable_shapes():
    @jax.jit
    def f(x):
        return x + 1

    with RetraceSentinel({"f": f}, budget=1) as s:
        for _ in range(4):
            f(jnp.zeros((5,)))
    assert s.compiles == {"f": 1} and not s.findings()


def test_retrace_sentinel_rejects_non_jit():
    with pytest.raises(TypeError):
        RetraceSentinel({"f": lambda x: x})


# -- host-sync tripwire -----------------------------------------------------


def test_hostsync_red_each_kind():
    x = jnp.arange(4)
    with HostSyncTripwire() as tw:
        np.asarray(x)                            # __array__
        x[0].item()                              # .item()
        jax.device_get(x)                        # device_get
    kinds = [e[0] for e in tw.events]
    assert kinds == ["np.asarray", ".item()", "device_get"]
    assert len(tw.violations()) == 3
    assert all(f.rule == "host-sync" for f in tw.violations())


def test_hostsync_sanctioned_tags():
    x = jnp.arange(4)
    with HostSyncTripwire() as tw:
        with sanctioned("tick-boundary"):
            np.asarray(x)                        # allowed: info, not gating
        with sanctioned("rogue-tag"):
            np.asarray(x)                        # unknown tag still gates
    assert not gating(tw.findings()[:1])
    assert len(tw.violations()) == 1
    assert "rogue-tag" in tw.violations()[0].message


def test_hostsync_unarmed_is_free():
    x = jnp.arange(4)
    with sanctioned("tick-boundary"):
        assert int(np.asarray(x)[0]) == 0        # no tripwire: plain numpy
    tw = HostSyncTripwire()
    assert tw.events == [] and not tw.findings()


# -- the serving contract, end to end ---------------------------------------


def test_scheduler_trace_compile_budget_and_no_host_sync(tiny_arch,
                                                         tiny_params):
    """Randomized mixed trace: prompt lengths, widths, arrivals, and EOS
    vary per request — none of it may retrace the chunk step or sync the
    host outside the tick boundary."""
    rng = np.random.default_rng(11)
    cfg = KVPolicyConfig(kind="dms", cr=2.0, window=4, block_p=8, paged=True)
    eng = Engine(tiny_arch, tiny_params, cfg, chunk=4)
    sched = eng.scheduler(num_lanes=4, max_len=48)
    n_req = 6
    for uid in range(n_req):
        w = int(rng.choice([1, 1, 2]))
        sched.submit(Request(
            uid=uid,
            prompt=rng.integers(1, 97, size=int(rng.integers(2, 11)))
                      .astype(np.int32),
            max_new=int(rng.integers(2, 6)),
            width=w,
            eos_id=(3 if uid % 2 else None),     # EOS may or may not fire
            arrival=int(rng.integers(0, 4))))
    with RetraceSentinel(engine_jits(eng),
                         exact={"chunk": 1},
                         budget={"gather": 1, "reset": 1, "prefill": 0,
                                 "export": 0, "import": 0}) as sentinel, \
            HostSyncTripwire() as tripwire:
        results = sched.run()
    assert len(results) == n_req
    assert sentinel.compiles["chunk"] == 1, sentinel.compiles
    assert not sentinel.findings(), sentinel.compiles
    assert not tripwire.violations(), \
        [f.path for f in tripwire.violations()]
    # the sanctioned tick-boundary sync did happen (the trace is not dead)
    assert any(tag == "tick-boundary" for _, tag, _ in tripwire.events)
