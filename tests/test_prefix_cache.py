"""Cross-request radix prefix cache: exactness, radix/LRU mechanics, the
two-tier (device slab / host LRU) machinery, and honest saved-vs-paid
metering.

Acceptance criteria pinned here:
* for EVERY registered policy, importing a cached L-token prefix snapshot and
  chunk-prefilling only the suffix produces step-0 logits bitwise-equal to a
  cold full prefill (the compressed state at a boundary is complete:
  pending eviction rings, score accumulators, page metadata included) —
  through BOTH tiers: cold-only and with the device-resident hot slab,
* hot-hit / demote-then-cold-hit / promote round-trips are bitwise-equal to
  cold prefill per policy, and hot hits move zero host↔device snapshot
  bytes (asserted from the cache's traffic counters),
* ``export_policy="second-miss"`` exports exactly the boundaries a repeated
  prefix asked for — and nothing at all on single-shot unshared traffic,
* a full-prompt hit skips prefill entirely and still generates identically,
* eviction under a tiny byte budget falls back to cold prefill correctly
  (same outputs, zero saved reads), and a device slab too small for one
  snapshot degrades to the cold tier — never an error,
* per-request meters stay honest: paid + saved == what a cold serve reads.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import KVPolicyConfig
from repro.core.policy import available_policies
from repro.serving.engine import Engine
from repro.serving.prefix_cache import PrefixCache, snapshot_nbytes
from repro.serving.scheduler import Request


def _prompt(n, seed=0, vocab=512):
    return np.random.default_rng(seed).integers(3, vocab, size=(n,)).astype(np.int32)


def _policy_cfg(kind, window):
    return KVPolicyConfig(kind=kind, cr=2.0, budget=12, window=window,
                          quest_page_size=4)


def _serve_one(eng, prompt, max_new, max_len):
    sched = eng.scheduler(num_lanes=1, max_len=max_len)
    sched.submit(Request(uid=0, prompt=prompt, max_new=max_new))
    return sched.run()[0]


# -- the tentpole acceptance: bitwise equivalence per policy ----------------


@pytest.mark.parametrize("device_mb", [0, 64], ids=["cold-tier", "hot-tier"])
@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_prefix_import_suffix_prefill_bitwise_equals_cold(tiny_arch,
                                                          tiny_params, kind,
                                                          device_mb):
    """Serve A = prefix(16) + suffix_a, then B = prefix(16) + suffix_b warm.
    B must hit the chunk-aligned 16-token boundary A exported, and generate
    EXACTLY what a cold serve of B generates — for every policy, including
    the evicting ones whose mid-prompt state is not a truncation; through
    both the host cold tier and the device-slab hot tier."""
    t_pre, max_new = 16, 5
    prefix = _prompt(t_pre, seed=1, vocab=tiny_arch.vocab_size)
    pa = np.concatenate([prefix, _prompt(7, seed=2, vocab=tiny_arch.vocab_size)])
    pb = np.concatenate([prefix, _prompt(9, seed=3, vocab=tiny_arch.vocab_size)])
    cfg = _policy_cfg(kind, tiny_arch.dms.window)
    max_len = len(pb) + max_new

    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64,
                  prefix_cache_device_mb=device_mb)
    ra = _serve_one(warm, pa, max_new, max_len)
    rb = _serve_one(warm, pb, max_new, max_len)
    assert rb.prefill_meter.kv_reads_saved > 0, kind       # actually hit
    if device_mb:
        st = warm.prefix_cache.stats()
        assert st["hot_hits"] > 0, kind                    # via the slab
        # hot path is device-resident: zero host↔device snapshot bytes
        assert st["h2d_bytes"] == 0 and st["d2h_bytes"] == 0, (kind, st)

    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    ca = _serve_one(cold, pa, max_new, max_len)
    cb = _serve_one(cold, pb, max_new, max_len)

    np.testing.assert_array_equal(ra.tokens, ca.tokens, err_msg=kind)
    np.testing.assert_array_equal(rb.tokens, cb.tokens, err_msg=kind)
    # honest metering: paid + saved == cold paid, exactly
    assert rb.prefill_meter.kv_reads + rb.prefill_meter.kv_reads_saved \
        == pytest.approx(cb.prefill_meter.kv_reads), kind


@pytest.mark.parametrize("device_mb", [0, 64], ids=["cold-tier", "hot-tier"])
@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_prefix_import_state_bitwise_equals_cold_state(tiny_arch, tiny_params,
                                                       kind, device_mb):
    """Stronger than logits: after the suffix prefill, EVERY leaf of the
    imported lane's decode state equals the cold-prefill state bitwise —
    whether the snapshot came back from the host tier or the device slab."""
    t_pre = 16
    prefix = _prompt(t_pre, seed=4, vocab=tiny_arch.vocab_size)
    pa = np.concatenate([prefix, _prompt(5, seed=5, vocab=tiny_arch.vocab_size)])
    pb = np.concatenate([prefix, _prompt(6, seed=6, vocab=tiny_arch.vocab_size)])
    cfg = _policy_cfg(kind, tiny_arch.dms.window)
    max_len = len(pb) + 4

    def state_after_prefill(eng, prompt):
        sched = eng.scheduler(num_lanes=1, max_len=max_len)
        sched.submit(Request(uid=0, prompt=prompt, max_new=4))
        sched._admit()
        results = []
        while sched.active_reqs[0].hold_logits is None:
            sched._tick(results)
        return sched.state

    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64,
                  prefix_cache_device_mb=device_mb)
    _serve_one(warm, pa, 4, max_len)                      # seeds the tree
    got = state_after_prefill(warm, pb)
    assert warm.prefix_cache.hits > 0, kind
    if device_mb:
        assert warm.prefix_cache.hot_hits > 0, kind

    ref = state_after_prefill(Engine(tiny_arch, tiny_params, cfg, chunk=8), pb)
    g_l, g_tree = jax.tree_util.tree_flatten(got)
    r_l, r_tree = jax.tree_util.tree_flatten(ref)
    assert g_tree == r_tree
    for a, b in zip(g_l, r_l):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=kind)


def test_full_prompt_hit_skips_prefill_entirely(tiny_arch, tiny_params):
    """Resubmitting an already-served prompt pays ZERO prefill reads: the
    cached boundary logits stand in for the hold-state sample."""
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    p = _prompt(19, seed=7, vocab=tiny_arch.vocab_size)
    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    r1 = _serve_one(warm, p, 5, len(p) + 5)
    r2 = _serve_one(warm, p, 5, len(p) + 5)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r2.prefill_meter.kv_reads == 0.0
    assert r2.prefill_meter.kv_reads_saved \
        == pytest.approx(r1.prefill_meter.kv_reads)


def test_hyperscale_fork_composes_with_prefix_hit(tiny_arch, tiny_params):
    """A width-W request admitted onto a prefix hit forks the imported state:
    every chain matches the cold hyperscale serve."""
    from repro.core.hyperscale import ScalingConfig
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    p = _prompt(16, seed=8, vocab=tiny_arch.vocab_size)
    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    sched = warm.scheduler(num_lanes=4, max_len=24)
    sched.submit(Request(uid=0, prompt=p, max_new=6))
    sched.run()
    sched2 = warm.scheduler(num_lanes=4, max_len=24)
    sched2.submit(Request(uid=1, prompt=p, max_new=6, width=4))
    r = sched2.run()[0]
    assert r.prefill_meter.kv_reads == 0.0                # full hit
    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    ref = cold.hyperscale_generate(p, ScalingConfig(24, 4))
    np.testing.assert_array_equal(r.tokens, ref.tokens[:, :6])


def test_tiny_budget_evicts_and_falls_back_to_cold(tiny_arch, tiny_params):
    """A byte budget too small for any snapshot must behave exactly like no
    cache: every insert rejected, zero hits, identical generations."""
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    eng = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    eng.prefix_cache = PrefixCache(capacity_bytes=64)     # < any snapshot
    p1 = _prompt(17, seed=9, vocab=tiny_arch.vocab_size)
    r1 = _serve_one(eng, p1, 4, len(p1) + 4)
    r2 = _serve_one(eng, p1, 4, len(p1) + 4)              # would be a hit
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r2.prefill_meter.kv_reads_saved == 0.0
    st = eng.prefix_cache.stats()
    assert st["hits"] == 0 and st["entries"] == 0
    # the scheduler skips the export outright (shape-derived snapshot bytes
    # can never fit), so nothing is even offered to the tree
    assert st["inserts"] == 0 and st["bytes"] == 0

    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    c = _serve_one(cold, p1, 4, len(p1) + 4)
    np.testing.assert_array_equal(r2.tokens, c.tokens)
    assert r2.prefill_meter.kv_reads == pytest.approx(c.prefill_meter.kv_reads)


def test_lru_eviction_keeps_recently_used_prefix(tiny_arch, tiny_params):
    """With room for ~one prompt's snapshots, serving prompt A, then A again
    (recency refresh), then B must evict B-or-A by recency — a third serve of
    A must still hit if A was more recently used than the evicted boundary."""
    cfg = _policy_cfg("vanilla", tiny_arch.dms.window)
    eng = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    pa = _prompt(16, seed=10, vocab=tiny_arch.vocab_size)
    pb = _prompt(16, seed=11, vocab=tiny_arch.vocab_size)
    # size the budget from a real snapshot: fits A's two boundaries plus one
    r = _serve_one(Engine(tiny_arch, tiny_params, cfg, chunk=8,
                          prefix_cache_mb=64), pa, 4, 20)
    probe = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    _serve_one(probe, pa, 4, 20)
    per_entry = probe.prefix_cache.total_bytes / max(
        probe.prefix_cache.stats()["entries"], 1)
    eng.prefix_cache = PrefixCache(capacity_bytes=int(per_entry * 3.5))
    _serve_one(eng, pa, 4, 20)                            # A: 2-3 boundaries
    _serve_one(eng, pa, 4, 20)                            # touch A (LRU head)
    _serve_one(eng, pb, 4, 20)                            # B forces eviction
    assert eng.prefix_cache.evictions > 0
    r3 = _serve_one(eng, pa, 4, 20)
    assert r3.prefill_meter.kv_reads_saved > 0            # A survived LRU
    np.testing.assert_array_equal(r3.tokens, r.tokens)


# -- two-tier machinery (device slab hot tier / host cold tier) -------------


def _entry_nbytes(eng, max_len):
    """Per-boundary entry bytes (snapshot + logits row) for this arena
    geometry — shape-derived via a throwaway scheduler, no serving needed."""
    sched = eng.scheduler(num_lanes=1, max_len=max_len)
    return sched._snap_nbytes


@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_hot_roundtrip_demote_promote_bitwise(tiny_arch, tiny_params, kind):
    """A ONE-slot slab forces every tier transition: each boundary insert
    demotes its predecessor (deferred export materialized d2h), later serves
    take cold hits that promote (h2d) and then hit hot (d2d) — and every
    serve stays bitwise-equal to a cold prefill, for every policy."""
    cfg = _policy_cfg(kind, tiny_arch.dms.window)
    prefix = _prompt(16, seed=20, vocab=tiny_arch.vocab_size)
    pa = np.concatenate([prefix, _prompt(7, seed=21, vocab=tiny_arch.vocab_size)])
    pb = np.concatenate([prefix, _prompt(9, seed=22, vocab=tiny_arch.vocab_size)])
    pc8 = np.concatenate([prefix[:8], _prompt(6, seed=23,
                                              vocab=tiny_arch.vocab_size)])
    max_new, max_len = 5, len(pb) + 5

    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    entry_nb = _entry_nbytes(warm, max_len)
    snap_nb = entry_nb - tiny_arch.padded_vocab * 4       # sans logits row
    warm.prefix_cache = PrefixCache(
        64 * 2 ** 20, device_capacity_bytes=entry_nb + entry_nb // 2)
    ra = _serve_one(warm, pa, max_new, max_len)
    st = warm.prefix_cache.stats()
    # boundaries 8 / 16 / 23: all deferred into the slab, two demoted out
    assert st["hot_inserts"] == 3 and st["demotions"] == 2, (kind, st)
    assert st["d2h_bytes"] == 2 * snap_nb, (kind, st)
    rb = _serve_one(warm, pb, max_new, max_len)   # cold hit @16 → promote
    rc = _serve_one(warm, pc8, max_new, max_len)  # cold hit @8 → promote
    st = warm.prefix_cache.stats()
    assert st["promotions"] >= 2 and st["hot_hits"] >= 2, (kind, st)

    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    for r, p in ((ra, pa), (rb, pb), (rc, pc8)):
        c = _serve_one(cold, p, max_new, max_len)
        np.testing.assert_array_equal(r.tokens, c.tokens, err_msg=kind)
        assert r.prefill_meter.kv_reads + r.prefill_meter.kv_reads_saved \
            == pytest.approx(c.prefill_meter.kv_reads), kind


def test_full_prompt_hot_hit_zero_snapshot_bytes(tiny_arch, tiny_params):
    """Resubmitting a served prompt with a hot tier: the full-prompt hit is
    served from the slab with ZERO host↔device snapshot bytes — only the
    O(V) boundary-logits row syncs (metered separately on aux_sync_bytes)."""
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    p = _prompt(19, seed=7, vocab=tiny_arch.vocab_size)
    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64,
                  prefix_cache_device_mb=64)
    r1 = _serve_one(warm, p, 5, len(p) + 5)
    r2 = _serve_one(warm, p, 5, len(p) + 5)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r2.prefill_meter.kv_reads == 0.0
    st = warm.prefix_cache.stats()
    assert st["hot_hits"] == 1 and st["h2d_bytes"] == 0 \
        and st["d2h_bytes"] == 0, st
    assert st["aux_sync_bytes"] == tiny_arch.padded_vocab * 4, st


def test_tiny_device_slab_degrades_to_cold_tier(tiny_arch, tiny_params):
    """A device budget too small for even one snapshot must behave exactly
    like the cold-tier-only cache: no slab, no hot traffic, hits still served
    from host — never an error."""
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    prefix = _prompt(16, seed=24, vocab=tiny_arch.vocab_size)
    pa = np.concatenate([prefix, _prompt(5, seed=25, vocab=tiny_arch.vocab_size)])
    pb = np.concatenate([prefix, _prompt(6, seed=26, vocab=tiny_arch.vocab_size)])
    max_len = len(pb) + 4
    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64,
                  prefix_cache_device_mb=128 / 2 ** 20)    # 128 B < snapshot
    ra = _serve_one(warm, pa, 4, max_len)
    rb = _serve_one(warm, pb, 4, max_len)
    assert rb.prefill_meter.kv_reads_saved > 0            # cold tier hit
    st = warm.prefix_cache.stats()
    assert st["hot_inserts"] == 0 and st["hot_hits"] == 0, st
    assert st["device_bytes"] == 0 and st["promotions"] == 0, st
    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    np.testing.assert_array_equal(rb.tokens,
                                  _serve_one(cold, pb, 4, max_len).tokens)


def test_second_miss_exports_exactly_what_traffic_asked(tiny_arch,
                                                        tiny_params):
    """`export_policy="second-miss"`: single-shot unshared prompts export
    NOTHING; a repeated prefix exports exactly the shared chunk boundaries
    the first request missed on — and nothing deeper."""
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    eng = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64,
                 export_policy="second-miss")
    max_len = 28
    for s in range(3):                                    # unshared singles
        _serve_one(eng, _prompt(20, seed=30 + s,
                                vocab=tiny_arch.vocab_size), 4, max_len)
    assert eng.prefix_cache.inserts == 0                  # zero exports

    prefix = _prompt(16, seed=40, vocab=tiny_arch.vocab_size)
    p1 = np.concatenate([prefix, _prompt(6, seed=41,
                                         vocab=tiny_arch.vocab_size)])
    p2 = np.concatenate([prefix, _prompt(7, seed=42,
                                         vocab=tiny_arch.vocab_size)])
    p3 = np.concatenate([prefix, _prompt(5, seed=43,
                                         vocab=tiny_arch.vocab_size)])
    _serve_one(eng, p1, 4, max_len)
    assert eng.prefix_cache.inserts == 0                  # first miss: record
    _serve_one(eng, p2, 4, max_len)
    # second miss: exports exactly the shared boundaries 8 and 16 — p2's own
    # deeper boundaries were only ever asked for once
    assert eng.prefix_cache.inserts == 2
    sig = eng.scheduler(num_lanes=1, max_len=max_len).signature
    assert eng.prefix_cache.covered(sig, prefix) == 16
    assert eng.prefix_cache.covered(sig, p2) == 16
    r3 = _serve_one(eng, p3, 4, max_len)                  # now a real hit
    assert r3.prefill_meter.kv_reads_saved > 0
    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    c3 = _serve_one(cold, p3, 4, max_len)
    np.testing.assert_array_equal(r3.tokens, c3.tokens)
    assert r3.prefill_meter.kv_reads + r3.prefill_meter.kv_reads_saved \
        == pytest.approx(c3.prefill_meter.kv_reads)


# -- radix tree unit tests --------------------------------------------------


def _mk(tokens):
    return np.asarray(tokens, np.int32)


def _dummy_snap(nbytes=64):
    return {"x": np.zeros((nbytes // 8,), np.float64)}


SIG = ("t", ((1,), "f32"))


def _insert(pc, tokens, reads=1.0, nbytes=64):
    return pc.insert(SIG, _mk(tokens), _dummy_snap(nbytes),
                     np.zeros((4,), np.float32), reads)


def test_radix_lookup_returns_deepest_boundary():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [1, 2, 3, 4], reads=4.0)
    assert _insert(pc, [1, 2, 3, 4, 5, 6], reads=6.0)
    hit = pc.lookup(SIG, _mk([1, 2, 3, 4, 5, 6, 7, 8]))
    assert hit.length == 6 and hit.reads_cum == 6.0
    hit = pc.lookup(SIG, _mk([1, 2, 3, 4, 5, 9]))
    assert hit.length == 4 and hit.reads_cum == 4.0       # diverges after 4
    assert pc.lookup(SIG, _mk([2, 2, 3])) is None
    assert pc.lookup(("other",), _mk([1, 2, 3, 4])) is None   # signature gate


def test_radix_edge_split_on_divergence():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [5, 6, 7, 8])
    assert _insert(pc, [5, 6, 9])                         # splits the edge
    assert pc.lookup(SIG, _mk([5, 6, 7, 8, 1])).length == 4
    assert pc.lookup(SIG, _mk([5, 6, 9, 1])).length == 3
    assert pc.covered(SIG, _mk([5, 6])) == 0              # no entry at split
    assert _insert(pc, [5, 6])                            # boundary at split
    assert pc.covered(SIG, _mk([5, 6])) == 2


def test_radix_never_returns_boundary_past_prompt():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [3, 3, 3, 3, 3, 3])
    assert pc.lookup(SIG, _mk([3, 3, 3])) is None         # entry is deeper
    assert _insert(pc, [3, 3])
    assert pc.lookup(SIG, _mk([3, 3, 3])).length == 2


def test_radix_duplicate_insert_is_noop():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [1, 2], reads=2.0)
    assert not _insert(pc, [1, 2], reads=99.0)
    assert pc.lookup(SIG, _mk([1, 2])).reads_cum == 2.0
    assert pc.stats()["entries"] == 1


def test_lru_evicts_least_recently_used_first():
    pc = PrefixCache(capacity_bytes=3 * 80)               # snap 64 + logits 16
    _insert(pc, [1, 1])
    _insert(pc, [2, 2])
    _insert(pc, [3, 3])
    pc.lookup(SIG, _mk([1, 1]))                           # refresh [1,1]
    _insert(pc, [4, 4])                                   # evicts [2,2]
    assert pc.lookup(SIG, _mk([2, 2])) is None
    assert pc.lookup(SIG, _mk([1, 1])) is not None
    assert pc.lookup(SIG, _mk([4, 4])) is not None
    assert pc.evictions == 1
    assert pc.total_bytes <= pc.capacity_bytes


def test_byte_accounting_tracks_entries():
    pc = PrefixCache(1 << 20)
    snap = _dummy_snap(128)
    logits = np.zeros((4,), np.float32)
    want = snapshot_nbytes(snap) + logits.nbytes
    pc.insert(SIG, _mk([7]), snap, logits, 1.0)
    assert pc.total_bytes == want
    pc.insert(SIG, _mk([7, 8]), snap, logits, 2.0)
    assert pc.total_bytes == 2 * want
    assert pc.stats()["bytes"] == pc.total_bytes


def test_oversized_snapshot_rejected():
    pc = PrefixCache(capacity_bytes=16)
    assert not _insert(pc, [1, 2, 3], nbytes=1024)
    assert pc.stats()["insert_rejects"] == 1
    assert pc.total_bytes == 0


def test_want_export_always_only_skips_covered_boundaries():
    pc = PrefixCache(1 << 20)
    assert pc.want_export(SIG, _mk([1, 2]))               # nothing cached
    _insert(pc, [1, 2])
    assert not pc.want_export(SIG, _mk([1, 2]))           # exactly covered
    assert pc.want_export(SIG, _mk([1, 2, 3]))            # deeper: still wanted


def test_want_export_second_miss_needs_two_askers():
    pc = PrefixCache(1 << 20, export_policy="second-miss")
    p = _mk([1, 2, 3, 4, 5, 6])
    assert not pc.want_export(SIG, p[:2])                 # nobody asked
    pc.lookup(SIG, p)                                     # first miss recorded
    assert not pc.want_export(SIG, p[:2])                 # one asker: its own
    pc.lookup(SIG, p)                                     # second miss
    for depth in (2, 4, 6):                               # incl. mid-edge
        assert pc.want_export(SIG, p[:depth]), depth
    assert not pc.want_export(SIG, _mk([1, 2, 9]))        # nobody went there
    pc.lookup(SIG, _mk([1, 2, 9, 9]))                     # third path shares [1,2]
    assert pc.want_export(SIG, p[:2])
    assert not pc.want_export(SIG, _mk([1, 2, 9]))        # single asker only
    _insert(pc, [1, 2])
    assert not pc.want_export(SIG, p[:2])                 # covered now


def test_want_export_stride_gates_chunk_boundaries():
    """export_stride=N: only every Nth prefill-chunk boundary is offered —
    except the final (full-prompt) one, which is always eligible."""
    pc = PrefixCache(1 << 20, export_stride=2)
    p = _mk([1, 2, 3, 4, 5, 6])
    assert not pc.want_export(SIG, p[:2], chunk_index=1)   # off-stride
    assert pc.want_export(SIG, p[:4], chunk_index=2)       # on-stride
    assert not pc.want_export(SIG, p[:5], chunk_index=3)
    assert pc.want_export(SIG, p, chunk_index=3, final=True)  # full prompt
    # stride 1 (default) gates nothing; callers without a chunk ordinal
    # (direct inserts, tests) are never stride-gated
    assert PrefixCache(1 << 20).want_export(SIG, p[:2], chunk_index=1)
    assert pc.want_export(SIG, p[:2])
    with pytest.raises(ValueError):
        PrefixCache(1 << 20, export_stride=0)


def test_export_stride_bounds_boundary_churn(tiny_arch, tiny_params):
    """End-to-end: a 32-token prompt at chunk 8 exports 4 boundaries at
    stride 1 but only 2 at stride 2 — and the full-prompt boundary is one
    of them, so a repeat prompt still skips prefill entirely and generates
    exactly the cold serve's tokens."""
    prompt = _prompt(32, seed=21, vocab=tiny_arch.vocab_size)
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    max_len = len(prompt) + 5

    def serve(stride):
        eng = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64,
                     export_stride=stride)
        first = _serve_one(eng, prompt, 5, max_len)
        return eng, first

    e1, _ = serve(1)
    e2, _ = serve(2)
    assert e1.prefix_cache.inserts == 4
    assert e2.prefix_cache.inserts == 2                    # chunks 2 and 4
    r2 = _serve_one(e2, prompt, 5, max_len)                # repeat: full hit
    assert r2.prefill_meter.kv_reads == 0.0                # skipped prefill
    cold = _serve_one(Engine(tiny_arch, tiny_params, cfg, chunk=8),
                      prompt, 5, max_len)
    np.testing.assert_array_equal(r2.tokens, cold.tokens)


def test_second_miss_records_survive_pruning_resets():
    """Miss history resets past the record budget: exports are delayed again
    (never wrong), ghost nodes are pruned, and entries survive the reset."""
    import repro.serving.prefix_cache as pcm
    pc = PrefixCache(1 << 20, export_policy="second-miss")
    _insert(pc, [7, 7])
    pc.lookup(SIG, _mk([1, 2, 3]))
    pc.lookup(SIG, _mk([1, 2, 3]))
    assert pc.want_export(SIG, _mk([1, 2]))
    pc._miss_tokens[SIG] = pcm.MISS_RECORD_TOKENS + 1     # force the reset
    pc.lookup(SIG, _mk([9, 9, 9]))
    assert not pc.want_export(SIG, _mk([1, 2]))           # history forgotten
    assert pc.lookup(SIG, _mk([7, 7])) is not None        # entry survived


def test_eviction_prunes_only_the_dead_path():
    """Parent-link pruning: evicting a leaf entry removes exactly its dead
    chain; shared interior nodes and sibling entries stay intact."""
    pc = PrefixCache(capacity_bytes=2 * 80)               # room for 2 entries
    _insert(pc, [1, 1])
    _insert(pc, [1, 1, 2, 2])
    _insert(pc, [1, 1, 3, 3])                             # evicts [1,1] (LRU)
    assert pc.evictions == 1
    # [1,1] survives as an interior split node (it has children) ...
    root = pc._roots[SIG]
    assert sorted(root.children[1].children) == [2, 3]
    assert pc.lookup(SIG, _mk([1, 1, 2, 2])).length == 4  # refresh [.., 2, 2]
    _insert(pc, [5])                                      # evicts [1,1,3,3]
    # ... and the dead [3,3] leaf chain is gone, sibling [2,2] untouched
    assert sorted(root.children[1].children) == [2]
    assert pc.lookup(SIG, _mk([1, 1, 2, 2])).length == 4


# -- hot-tier slab unit tests (dummy snapshots, no model) -------------------


def _dev_snap(val, n=16):
    # snapshot leaves carry (superblock, lane, ...) axes — lane axis width 1
    return {"x": jnp.full((1, 1, n), float(val), jnp.float32)}


def test_hot_tier_slab_store_demote_promote_unit():
    n, snap_nb = 16, 16 * 4
    logits = jnp.zeros((4,), jnp.float32)
    pc = PrefixCache(1 << 20,                             # K = 1 slot
                     device_capacity_bytes=snap_nb + snap_nb // 2)
    assert pc.insert(SIG, _mk([1, 1]), _dev_snap(1.0), logits, 1.0)
    assert pc.hot_inserts == 1 and pc.d2h_bytes == 0      # deferred: no sync
    assert pc.total_bytes == 0                            # not on the host
    assert pc.insert(SIG, _mk([2, 2]), _dev_snap(2.0), logits, 2.0)
    assert pc.demotions == 1 and pc.d2h_bytes == snap_nb  # [1,1] demoted
    h1 = pc.lookup(SIG, _mk([1, 1]))                      # cold → promote
    assert h1.tier == "hot" and pc.promotions == 1
    np.testing.assert_array_equal(
        np.asarray(h1.snapshot["x"]).ravel(), np.full(n, 1.0, np.float32))
    h2 = pc.lookup(SIG, _mk([2, 2]))                      # demoted by promote
    assert h2.tier == "hot" and pc.promotions == 2
    np.testing.assert_array_equal(
        np.asarray(h2.snapshot["x"]).ravel(), np.full(n, 2.0, np.float32))
    assert pc.h2d_bytes == 2 * snap_nb                    # the two promotions


def test_hot_tier_multiple_slots_lru_demotion_order():
    entry_nb = 16 * 4 + 16                                # snapshot + logits
    logits = jnp.zeros((4,), jnp.float32)
    pc = PrefixCache(1 << 20, device_capacity_bytes=2 * entry_nb)  # K = 2
    pc.insert(SIG, _mk([1, 1]), _dev_snap(1.0), logits, 1.0)
    pc.insert(SIG, _mk([2, 2]), _dev_snap(2.0), logits, 2.0)
    pc.lookup(SIG, _mk([1, 1]))                           # [1,1] now MRU
    pc.insert(SIG, _mk([3, 3]), _dev_snap(3.0), logits, 3.0)
    assert pc.demotions == 1                              # [2,2] demoted
    assert pc.lookup(SIG, _mk([1, 1])).tier == "hot"
    assert pc.lookup(SIG, _mk([3, 3])).tier == "hot"


def test_hot_insert_survives_demotion_eviction_prune_race():
    """Inserting a boundary that SPLITS an edge, into a full slab, while the
    host budget is also full: the slot acquisition demotes the hot LRU,
    whose arrival evicts the cold LRU, whose prune chain walks up through
    the freshly split (still entry-less, pre-fix) node.  The new entry must
    stay reachable."""
    entry_nb = 16 * 4 + 16
    logits = jnp.zeros((4,), jnp.float32)
    pc = PrefixCache(capacity_bytes=entry_nb,             # one cold entry
                     device_capacity_bytes=entry_nb)      # K = 1
    assert pc.insert(SIG, _mk([1, 1, 2, 2]), _dev_snap(1.0), logits, 1.0)
    assert pc.insert(SIG, _mk([5]), _dev_snap(2.0), logits, 2.0)
    assert pc.demotions == 1                              # [1,1,2,2] → cold
    # splits [1,1,2,2]'s edge at depth 2; demotes [5]; evicts [1,1,2,2]
    assert pc.insert(SIG, _mk([1, 1]), _dev_snap(3.0), logits, 3.0)
    assert pc.evictions == 1
    hit = pc.lookup(SIG, _mk([1, 1]))
    assert hit is not None and hit.length == 2 and hit.tier == "hot"
    np.testing.assert_array_equal(
        np.asarray(hit.snapshot["x"]).ravel(), np.full(16, 3.0, np.float32))


def test_hot_slab_slot_cap_leaves_budget_for_later_signatures():
    """max_hot_slots bounds one signature's slab so an engine-shared cache
    still has device budget when a second arena geometry shows up."""
    entry_nb = 16 * 4 + 16
    logits = jnp.zeros((4,), jnp.float32)
    pc = PrefixCache(1 << 20, device_capacity_bytes=10 * entry_nb,
                     max_hot_slots=2)
    sig2 = ("t2", ((1,), "f32"))
    assert pc.insert(SIG, _mk([1, 1]), _dev_snap(1.0), logits, 1.0)
    assert pc._device_bytes == 2 * entry_nb               # capped, not 10
    assert pc.insert(sig2, _mk([1, 1]), _dev_snap(5.0), logits, 1.0)
    assert pc.stats()["hot_entries"] == 2                 # both went hot
    assert pc.lookup(sig2, _mk([1, 1])).tier == "hot"


def test_hot_insert_without_host_room_still_works():
    """The slab is its own budget: hot inserts don't consume host bytes, and
    a demotion that can't fit the host budget drops the entry outright."""
    snap_nb = 16 * 4
    logits = jnp.zeros((4,), jnp.float32)
    pc = PrefixCache(capacity_bytes=8,                    # < any snapshot
                     device_capacity_bytes=snap_nb + snap_nb // 2)
    assert pc.insert(SIG, _mk([1, 1]), _dev_snap(1.0), logits, 1.0)
    assert pc.total_bytes == 0
    assert pc.insert(SIG, _mk([2, 2]), _dev_snap(2.0), logits, 2.0)
    # [1,1]'s demotion had nowhere to land: dropped, not an error
    assert pc.demotions == 1 and pc.evictions == 1
    assert pc.lookup(SIG, _mk([1, 1])) is None
    assert pc.lookup(SIG, _mk([2, 2])).tier == "hot"
