"""Cross-request radix prefix cache: exactness, radix/LRU mechanics, and
honest saved-vs-paid metering.

Acceptance criteria pinned here:
* for EVERY registered policy, importing a cached L-token prefix snapshot and
  chunk-prefilling only the suffix produces step-0 logits bitwise-equal to a
  cold full prefill (the compressed state at a boundary is complete:
  pending eviction rings, score accumulators, page metadata included),
* a full-prompt hit skips prefill entirely and still generates identically,
* eviction under a tiny byte budget falls back to cold prefill correctly
  (same outputs, zero saved reads),
* per-request meters stay honest: paid + saved == what a cold serve reads.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import KVPolicyConfig
from repro.core.policy import available_policies
from repro.models import transformer as tfm
from repro.serving.engine import Engine
from repro.serving.prefix_cache import PrefixCache, snapshot_nbytes
from repro.serving.scheduler import Request


def _prompt(n, seed=0, vocab=512):
    return np.random.default_rng(seed).integers(3, vocab, size=(n,)).astype(np.int32)


def _policy_cfg(kind, window):
    return KVPolicyConfig(kind=kind, cr=2.0, budget=12, window=window,
                          quest_page_size=4)


def _serve_one(eng, prompt, max_new, max_len):
    sched = eng.scheduler(num_lanes=1, max_len=max_len)
    sched.submit(Request(uid=0, prompt=prompt, max_new=max_new))
    return sched.run()[0]


# -- the tentpole acceptance: bitwise equivalence per policy ----------------


@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_prefix_import_suffix_prefill_bitwise_equals_cold(tiny_arch,
                                                          tiny_params, kind):
    """Serve A = prefix(16) + suffix_a, then B = prefix(16) + suffix_b warm.
    B must hit the chunk-aligned 16-token boundary A exported, and generate
    EXACTLY what a cold serve of B generates — for every policy, including
    the evicting ones whose mid-prompt state is not a truncation."""
    t_pre, max_new = 16, 5
    prefix = _prompt(t_pre, seed=1, vocab=tiny_arch.vocab_size)
    pa = np.concatenate([prefix, _prompt(7, seed=2, vocab=tiny_arch.vocab_size)])
    pb = np.concatenate([prefix, _prompt(9, seed=3, vocab=tiny_arch.vocab_size)])
    cfg = _policy_cfg(kind, tiny_arch.dms.window)
    max_len = len(pb) + max_new

    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    ra = _serve_one(warm, pa, max_new, max_len)
    rb = _serve_one(warm, pb, max_new, max_len)
    assert rb.prefill_meter.kv_reads_saved > 0, kind       # actually hit

    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    ca = _serve_one(cold, pa, max_new, max_len)
    cb = _serve_one(cold, pb, max_new, max_len)

    np.testing.assert_array_equal(ra.tokens, ca.tokens, err_msg=kind)
    np.testing.assert_array_equal(rb.tokens, cb.tokens, err_msg=kind)
    # honest metering: paid + saved == cold paid, exactly
    assert rb.prefill_meter.kv_reads + rb.prefill_meter.kv_reads_saved \
        == pytest.approx(cb.prefill_meter.kv_reads), kind


@pytest.mark.parametrize("kind", sorted(available_policies()))
def test_prefix_import_state_bitwise_equals_cold_state(tiny_arch, tiny_params,
                                                       kind):
    """Stronger than logits: after the suffix prefill, EVERY leaf of the
    imported lane's decode state equals the cold-prefill state bitwise."""
    t_pre = 16
    prefix = _prompt(t_pre, seed=4, vocab=tiny_arch.vocab_size)
    pa = np.concatenate([prefix, _prompt(5, seed=5, vocab=tiny_arch.vocab_size)])
    pb = np.concatenate([prefix, _prompt(6, seed=6, vocab=tiny_arch.vocab_size)])
    cfg = _policy_cfg(kind, tiny_arch.dms.window)
    max_len = len(pb) + 4

    def state_after_prefill(eng, prompt):
        sched = eng.scheduler(num_lanes=1, max_len=max_len)
        sched.submit(Request(uid=0, prompt=prompt, max_new=4))
        sched._admit()
        results = []
        while sched.active_reqs[0].hold_logits is None:
            sched._tick(results)
        return sched.state

    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    _serve_one(warm, pa, 4, max_len)                      # seeds the tree
    got = state_after_prefill(warm, pb)
    assert warm.prefix_cache.hits > 0, kind

    ref = state_after_prefill(Engine(tiny_arch, tiny_params, cfg, chunk=8), pb)
    g_l, g_tree = jax.tree_util.tree_flatten(got)
    r_l, r_tree = jax.tree_util.tree_flatten(ref)
    assert g_tree == r_tree
    for a, b in zip(g_l, r_l):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=kind)


def test_full_prompt_hit_skips_prefill_entirely(tiny_arch, tiny_params):
    """Resubmitting an already-served prompt pays ZERO prefill reads: the
    cached boundary logits stand in for the hold-state sample."""
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    p = _prompt(19, seed=7, vocab=tiny_arch.vocab_size)
    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    r1 = _serve_one(warm, p, 5, len(p) + 5)
    r2 = _serve_one(warm, p, 5, len(p) + 5)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r2.prefill_meter.kv_reads == 0.0
    assert r2.prefill_meter.kv_reads_saved \
        == pytest.approx(r1.prefill_meter.kv_reads)


def test_hyperscale_fork_composes_with_prefix_hit(tiny_arch, tiny_params):
    """A width-W request admitted onto a prefix hit forks the imported state:
    every chain matches the cold hyperscale serve."""
    from repro.core.hyperscale import ScalingConfig
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    p = _prompt(16, seed=8, vocab=tiny_arch.vocab_size)
    warm = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    sched = warm.scheduler(num_lanes=4, max_len=24)
    sched.submit(Request(uid=0, prompt=p, max_new=6))
    sched.run()
    sched2 = warm.scheduler(num_lanes=4, max_len=24)
    sched2.submit(Request(uid=1, prompt=p, max_new=6, width=4))
    r = sched2.run()[0]
    assert r.prefill_meter.kv_reads == 0.0                # full hit
    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    ref = cold.hyperscale_generate(p, ScalingConfig(24, 4))
    np.testing.assert_array_equal(r.tokens, ref.tokens[:, :6])


def test_tiny_budget_evicts_and_falls_back_to_cold(tiny_arch, tiny_params):
    """A byte budget too small for any snapshot must behave exactly like no
    cache: every insert rejected, zero hits, identical generations."""
    cfg = _policy_cfg("dms", tiny_arch.dms.window)
    eng = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    eng.prefix_cache = PrefixCache(capacity_bytes=64)     # < any snapshot
    p1 = _prompt(17, seed=9, vocab=tiny_arch.vocab_size)
    r1 = _serve_one(eng, p1, 4, len(p1) + 4)
    r2 = _serve_one(eng, p1, 4, len(p1) + 4)              # would be a hit
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r2.prefill_meter.kv_reads_saved == 0.0
    st = eng.prefix_cache.stats()
    assert st["hits"] == 0 and st["entries"] == 0
    # the scheduler skips the export outright (shape-derived snapshot bytes
    # can never fit), so nothing is even offered to the tree
    assert st["inserts"] == 0 and st["bytes"] == 0

    cold = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    c = _serve_one(cold, p1, 4, len(p1) + 4)
    np.testing.assert_array_equal(r2.tokens, c.tokens)
    assert r2.prefill_meter.kv_reads == pytest.approx(c.prefill_meter.kv_reads)


def test_lru_eviction_keeps_recently_used_prefix(tiny_arch, tiny_params):
    """With room for ~one prompt's snapshots, serving prompt A, then A again
    (recency refresh), then B must evict B-or-A by recency — a third serve of
    A must still hit if A was more recently used than the evicted boundary."""
    cfg = _policy_cfg("vanilla", tiny_arch.dms.window)
    eng = Engine(tiny_arch, tiny_params, cfg, chunk=8)
    pa = _prompt(16, seed=10, vocab=tiny_arch.vocab_size)
    pb = _prompt(16, seed=11, vocab=tiny_arch.vocab_size)
    # size the budget from a real snapshot: fits A's two boundaries plus one
    r = _serve_one(Engine(tiny_arch, tiny_params, cfg, chunk=8,
                          prefix_cache_mb=64), pa, 4, 20)
    probe = Engine(tiny_arch, tiny_params, cfg, chunk=8, prefix_cache_mb=64)
    _serve_one(probe, pa, 4, 20)
    per_entry = probe.prefix_cache.total_bytes / max(
        probe.prefix_cache.stats()["entries"], 1)
    eng.prefix_cache = PrefixCache(capacity_bytes=int(per_entry * 3.5))
    _serve_one(eng, pa, 4, 20)                            # A: 2-3 boundaries
    _serve_one(eng, pa, 4, 20)                            # touch A (LRU head)
    _serve_one(eng, pb, 4, 20)                            # B forces eviction
    assert eng.prefix_cache.evictions > 0
    r3 = _serve_one(eng, pa, 4, 20)
    assert r3.prefill_meter.kv_reads_saved > 0            # A survived LRU
    np.testing.assert_array_equal(r3.tokens, r.tokens)


# -- radix tree unit tests --------------------------------------------------


def _mk(tokens):
    return np.asarray(tokens, np.int32)


def _dummy_snap(nbytes=64):
    return {"x": np.zeros((nbytes // 8,), np.float64)}


SIG = ("t", ((1,), "f32"))


def _insert(pc, tokens, reads=1.0, nbytes=64):
    return pc.insert(SIG, _mk(tokens), _dummy_snap(nbytes),
                     np.zeros((4,), np.float32), reads)


def test_radix_lookup_returns_deepest_boundary():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [1, 2, 3, 4], reads=4.0)
    assert _insert(pc, [1, 2, 3, 4, 5, 6], reads=6.0)
    hit = pc.lookup(SIG, _mk([1, 2, 3, 4, 5, 6, 7, 8]))
    assert hit.length == 6 and hit.reads_cum == 6.0
    hit = pc.lookup(SIG, _mk([1, 2, 3, 4, 5, 9]))
    assert hit.length == 4 and hit.reads_cum == 4.0       # diverges after 4
    assert pc.lookup(SIG, _mk([2, 2, 3])) is None
    assert pc.lookup(("other",), _mk([1, 2, 3, 4])) is None   # signature gate


def test_radix_edge_split_on_divergence():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [5, 6, 7, 8])
    assert _insert(pc, [5, 6, 9])                         # splits the edge
    assert pc.lookup(SIG, _mk([5, 6, 7, 8, 1])).length == 4
    assert pc.lookup(SIG, _mk([5, 6, 9, 1])).length == 3
    assert pc.covered(SIG, _mk([5, 6])) == 0              # no entry at split
    assert _insert(pc, [5, 6])                            # boundary at split
    assert pc.covered(SIG, _mk([5, 6])) == 2


def test_radix_never_returns_boundary_past_prompt():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [3, 3, 3, 3, 3, 3])
    assert pc.lookup(SIG, _mk([3, 3, 3])) is None         # entry is deeper
    assert _insert(pc, [3, 3])
    assert pc.lookup(SIG, _mk([3, 3, 3])).length == 2


def test_radix_duplicate_insert_is_noop():
    pc = PrefixCache(1 << 20)
    assert _insert(pc, [1, 2], reads=2.0)
    assert not _insert(pc, [1, 2], reads=99.0)
    assert pc.lookup(SIG, _mk([1, 2])).reads_cum == 2.0
    assert pc.stats()["entries"] == 1


def test_lru_evicts_least_recently_used_first():
    pc = PrefixCache(capacity_bytes=3 * 80)               # snap 64 + logits 16
    _insert(pc, [1, 1])
    _insert(pc, [2, 2])
    _insert(pc, [3, 3])
    pc.lookup(SIG, _mk([1, 1]))                           # refresh [1,1]
    _insert(pc, [4, 4])                                   # evicts [2,2]
    assert pc.lookup(SIG, _mk([2, 2])) is None
    assert pc.lookup(SIG, _mk([1, 1])) is not None
    assert pc.lookup(SIG, _mk([4, 4])) is not None
    assert pc.evictions == 1
    assert pc.total_bytes <= pc.capacity_bytes


def test_byte_accounting_tracks_entries():
    pc = PrefixCache(1 << 20)
    snap = _dummy_snap(128)
    logits = np.zeros((4,), np.float32)
    want = snapshot_nbytes(snap) + logits.nbytes
    pc.insert(SIG, _mk([7]), snap, logits, 1.0)
    assert pc.total_bytes == want
    pc.insert(SIG, _mk([7, 8]), snap, logits, 2.0)
    assert pc.total_bytes == 2 * want
    assert pc.stats()["bytes"] == pc.total_bytes


def test_oversized_snapshot_rejected():
    pc = PrefixCache(capacity_bytes=16)
    assert not _insert(pc, [1, 2, 3], nbytes=1024)
    assert pc.stats()["insert_rejects"] == 1
    assert pc.total_bytes == 0
