"""The unified KVPolicy registry: one pluggable cache-policy API.

Covers the PR's acceptance criteria:
* every registered policy decodes through ``Engine.generate`` with no
  policy-specific code in models/serving,
* Quest budget metering is split correctly (reads shrink, peak does not),
* per-layer policy maps (gemma2-style hybrid caching),
* ``SlotDMSCache.from_prefill``'s pending-ring import matches the masked
  oracle step-by-step for tokens still inside the delay window,
* a new policy registers through the public API alone (the Keyformer path),
* the cross-attention parameter-count fix.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, get_smoke
from repro.core import policy as policy_lib
from repro.core.config import KVPolicyConfig
from repro.core.keyformer import KeyformerCache
from repro.core.kv_cache import MaskedDMSCache, SlotDMSCache
from repro.core.policy import (AttendSpec, KVPolicy, available_policies,
                               get_policy, iter_policy_caches, register_policy)
from repro.models import transformer as tfm
from repro.serving.engine import Engine


BUILTINS = {"vanilla", "dms", "dms_masked", "tova", "h2o", "quest", "dmc",
            "window", "keyformer"}


# tiny_arch / tiny_params come from tests/conftest.py (shared tiny model)


# -- registry ------------------------------------------------------------


def test_registry_has_all_builtin_policies():
    assert BUILTINS.issubset(set(available_policies()))


def test_unknown_policy_is_a_clear_error():
    with pytest.raises(KeyError, match="registered"):
        get_policy("nope")


def test_every_registered_policy_runs_through_engine(tiny_arch, tiny_params):
    """The acceptance gate: all policies generate via the registry alone."""
    prompts = np.random.default_rng(0).integers(
        3, tiny_arch.vocab_size, size=(1, 12)).astype(np.int32)
    for kind in available_policies():
        res = Engine(tiny_arch, tiny_params,
                     KVPolicyConfig(kind=kind, cr=2.0, budget=16)
                     ).generate(prompts, 6)
        assert res.tokens.shape == (1, 6), kind
        assert np.isfinite(res.meter.kv_reads), kind
        assert res.meter.peak_tokens > 0, kind
        assert res.meter.peak_bytes > 0, kind


def test_extension_via_public_api_only(tiny_arch, tiny_params):
    """Register a brand-new policy here, in test code — zero edits anywhere.

    (Keyformer is the in-tree proof; this guards the mechanism itself.)"""

    @register_policy("_test_last8")
    class Last8Policy(KVPolicy):
        def init_cache(self, arch, batch, max_len, cfg, *, layer_window, dtype):
            a = arch.attn
            return SlotDMSCache.init(batch, a.num_kv_heads, 8 + 1, a.head_dim,
                                     max(arch.dms.window, 1), dtype,
                                     dms_active=False)

        def decode_update(self, cache, q, k_new, v_new, aux):
            alpha = jnp.zeros(k_new.shape[:2], bool)
            cache = cache.step(k_new, v_new, alpha)
            return cache, AttendSpec(cache.k, cache.v, cache.valid_mask(),
                                     cache.positions())

    try:
        res = Engine(tiny_arch, tiny_params,
                     KVPolicyConfig(kind="_test_last8")).generate(
            np.ones((1, 12), np.int32) * 3, 6)
        assert res.tokens.shape == (1, 6)
        assert res.meter.peak_tokens <= 9 * tiny_arch.num_layers
    finally:
        policy_lib._REGISTRY.pop("_test_last8", None)


# -- budget metering (Quest regression) ----------------------------------


def test_quest_meters_reads_not_size(tiny_arch, tiny_params):
    """Quest reduces KV *reads*, not cache size: kv_reads must drop below
    vanilla while peak_tokens stays identical (the seed metered live tokens
    on both axes, hiding Quest's entire effect)."""
    prompts = np.random.default_rng(1).integers(
        3, tiny_arch.vocab_size, size=(1, 24)).astype(np.int32)
    res_v = Engine(tiny_arch, tiny_params,
                   KVPolicyConfig(kind="vanilla")).generate(prompts, 16)
    res_q = Engine(tiny_arch, tiny_params,
                   KVPolicyConfig(kind="quest", quest_page_size=4,
                                  quest_top_pages=2)).generate(prompts, 16)
    assert res_q.meter.kv_reads < res_v.meter.kv_reads
    assert res_q.meter.peak_tokens == pytest.approx(res_v.meter.peak_tokens)


def test_metrics_contract_uniform_across_policies(tiny_arch):
    """metrics() returns the same keys for every policy; peak_bytes is
    shape-derived and positive."""
    for kind in available_policies():
        cfg = KVPolicyConfig(kind=kind, cr=2.0, budget=8)
        state = tfm.init_decode_state(tiny_arch, 1, 16, cfg)
        for pc in iter_policy_caches(state):
            m = get_policy(pc.policy).peak_bytes(pc.cache)
            assert isinstance(m, int) and m > 0, kind
        assert policy_lib.state_peak_bytes(state) > 0, kind


# -- per-layer policy maps ------------------------------------------------


def test_layer_map_assigns_policies_per_layer_kind():
    arch = get_smoke("gemma2-2b")        # ("attn_local", "attn") pattern
    cfg = KVPolicyConfig(kind="dms", cr=2.0,
                         layer_map={"attn_local": "window", "attn": "dms"})
    assert cfg.kind_for_layer("attn_local") == "window"
    assert cfg.kind_for_layer("attn") == "dms"
    assert cfg.kind_for_layer("other") == "dms"
    state = tfm.init_decode_state(arch, 1, 16, cfg)
    assert sorted({pc.policy for pc in iter_policy_caches(state)}) == \
        ["dms", "window"]


def test_layer_map_decodes_end_to_end():
    arch = get_smoke("gemma2-2b")
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    cfg = KVPolicyConfig(kind="vanilla", budget=8,
                         layer_map={"attn": "tova"})
    prompts = np.random.default_rng(2).integers(
        3, arch.vocab_size, size=(1, 10)).astype(np.int32)
    res = Engine(arch, params, cfg).generate(prompts, 4)
    assert res.tokens.shape == (1, 4)
    assert np.isfinite(res.meter.kv_reads)


def test_layer_map_is_hashable():
    cfg = KVPolicyConfig(kind="dms", layer_map={"attn_local": "window"})
    assert isinstance(cfg.layer_map, tuple)
    hash(cfg)  # jit-static requirement


# -- keyformer ------------------------------------------------------------


def test_keyformer_respects_budget_and_recency():
    budget, recent = 8, 4
    c = KeyformerCache.init(1, 1, budget + 1, 4, recent, tau=1.0)
    k = jnp.ones((1, 1, 1, 4))
    for i in range(24):
        c = c.insert(k * (i + 1), k * (i + 1))
        n = int(jnp.sum(c.valid))
        w = jnp.ones((1, 1, budget + 1)) / max(n, 1)
        c = c.accumulate_and_evict(w)
    assert int(c.retained_tokens()[0, 0]) <= budget
    pos = set(np.asarray(c.pos[0, 0])[np.asarray(c.valid[0, 0])].tolist())
    # the recency window is always protected (Keyformer keeps recent + heavy)
    assert {23 - i for i in range(recent)}.issubset(pos)


def test_keyformer_noise_is_deterministic():
    c1 = KeyformerCache.init(1, 1, 5, 4, 2, tau=1.0)
    c2 = KeyformerCache.init(1, 1, 5, 4, 2, tau=1.0)
    k = jnp.ones((1, 1, 1, 4))
    w = jnp.full((1, 1, 5), 0.2)
    for _ in range(8):
        c1 = c1.insert(k, k).accumulate_and_evict(w)
        c2 = c2.insert(k, k).accumulate_and_evict(w)
    np.testing.assert_array_equal(np.asarray(c1.valid), np.asarray(c2.valid))
    np.testing.assert_allclose(np.asarray(c1.score), np.asarray(c2.score))


# -- prefill import (pending-ring path) -----------------------------------


def _dms_stream(seed, t, b=1, h=2, dh=4, p_evict=0.4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(ks[0], (t, b, h, 1, dh))
    v = jax.random.normal(ks[1], (t, b, h, 1, dh))
    a = jax.random.bernoulli(ks[2], p_evict, (t, b, h))
    return k, v, a


@pytest.mark.parametrize("seed,window", [(0, 3), (1, 5), (2, 2)])
def test_from_prefill_pending_ring_matches_masked_decode(seed, window):
    """Prefill-imported SlotDMSCache == MaskedDMSCache continued step-by-step:
    decisions for tokens still inside the delay window must execute on
    schedule via the imported pending ring (the ``alpha_bin is not None``
    branch of ``from_prefill``)."""
    t_pre, t_dec, b, h, dh = 12, 8, 1, 2, 4
    total = t_pre + t_dec
    k, v, a = _dms_stream(seed, total, b=b, h=h, dh=dh)

    mc = MaskedDMSCache.init(b, h, total, dh, window)
    for i in range(t_pre):
        mc = mc.step(k[i], v[i], a[i])

    # prefill outputs: full post-"RoPE" k/v, the retained map, raw alpha
    k_full = jnp.concatenate([k[i] for i in range(t_pre)], axis=2)  # (B,H,T,Dh)
    v_full = jnp.concatenate([v[i] for i in range(t_pre)], axis=2)
    alpha_full = jnp.stack([a[i] for i in range(t_pre)], axis=2)    # (B,H,T)
    written = (jnp.arange(total) < t_pre)[None, None]
    retained = jnp.asarray(mc.valid_mask() & written)[:, :, :t_pre]
    sc = SlotDMSCache.from_prefill(
        k_full, v_full, jnp.arange(t_pre, dtype=jnp.int32), retained,
        window, num_slots=t_pre + t_dec + 1, alpha_bin=alpha_full)

    assert (mc.retained_tokens() == sc.retained_tokens()).all()
    for i in range(t_pre, total):
        mc = mc.step(k[i], v[i], a[i])
        sc = sc.step(k[i], v[i], a[i])
        assert (mc.retained_tokens() == sc.retained_tokens()).all(), i
        for bb in range(b):
            for hh in range(h):
                mpos = set(np.where(np.asarray(mc.valid_mask()[bb, hh]))[0].tolist())
                spos = set(np.asarray(sc.pos[bb, hh])[np.asarray(sc.valid[bb, hh])].tolist())
                assert mpos == spos, (i, bb, hh)


def test_dms_policy_prefill_import_via_protocol(tiny_arch):
    """The same path through the public KVPolicy.prefill_import hook."""
    pol = get_policy("dms")
    b, h, dh, t = 1, tiny_arch.attn.num_kv_heads, tiny_arch.attn.head_dim, 10
    cfg = KVPolicyConfig(kind="dms", cr=1.0)
    k = jax.random.normal(jax.random.PRNGKey(0), (b, h, t, dh))
    retained = jnp.ones((b, h, t), bool)
    cache = pol.prefill_import(
        tiny_arch, cfg, k, k, jnp.arange(t, dtype=jnp.int32), retained, None,
        max_len=t + 6)
    assert int(cache.length[0]) == t
    assert (cache.retained_tokens() == t).all()


# -- chunked prefill (scheduler path) --------------------------------------


@pytest.mark.parametrize("kind", sorted(BUILTINS))
def test_chunked_prefill_matches_per_token_scan(tiny_arch, tiny_params, kind):
    """The scheduler's T-chunked prefill must be state-identical to the
    per-token ``lax.scan`` reference for every policy — including TOVA/H2O,
    whose budgets force mid-prompt eviction (prompt 13 > budget 8), and a
    chunk size (8) that does not divide the prompt length."""
    from repro.serving.scheduler import Request

    t0 = 13
    prompt = np.random.default_rng(7).integers(
        3, tiny_arch.vocab_size, size=(t0,)).astype(np.int32)
    cfg = KVPolicyConfig(kind=kind, cr=2.0, budget=8,
                         window=tiny_arch.dms.window, quest_page_size=4)
    eng = Engine(tiny_arch, tiny_params, cfg, chunk=8)

    ref = tfm.init_decode_state(tiny_arch, 1, t0 + 4, cfg)
    ref = eng._prefill_jit(eng.params, jnp.asarray(prompt[None]), ref, t=t0)

    sched = eng.scheduler(num_lanes=1, max_len=t0 + 4)
    sched.submit(Request(uid=0, prompt=prompt, max_new=4))
    sched._admit()
    results = []
    while sched.active_reqs[0].hold_logits is None:
        sched._tick(results)

    ref_l, ref_tree = jax.tree_util.tree_flatten(ref)
    got_l, got_tree = jax.tree_util.tree_flatten(sched.state)
    assert ref_tree == got_tree
    for a, b in zip(ref_l, got_l):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=kind)


# -- config fixes ---------------------------------------------------------


def test_cross_attention_param_count_counts_decoder_layers():
    """Regression: `n += self.encoder_layers and ...` (boolean short-circuit)
    undercounted encoder-decoder rooflines by the full cross-attn stack."""
    arch = get_arch("seamless-m4t-large-v2")
    assert arch.cross_attention and arch.encoder_layers
    a = arch.attn
    per_cross = (arch.d_model * a.num_heads * a.head_dim * 2
                 + arch.d_model * a.num_kv_heads * a.head_dim * 2)
    no_cross = dataclasses.replace(arch, cross_attention=False)
    assert arch.param_count() - no_cross.param_count() == \
        arch.num_layers * per_cross
