"""Graceful degradation when `hypothesis` is not installed.

Property-based tests use the real library when available (see
requirements-dev.txt); without it, each ``@given`` test degrades to a single
pytest skip instead of erroring the whole collection — the rest of the suite
still runs.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without the dep
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **kw: None

    st = _AnyStrategy()

    def given(*_a, **_kw):
        def deco(fn):
            # zero-arg replacement: the strategy params must not be mistaken
            # for pytest fixtures
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn
