"""End-to-end behaviour tests: the paper's pipeline on CPU-scale models.

1. retrofit a tiny LM with DMS (distillation + CR schedule) — α rises,
   distill loss stays sane (no collapse),
2. serve with the compressed cache — budget metrics shrink by ~CR,
3. fault tolerance: checkpoint + resume mid-training.
"""
import dataclasses

import numpy as np

from repro.core.config import DMSConfig, KVPolicyConfig
from repro.data.pipeline import DataConfig
from repro.serving.engine import Engine
from repro.train.loop import TrainConfig, train


# tiny_arch comes from tests/conftest.py — one shared tiny model across the
# registry / scheduler / prefix-cache / system suites


def test_retrofit_increases_alpha_and_tracks_teacher(tiny_arch):
    data = DataConfig(vocab_size=tiny_arch.vocab_size, seq_len=64,
                      global_batch=8, seed=1)
    out = train(tiny_arch, data,
                TrainConfig(total_steps=50, retrofit=True, log_every=5,
                            ckpt_every=1000))
    hist = out["history"]
    assert hist[-1]["alpha_mean"] > 0.15, hist[-1]       # compression learned
    assert hist[-1]["alpha_mean"] > hist[0]["alpha_mean"] + 0.1
    assert np.isfinite(hist[-1]["loss_main"])
    # the distillation loss must not explode as compression ramps
    assert hist[-1]["loss_main"] < hist[0]["loss_main"] * 10 + 1.0


def test_pretrain_loss_decreases(tiny_arch):
    arch = dataclasses.replace(tiny_arch, dms=DMSConfig(enabled=False))
    data = DataConfig(vocab_size=arch.vocab_size, seq_len=64, global_batch=8)
    out = train(arch, data, TrainConfig(total_steps=80, log_every=5))
    hist = out["history"]
    assert hist[-1]["ce"] < hist[0]["ce"] - 0.1


def test_engine_budget_shrinks_with_dms(tiny_arch, tiny_params):
    """Paper core claim, measured: DMS reduces both KV reads and peak tokens
    vs vanilla for the same generation length."""
    params = tiny_params
    prompts = np.random.default_rng(0).integers(3, tiny_arch.vocab_size,
                                                size=(2, 24)).astype(np.int32)
    res_v = Engine(tiny_arch, params, KVPolicyConfig(kind="vanilla")
                   ).generate(prompts, 16)
    res_d = Engine(tiny_arch, params, KVPolicyConfig(kind="dms", cr=2.0)
                   ).generate(prompts, 16)
    assert res_d.meter.peak_tokens <= res_v.meter.peak_tokens
    assert res_d.meter.kv_reads <= res_v.meter.kv_reads
    assert res_v.tokens.shape == res_d.tokens.shape == (2, 16)


def test_engine_policies_run(tiny_arch, tiny_params):
    params = tiny_params
    prompts = np.random.default_rng(0).integers(3, tiny_arch.vocab_size,
                                                size=(1, 12)).astype(np.int32)
    for kind in ["vanilla", "dms", "tova", "h2o", "quest", "dmc"]:
        res = Engine(tiny_arch, params,
                     KVPolicyConfig(kind=kind, cr=2.0, budget=16)
                     ).generate(prompts, 6)
        assert res.tokens.shape == (1, 6), kind
        assert np.isfinite(res.meter.kv_reads), kind


def test_checkpoint_resume_mid_training(tiny_arch, tmp_path):
    """Fault tolerance: stop at step 20, resume, reach the full step count."""
    arch = dataclasses.replace(tiny_arch, dms=DMSConfig(enabled=False))
    data = DataConfig(vocab_size=arch.vocab_size, seq_len=32, global_batch=4)
    cfg = TrainConfig(total_steps=20, ckpt_every=10, ckpt_dir=str(tmp_path),
                      log_every=5)
    train(arch, data, cfg)
    cfg2 = dataclasses.replace(cfg, total_steps=30)
    out2 = train(arch, data, cfg2)
    assert out2["resumed_from"] == 20
    assert out2["history"][-1]["step"] == 29
