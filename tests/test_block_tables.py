"""Block-table flash-decode: incremental-table invariants and per-policy
kernel parity (docs/kernels.md).

Two contracts are pinned here:

* **incremental == recomputed** — every cache that maintains a
  :class:`~repro.core.kv_cache.BlockTable` incrementally (SlotDMS, Masked
  DMS, TOVA, H2O, Keyformer) must, after ANY random insert/evict trace,
  hold exactly the canonical table recomputed from its ``valid`` bitmap
  (same per-block counts, same live-block set, consistent inverse index).
* **kernel parity through the table** — for all 9 registry policies, the
  block-table kernel path produces the same attention output as the
  ``_masked_decode`` reference on fragmented arenas (free-list holes, GQA
  ratios, odd logical P, bf16), and Quest's page-sparse ``use_kernel=True``
  serving path is token-equal to the reference serve.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, policy as policy_lib
from repro.core.config import KVPolicyConfig
from repro.core.keyformer import KeyformerCache
from repro.core.kv_cache import BlockTable, MaskedDMSCache, SlotDMSCache
from repro.models.attention import _masked_decode

BP = 8


# -- canonical-form oracle ---------------------------------------------------


def assert_table_canonical(bt: BlockTable, valid):
    """The incremental table must match the from_valid recomputation up to
    table order: identical counts and live-block sets, consistent pos."""
    ref = BlockTable.from_valid(jnp.asarray(valid), bt.block_p)
    np.testing.assert_array_equal(np.asarray(bt.count), np.asarray(ref.count))
    np.testing.assert_array_equal(np.asarray(bt.n), np.asarray(ref.n))
    b, h, nb = bt.count.shape
    tbl, pos, n = np.asarray(bt.tbl), np.asarray(bt.pos), np.asarray(bt.n)
    cnt = np.asarray(bt.count)
    for bi in range(b):
        for hi in range(h):
            live = set(np.where(cnt[bi, hi] > 0)[0].tolist())
            listed = set(tbl[bi, hi, :n[bi, hi]].tolist())
            assert listed == live, (bi, hi, listed, live)
            for blk in range(nb):
                if blk in live:
                    assert tbl[bi, hi, pos[bi, hi, blk]] == blk, (bi, hi, blk)
                else:
                    assert pos[bi, hi, blk] == -1, (bi, hi, blk)


def _kv_stream(seed, t, b=2, h=2, dh=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    k = jax.random.normal(ks[0], (t, b, h, 1, dh))
    v = jax.random.normal(ks[1], (t, b, h, 1, dh))
    a = jax.random.bernoulli(ks[2], 0.5, (t, b, h))
    return k, v, a


# -- incremental == recomputed under random traces ---------------------------


@pytest.mark.parametrize("seed,num_slots", [(0, 24), (1, 19), (2, 9)])
def test_slot_dms_incremental_table(seed, num_slots):
    """Random eviction streams, including arenas small enough to overflow
    (recycle path) and odd logical sizes (physical padding)."""
    t = 30
    k, v, a = _kv_stream(seed, t)
    c = SlotDMSCache.init(2, 2, num_slots, 8, window=3, block_p=BP)
    assert c.k.shape[2] % BP == 0
    for i in range(t):
        c = c.step(k[i], v[i], a[i])
        assert_table_canonical(c.blocks, c.valid)


def test_slot_dms_table_under_jit_scan():
    t = 16
    k, v, a = _kv_stream(3, t)
    c0 = SlotDMSCache.init(2, 2, 17, 8, window=3, block_p=BP)

    def body(c, xs):
        kk, vv, aa = xs
        return c.step(kk, vv, aa), None

    c, _ = jax.jit(lambda c: jax.lax.scan(body, c, (k, v, a)))(c0)
    assert_table_canonical(c.blocks, c.valid)


def test_masked_dms_incremental_table():
    t = 24
    k, v, a = _kv_stream(4, t)
    c = MaskedDMSCache.init(2, 2, t, 8, window=3, block_p=BP)
    for i in range(t):
        c = c.step(k[i], v[i], a[i])
        assert_table_canonical(c.blocks, c.valid_mask())


@pytest.mark.parametrize("kind", ["tova", "h2o", "keyformer"])
def test_weight_evict_incremental_table(kind, nprng):
    b, h, dh, budget = 2, 2, 8, 11
    if kind == "tova":
        c = baselines.TOVACache.init(b, h, budget + 1, dh, block_p=BP)
    elif kind == "h2o":
        c = baselines.H2OCache.init(b, h, budget + 1, dh, 3, block_p=BP)
    else:
        c = KeyformerCache.init(b, h, budget + 1, dh, 3, 1.0, block_p=BP)
    key = jax.random.PRNGKey(5)
    for i in range(24):
        key, k1, k2 = jax.random.split(key, 3)
        c = c.insert(jax.random.normal(k1, (b, h, 1, dh)),
                     jax.random.normal(k2, (b, h, 1, dh)))
        w = jnp.asarray(nprng.random((b, h, c.k.shape[2])), jnp.float32)
        c = c.accumulate_and_evict(w) if kind == "keyformer" else c.evict(w)
        assert_table_canonical(c.blocks, c.valid)
        assert int(c.retained_tokens().max()) <= budget + 1


def test_from_valid_matches_incremental_reclaim():
    """A reclaimed (pristine) table reads as empty."""
    c = SlotDMSCache.init(1, 2, 16, 8, window=3, block_p=BP)
    k, v, a = _kv_stream(6, 5, b=1)
    for i in range(5):
        c = c.step(k[i], v[i], a[i])
    pol = policy_lib.get_policy("dms")
    fresh = SlotDMSCache.init(1, 2, 16, 8, window=3, block_p=BP)
    c = pol.reclaim_cache(c, jnp.ones((1,), bool), fresh)
    assert int(c.blocks.n.sum()) == 0
    assert_table_canonical(c.blocks, c.valid)


# -- kernel parity across all 9 policies on fragmented arenas ---------------

ALL_POLICIES = ["vanilla", "window", "dms", "dms_masked", "tova", "h2o",
                "quest", "dmc", "keyformer"]


def _policy_cache_after_steps(tiny_arch, kind, steps, dtype, batch=2,
                              max_len=40, paged=False):
    """Fragment a registry policy's cache with a random decode trace; return
    (cache pytree, last AttendSpec, q used at the last step, attn cfg)."""
    arch = dataclasses.replace(tiny_arch, dtype=dtype)
    cfg = KVPolicyConfig(kind=kind, cr=2.0, window=arch.dms.window,
                         block_p=BP, quest_page_size=BP, paged=paged)
    pc = policy_lib.init_policy_cache(arch, batch, max_len, cfg)
    pol = policy_lib.get_policy(pc.policy)
    a = arch.attn
    dt = jnp.dtype(arch.dtype)
    key = jax.random.PRNGKey(17)
    cache, spec, q = pc.cache, None, None
    for i in range(steps):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        q = jax.random.normal(k1, (batch, 1, a.num_heads, a.head_dim), dt)
        k_new = jax.random.normal(k2, (batch, a.num_kv_heads, 1, a.head_dim), dt)
        v_new = jax.random.normal(k3, (batch, a.num_kv_heads, 1, a.head_dim), dt)
        aux = {"alpha_bin": jax.random.bernoulli(
                   k4, 0.5, (batch, a.num_kv_heads)),
               "pos_t": jnp.full((batch,), i, jnp.int32),
               "attn_cfg": a, "arch": arch, "dtype": dt}
        cache, spec = pol.decode_update(cache, q, k_new, v_new, aux)
        if spec.needs_weights:
            w = jax.random.uniform(k4, spec.visible.shape, jnp.float32)
            cache = pol.post_attend(cache, jnp.where(spec.visible, w, 0.0))
    return cache, spec, q, a


@pytest.mark.parametrize("kind", ALL_POLICIES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_policy_parity_kernel_vs_ref(tiny_arch, kind, dtype):
    """Every policy's AttendSpec drives the block-table kernel to the same
    output as the masked-softmax reference — fragmented arenas, GQA, padded
    physical extents, bf16."""
    _, spec, q, acfg = _policy_cache_after_steps(tiny_arch, kind, 18, dtype)
    if spec.block_p:
        assert spec.block_tbl is not None
        assert spec.k.shape[2] % spec.block_p == 0
    out_k, w_k, impl_k = _masked_decode(q, spec, None, acfg, use_kernel=True,
                                        need_weights=spec.needs_weights)
    out_r, w_r, impl_r = _masked_decode(q, spec, None, acfg, use_kernel=False,
                                        need_weights=spec.needs_weights)
    assert (impl_k, impl_r) == ("kernel", "ref")
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == "bfloat16" else \
        dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **tol)
    if spec.needs_weights:
        np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), **tol)


@pytest.mark.parametrize("kind", ["tova", "h2o", "keyformer"])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("paged", [False, True])
def test_weights_out_parity(tiny_arch, kind, dtype, paged):
    """The kernel's weights-out path returns the exact group-summed softmax
    the reference computes — fragmented tables, GQA, {fixed, paged} layouts.
    These weights drive eviction, so parity here is what makes
    ``use_kernel=True`` serving token-equal for the score-based policies."""
    _, spec, q, acfg = _policy_cache_after_steps(tiny_arch, kind, 18, dtype,
                                                 paged=paged)
    assert spec.needs_weights and spec.block_tbl is not None
    out_k, w_k, _ = _masked_decode(q, spec, None, acfg, use_kernel=True,
                                   need_weights=True)
    out_r, w_r, _ = _masked_decode(q, spec, None, acfg, use_kernel=False,
                                   need_weights=True)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == "bfloat16" else \
        dict(rtol=2e-5, atol=2e-5)
    assert w_k.shape == spec.visible.shape == w_r.shape
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_r), **tol)
    # weights on invisible slots are exactly zero on BOTH paths (the scatter
    # drops dead table rows; the reference masks to NEG_INF pre-softmax)
    dead = ~np.asarray(spec.visible)
    assert not np.asarray(w_k)[dead].any()
    assert not np.asarray(w_r)[dead].any()


@pytest.mark.parametrize("kind", ALL_POLICIES)
def test_policy_window_layer_masking(tiny_arch, kind):
    """Every registry policy must supply slot positions so ``layer_map``
    window layers can mask — and the window must actually zero attention
    (and returned weights) on slots older than ``pos_t - window``.  DMC
    historically returned ``positions=None`` and silently attended beyond
    the window on window layers; its entries now carry their newest
    contribution's position."""
    steps, window = 12, 4
    _, spec, q, acfg = _policy_cache_after_steps(tiny_arch, kind, steps,
                                                 "float32")
    assert spec.positions is not None, \
        f"{kind}: no positions — window layers would attend beyond the window"
    b = q.shape[0]
    pos_t = jnp.full((b,), steps - 1, jnp.int32)
    for use_kernel in (False, True):
        _, w, _ = _masked_decode(q, spec, window, acfg,
                                 use_kernel=use_kernel, pos_t=pos_t,
                                 need_weights=True)
        w = np.asarray(w)
        pos = np.asarray(jnp.broadcast_to(spec.positions, spec.visible.shape))
        old = pos <= (steps - 1 - window)
        assert not w[old].any(), f"{kind}: weight on slots beyond the window"
        # the window never hides everything: the newest entry is inside it
        assert (w.sum(axis=-1) > 0.5).all(), f"{kind}: window hid all slots"


@pytest.mark.parametrize("kind", ALL_POLICIES)
def test_policy_table_covers_visibility(tiny_arch, kind):
    """Contract: every visible slot lies in a block listed in the table —
    the kernel may then mask within blocks, but may never miss one."""
    _, spec, _, _ = _policy_cache_after_steps(tiny_arch, kind, 18, "float32")
    if not spec.block_p:
        pytest.skip(f"{kind}: no block table")
    vis = np.asarray(jnp.broadcast_to(
        spec.visible, spec.k.shape[:3]))
    tbl, n = np.asarray(spec.block_tbl), np.asarray(spec.block_n)
    b, h, p = vis.shape
    for bi in range(b):
        for hi in range(h):
            listed = set(tbl[bi, hi, :n[bi, hi]].tolist())
            needed = set((np.where(vis[bi, hi])[0] // spec.block_p).tolist())
            assert needed <= listed, (kind, bi, hi, needed - listed)


def test_quest_kernel_fetches_only_selected_pages(tiny_arch):
    """Quest's table is the top-k page selection: the modeled fetch is
    top_pages blocks, far below the arena — reads-sparsity as real traffic."""
    from repro.kernels.dms_decode import ops as dkops
    cache, spec, _, _ = _policy_cache_after_steps(
        tiny_arch, "quest", 30, "float32", max_len=64)
    assert spec.block_p == BP
    n_pages = cache.kmin.shape[2]
    fetched = dkops.modeled_hbm_bytes(spec.block_n, spec.block_p, 16,
                                      jnp.float32, jnp.float32)
    dense = spec.k.shape[0] * spec.k.shape[1] * n_pages * BP * 16 * 2 * 4
    assert int(np.asarray(spec.block_n).max()) <= cache.top_pages
    assert fetched < dense


def test_quest_scheduler_smoke_use_kernel(tiny_arch, tiny_params):
    """End-to-end: Quest serving through the page-sparse kernel path is
    token-equal to the reference decode path."""
    from repro.serving.engine import Engine
    prompts = np.random.default_rng(9).integers(
        3, tiny_arch.vocab_size, size=(2, 11)).astype(np.int32)
    cfg = KVPolicyConfig(kind="quest", cr=2.0, quest_page_size=8,
                         window=tiny_arch.dms.window)
    res_k = Engine(tiny_arch, tiny_params, cfg,
                   use_kernel=True).generate(prompts, 5)
    res_r = Engine(tiny_arch, tiny_params, cfg).generate(prompts, 5)
    np.testing.assert_array_equal(res_k.tokens, res_r.tokens)
    assert np.isfinite(res_k.meter.kv_reads)


@pytest.mark.parametrize("kind", ["tova", "h2o", "keyformer"])
def test_weight_policy_scheduler_smoke_use_kernel(tiny_arch, tiny_params,
                                                  kind):
    """End-to-end: the score-based eviction policies serve through the
    weights-out kernel path token-equal to the reference decode path —
    the silent ``needs_weights`` fallback is gone, so ``use_kernel=True``
    here really means the Pallas kernel (pinned by the audit's
    ``ref-fallback`` lint and the ``attn_impl_kernel`` step metric).

    Token equality is a per-trace pin, not a universal guarantee: these
    policies *evict by the returned weights*, and the kernel's blockwise
    softmax differs from the dense reference by float reassociation ulps,
    so a near-tied eviction argmin can legitimately flip on some traces
    (the per-dtype weights tolerance in ``test_weights_out_parity`` is the
    numerical contract).  The seed is chosen tie-free for all three."""
    from repro.serving.engine import Engine
    prompts = np.random.default_rng(3).integers(
        3, tiny_arch.vocab_size, size=(2, 11)).astype(np.int32)
    cfg = KVPolicyConfig(kind=kind, cr=2.0, window=tiny_arch.dms.window,
                         block_p=BP)
    res_k = Engine(tiny_arch, tiny_params, cfg,
                   use_kernel=True).generate(prompts, 5)
    res_r = Engine(tiny_arch, tiny_params, cfg).generate(prompts, 5)
    np.testing.assert_array_equal(res_k.tokens, res_r.tokens)
    assert np.isfinite(res_k.meter.kv_reads)


@pytest.mark.parametrize("kind", ["tova", "vanilla"])
def test_decode_step_reports_attn_impl(tiny_arch, tiny_params, kind):
    """``decode_step``'s aux pins which attention implementation was traced:
    1 iff every attention layer went through the Pallas kernel.  A silent
    kernel→reference fallback (the bug this PR removes) flips it to 0."""
    from repro.models import transformer as tfm
    cfg = KVPolicyConfig(kind=kind, cr=2.0, window=tiny_arch.dms.window,
                         block_p=BP)
    state = tfm.init_decode_state(tiny_arch, 2, 16, cfg)
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    _, _, aux_k = tfm.decode_step(tiny_params, tok, state, tiny_arch, pos,
                                  use_kernel=True)
    _, _, aux_r = tfm.decode_step(tiny_params, tok, state, tiny_arch, pos,
                                  use_kernel=False)
    assert int(aux_k["attn_impl_kernel"]) == 1
    assert int(aux_r["attn_impl_kernel"]) == 0
