"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, output shapes + no NaNs; decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_arch, get_smoke
from repro.core.config import KVPolicyConfig
from repro.models import transformer as tfm
from repro.launch import steps as steps_lib
from repro.optim import adamw


def _batch_for(arch, b=2, t=32, key=None):
    key = key or jax.random.PRNGKey(0)
    kwargs = {}
    tokens = jax.random.randint(key, (b, t), 0, arch.vocab_size)
    if arch.frontend == "vision_patches" and arch.frontend_tokens:
        kwargs["frontend_embeds"] = jnp.zeros((b, arch.frontend_tokens, arch.d_model),
                                              jnp.bfloat16)
        tokens = tokens[:, : t - arch.frontend_tokens]
    if arch.encoder_layers:
        kwargs["enc_embeds"] = jax.random.normal(key, (b, 16, arch.d_model)) * 0.02
    return tokens, kwargs


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_smoke_forward(name):
    arch = get_smoke(name)
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    tokens, kwargs = _batch_for(arch)
    mode = "dms_train" if arch.dms.enabled else "vanilla"
    logits, aux = tfm.model_forward(params, tokens, arch, mode=mode,
                                    rng=jax.random.PRNGKey(1), **kwargs)
    b = tokens.shape[0]
    t_total = tokens.shape[1] + (arch.frontend_tokens
                                 if arch.frontend == "vision_patches" else 0)
    assert logits.shape == (b, t_total, arch.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), name
    if arch.dms.enabled and arch.attn is not None:
        assert float(aux["alpha_count"]) > 0


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_smoke_train_step(name):
    arch = get_smoke(name)
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    opt_state = adamw.init(params)
    step_fn = steps_lib.make_train_step(
        arch, adamw.AdamWConfig(lr=1e-3), dms_train=arch.dms.enabled)
    tokens, kwargs = _batch_for(arch)
    t_total = tokens.shape[1] + (arch.frontend_tokens
                                 if arch.frontend == "vision_patches" else 0)
    batch = {"tokens": tokens,
             "labels": jax.random.randint(jax.random.PRNGKey(2),
                                          (tokens.shape[0], t_total), 0,
                                          arch.vocab_size), **kwargs}
    p2, o2, metrics = step_fn(params, opt_state, batch, jnp.asarray(0, jnp.int32))
    assert np.isfinite(float(metrics["loss"])), name
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params)[:4],
                        jax.tree_util.tree_leaves(p2)[:4]))
    assert changed, name


@pytest.mark.parametrize("name", ["phi3-mini-3.8b", "gemma2-2b",
                                  "recurrentgemma-2b", "mamba2-2.7b",
                                  "seamless-m4t-large-v2"])
def test_decode_matches_full_forward(name):
    """Teacher-forced decode == full forward (vanilla policy)."""
    arch = get_smoke(name)
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    b, t = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, arch.vocab_size)
    kwargs = {}
    enc_out = None
    if arch.encoder_layers:
        enc = jax.random.normal(jax.random.PRNGKey(3), (b, 8, arch.d_model)) * 0.02
        kwargs["enc_embeds"] = enc
        enc_out = tfm.encode(params, enc, arch)
    full, _ = tfm.model_forward(params, tokens, arch, **kwargs)
    state = tfm.init_decode_state(arch, b, t, KVPolicyConfig(kind="vanilla"))
    outs = []
    for i in range(t):
        lg, state, _ = tfm.decode_step(params, tokens[:, i:i + 1], state, arch,
                                       jnp.asarray(i, jnp.int32), enc_out=enc_out)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32), rtol=0.12, atol=0.12)


def test_dms_decode_matches_masked_reference():
    """SlotDMSCache decode == MaskedDMSCache decode for the same model."""
    arch = get_smoke("phi3-mini-3.8b")
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    b, t = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, arch.vocab_size)
    s_slot = tfm.init_decode_state(arch, b, t, KVPolicyConfig(kind="dms", cr=1.0))
    s_mask = tfm.init_decode_state(arch, b, t, KVPolicyConfig(kind="dms_masked"))
    for i in range(t):
        l1, s_slot, _ = tfm.decode_step(params, tokens[:, i:i + 1], s_slot, arch,
                                        jnp.asarray(i, jnp.int32))
        l2, s_mask, _ = tfm.decode_step(params, tokens[:, i:i + 1], s_mask, arch,
                                        jnp.asarray(i, jnp.int32))
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32), rtol=0.05, atol=0.05)


@pytest.mark.parametrize("name", PAPER_ARCHS)
def test_paper_archs_smoke(name):
    arch = get_smoke(name)
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    tokens, kwargs = _batch_for(arch)
    logits, _ = tfm.model_forward(params, tokens, arch, mode="dms_train",
                                  rng=jax.random.PRNGKey(1), **kwargs)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", ASSIGNED_ARCHS)
def test_full_configs_match_assignment(name):
    """The full configs carry the exact assigned hyper-parameters."""
    a = get_arch(name)
    expect = {
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab_size=50280),
        "granite-moe-3b-a800m": dict(num_layers=32, d_model=1536, vocab_size=49155),
        "granite-moe-1b-a400m": dict(num_layers=24, d_model=1024, vocab_size=49155),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560, vocab_size=256000),
        "qwen2-vl-7b": dict(num_layers=28, d_model=3584, vocab_size=152064),
        "gemma2-2b": dict(num_layers=26, d_model=2304, vocab_size=256000),
        "chatglm3-6b": dict(num_layers=28, d_model=4096, vocab_size=65024),
        "phi3-mini-3.8b": dict(num_layers=32, d_model=3072, vocab_size=32064),
        "minitron-4b": dict(num_layers=32, d_model=3072, vocab_size=256000),
        "seamless-m4t-large-v2": dict(num_layers=24, d_model=1024, vocab_size=256206),
    }[name]
    for k, v in expect.items():
        assert getattr(a, k) == v, (name, k)
    heads = {
        "granite-moe-3b-a800m": (24, 8), "granite-moe-1b-a400m": (16, 8),
        "recurrentgemma-2b": (10, 1), "qwen2-vl-7b": (28, 4),
        "gemma2-2b": (8, 4), "chatglm3-6b": (32, 2),
        "phi3-mini-3.8b": (32, 32), "minitron-4b": (24, 8),
        "seamless-m4t-large-v2": (16, 16),
    }
    if a.attn is not None:
        assert (a.attn.num_heads, a.attn.num_kv_heads) == heads[name]
    if name.startswith("granite"):
        assert a.mlp.moe.top_k == 8
        assert a.mlp.moe.num_experts == (40 if "3b" in name else 32)
    if name == "mamba2-2.7b":
        assert a.ssm.d_state == 128 and a.attn is None
