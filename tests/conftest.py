import dataclasses
import os
import sys

# tests see the real (1-device) CPU topology — only the dry-run forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import transformer as tfm


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def nprng():
    return np.random.default_rng(0)


# One tiny model shared by every suite (registry / scheduler / prefix-cache /
# system): session-scoped so params init once, with the DMS knobs every suite
# needs (short delay window, CPU-scale CR ramp for the retrofit test).
@pytest.fixture(scope="session")
def tiny_arch():
    arch = get_smoke("qwen-r1-1.5b")
    return dataclasses.replace(
        arch, dms=dataclasses.replace(arch.dms, window=4, target_cr=4.0,
                                      steps_per_cr_unit=5))


@pytest.fixture(scope="session")
def tiny_params(tiny_arch):
    return tfm.init_model(jax.random.PRNGKey(0), tiny_arch)
