import os
import sys

# tests see the real (1-device) CPU topology — only the dry-run forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def nprng():
    return np.random.default_rng(0)
