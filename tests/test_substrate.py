"""Substrate tests: data determinism, checkpoint manager, optimizer,
gradient compression, hyper-scaling accounting, sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.core import hyperscale as hs
from repro.data.pipeline import DataConfig, make_batch
from repro.data import tasks
from repro.optim import adamw, compress


# -- data ---------------------------------------------------------------


def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=8, seed=3)
    b1 = make_batch(cfg, step=5)
    b2 = make_batch(cfg, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, step=6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # shards are independent and disjoint in RNG space
    s0 = make_batch(cfg, step=5, shard=0, num_shards=2)
    s1 = make_batch(cfg, step=5, shard=1, num_shards=2)
    assert s0["tokens"].shape == (4, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_data_microbatched_shape():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=8, accum_steps=4)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (4, 2, 16)


def test_data_learnable_structure():
    """The Markov stream has real next-token signal (≈75% follow prob)."""
    cfg = DataConfig(vocab_size=32, seq_len=256, global_batch=4, seed=0)
    b = make_batch(cfg, 0)
    toks, labels = b["tokens"], b["labels"]
    perm_rng = np.random.default_rng(cfg.seed + 1)
    perm = perm_rng.permutation(cfg.vocab_size)
    follow = (perm[toks] == labels).mean()
    assert follow > 0.6


def test_task_answers_verifiable():
    cfg = tasks.TaskConfig(kind="chain_arith", chain_len=4)
    prompts, answers = tasks.make_eval_set(cfg, 16)
    assert prompts.shape == (16, cfg.prompt_len)
    assert (answers >= tasks.FIRST_SYM).all()
    n = tasks.TaskConfig(kind="needle")
    p2, a2 = tasks.make_eval_set(n, 8)
    # the needle (answer) is present in each prompt
    for i in range(8):
        assert a2[i] in p2[i]


# -- checkpointing ------------------------------------------------------


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree_util.tree_map(lambda x: x * s, tree))
    assert mgr.steps() == [2, 3]           # keep-last-2 retention
    restored, step, _ = mgr.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]) * 3)


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=3)
    tree = {"w": jnp.ones((128, 128))}
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 7
    assert not list(tmp_path.glob("*.tmp"))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.ones((5,))})


# -- optimizer ----------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                            total_steps=100, grad_clip=None)
    for _ in range(60):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = adamw.apply_updates(params, g, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_grad_clip():
    params = {"w": jnp.zeros((3,))}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(grad_clip=1.0, warmup_steps=0)
    g = {"w": jnp.full((3,), 100.0)}
    _, _, m = adamw.apply_updates(params, g, state, cfg)
    assert float(m["grad_norm"]) > 100.0      # reported pre-clip


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_int8_compression_error_feedback_unbiased(seed):
    """Residual carry: the *sum* of dequantised updates converges to the sum
    of the true values (error feedback keeps compression unbiased)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10))
    res = None
    total_sent = jnp.zeros_like(g)
    for _ in range(20):
        q, s, res = compress.compress_grads({"g": g}, {"g": res["g"]} if res else None)
        total_sent = total_sent + compress.dequantize_int8(q["g"], s["g"])
        res = {"g": res["g"]}
    np.testing.assert_allclose(np.asarray(total_sent / 20), np.asarray(g),
                               rtol=0.02, atol=float(jnp.abs(g).max()) * 0.02)


def test_int8_quantize_bounds():
    x = jnp.asarray([-1000.0, 0.0, 1000.0])
    q, s = compress.quantize_int8(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(compress.dequantize_int8(q, s)),
                               np.asarray(x), rtol=0.02)


# -- hyper-scaling accounting --------------------------------------------


def test_budget_meter_matches_analytic():
    m = hs.BudgetMeter()
    window, cr, layers = 4, 2.0, 3
    live = 0.0
    for t in range(1, 33):
        live = t if t <= window else window + (t - window) / cr
        m.observe_step([live * layers])
    reads, peak = hs.analytic_budget(32, 1, cr, layers, window)
    assert m.kv_reads == pytest.approx(reads, rel=1e-6)
    assert m.peak_tokens == pytest.approx(peak, rel=1e-6)


def test_pareto_frontier_monotone():
    pts = [(1, 0.2), (2, 0.1), (3, 0.5), (4, 0.4), (8, 0.9)]
    f = hs.pareto_frontier(pts)
    assert f == [(1, 0.2), (3, 0.5), (8, 0.9)]


def test_frontier_margin_positive_for_dominating():
    a = [(1, 0.5), (10, 0.9)]
    b = [(1, 0.3), (10, 0.7)]
    assert hs.frontier_margin(a, b) == pytest.approx(0.2, abs=1e-6)


def test_majority_vote():
    assert hs.majority_vote(["7", "3", "7", None]) == "7"
    assert hs.majority_vote([None, None]) is None


# -- sharding rules (pure logic) ------------------------------------------


def test_param_specs_divisibility():
    """Every sharded dim divides the mesh axis for every arch."""
    from repro.configs import ASSIGNED_ARCHS, get_arch
    from repro.launch import steps as steps_lib
    from repro.parallel.sharding import param_spec

    tp = 16
    for name in ASSIGNED_ARCHS:
        arch = get_arch(name)
        shapes = steps_lib.params_spec(arch)
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in flat:
            keys = tuple(str(getattr(p, "name", getattr(p, "key", p)))
                         for p in path)
            spec = param_spec(keys, leaf.shape, arch, tp)
            for dim, s in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if s == "model":
                    assert dim % tp == 0, (name, keys, leaf.shape, spec)
