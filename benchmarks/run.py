"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--check]

``--check`` is regression mode: suites run as usual but their saved metric
payloads are captured (baselines under ``artifacts/bench`` are NOT
overwritten) and compared against those baselines with tolerances —
wall-clock keys are skipped, everything else (saved-reads identities,
hit-path byte-traffic counters, hit rates) must agree within ``--rtol``.
Exit 1 on drift; with ``--only`` a missing baseline is also a failure
(the explicit gate must not be vacuous), a full sweep skips suites whose
baselines aren't committed.  Re-record a baseline by running the suite
WITHOUT ``--check`` and committing the JSON
(``artifacts/bench/prefix_cache.json`` and
``artifacts/bench/decode_path.json`` are git-tracked today).

Suites (↔ paper artifact):
    latency_model     Appendix G / Fig. 7 (TPU re-derivation)
    roofline_table    40-cell dry-run roofline collation (§Roofline)
    cr_profile        Fig. 6 (CR vs position, per-layer retention)
    ablation_eviction Fig. 5 left (delayed vs immediate)
    data_efficiency   Fig. 5 right (DMS vs immediate/DMC objective)
    cr_sweep          Table 1 (method × CR on needle task)
    pareto            Fig. 3 / Fig. 4 (accuracy vs budget frontiers)
    continuous_batching  serving: scheduler vs lockstep, shared-prefill fork
    prefix_cache      serving: cross-request radix prefix reuse (shared
                      system prompt, two-tier hot path, single-shot export
                      gating, multi-turn chat traces)
    decode_path       kernel: block-table flash-decode HBM traffic ∝ live
                      tokens (fill/CR/fragmentation sweeps, zero-copy step
                      path — see docs/kernels.md)
    paged_arena       serving: paged KV block pool — footprint ∝ live
                      tokens, 4x lanes per byte budget, zero-copy CoW fork
                      (see docs/serving.md)
    preemption        serving: preemptive lane eviction under an
                      oversubscribed pool — bitwise snapshot resume, zero
                      re-prefill, deterministic lifecycle counters (see
                      docs/serving.md "Failure semantics & preemption")
    slo_harness       serving: SLO-driven overload control — the same 2x
                      burst trace with and without the degradation ladder;
                      gates the goodput win, zero-prefill sheds, and solo
                      token equality of degraded requests (see
                      docs/serving.md "SLO & overload control")
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts (CI mode)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--check", action="store_true",
                    help="compare fresh metrics against artifacts/bench "
                         "baselines instead of overwriting them")
    ap.add_argument("--rtol", type=float, default=0.1,
                    help="relative tolerance for --check comparisons")
    args = ap.parse_args(argv)

    from benchmarks import common
    from benchmarks import (ablation_eviction, continuous_batching, cr_profile,
                            cr_sweep, data_efficiency, decode_path,
                            latency_model, paged_arena, pareto, preemption,
                            prefix_cache, roofline_table, slo_harness)
    suites = {
        "latency_model": latency_model.run,
        "roofline_table": roofline_table.run,
        "cr_profile": cr_profile.run,
        "ablation_eviction": ablation_eviction.run,
        "data_efficiency": data_efficiency.run,
        "cr_sweep": cr_sweep.run,
        "pareto": pareto.run,
        "continuous_batching": continuous_batching.run,
        "prefix_cache": prefix_cache.run,
        "decode_path": decode_path.run,
        "paged_arena": paged_arena.run,
        "preemption": preemption.run,
        "slo_harness": slo_harness.run,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if k == args.only}
    common.set_check_mode(args.check)
    failed = []
    for name, fn in suites.items():
        t0 = time.time()
        print(f"# === {name} ===", file=sys.stderr)
        try:
            fn(quick=args.quick)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:
            traceback.print_exc()
            failed.append((name, repr(e)))
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        return 1
    if args.check:
        import json
        problems = []
        compared = 0
        for name, payload in sorted(common.CAPTURED.items()):
            base_path = common.ARTIFACTS / f"{name}.json"
            if not base_path.exists():
                # with --only the caller explicitly asked to gate THIS
                # suite: a vacuously-green gate is worse than a red one.
                # A full sweep just skips suites with no committed baseline.
                if args.only:
                    problems.append(f"{name}: no baseline at {base_path} "
                                    "(run without --check to record it)")
                else:
                    print(f"# check: no baseline for {name} — skipped",
                          file=sys.stderr)
                continue
            baseline = json.loads(base_path.read_text())
            problems += common.compare_to_baseline(name, payload, baseline,
                                                   rtol=args.rtol)
            compared += 1
        if problems:
            print("# CHECK FAILED:", file=sys.stderr)
            for p in problems:
                print(f"#   {p}", file=sys.stderr)
            return 1
        print(f"# check OK: {compared} suite payload(s) within "
              f"rtol={args.rtol} of artifacts/bench baselines",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
