"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Suites (↔ paper artifact):
    latency_model     Appendix G / Fig. 7 (TPU re-derivation)
    roofline_table    40-cell dry-run roofline collation (§Roofline)
    cr_profile        Fig. 6 (CR vs position, per-layer retention)
    ablation_eviction Fig. 5 left (delayed vs immediate)
    data_efficiency   Fig. 5 right (DMS vs immediate/DMC objective)
    cr_sweep          Table 1 (method × CR on needle task)
    pareto            Fig. 3 / Fig. 4 (accuracy vs budget frontiers)
    continuous_batching  serving: scheduler vs lockstep, shared-prefill fork
    prefix_cache      serving: cross-request radix prefix reuse (shared
                      system prompt + multi-turn chat traces)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import (ablation_eviction, continuous_batching, cr_profile,
                            cr_sweep, data_efficiency, latency_model, pareto,
                            prefix_cache, roofline_table)
    suites = {
        "latency_model": latency_model.run,
        "roofline_table": roofline_table.run,
        "cr_profile": cr_profile.run,
        "ablation_eviction": ablation_eviction.run,
        "data_efficiency": data_efficiency.run,
        "cr_sweep": cr_sweep.run,
        "pareto": pareto.run,
        "continuous_batching": continuous_batching.run,
        "prefix_cache": prefix_cache.run,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if k == args.only}
    failed = []
    for name, fn in suites.items():
        t0 = time.time()
        print(f"# === {name} ===", file=sys.stderr)
        try:
            fn(quick=args.quick)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:
            traceback.print_exc()
            failed.append((name, repr(e)))
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
