"""Benchmark ↔ paper Fig. 3 / Fig. 4: accuracy vs KV-reads / peak-tokens
Pareto frontiers under L-W-CR inference-time scaling.

A tiny reasoning model is trained on chain-arithmetic with verifiable
answers, retrofitted with DMS, then evaluated over a grid of
(length, width, CR) configurations with *measured* budget metrics from the
real cache states.  The paper's qualitative claim to reproduce: the DMS
frontier dominates vanilla at equal budget (more chains affordable for the
same KV reads / peak memory).
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit, save_json
from repro.configs import get_smoke
from repro.core.config import DMSConfig, KVPolicyConfig
from repro.core.policy import available_policies
from repro.core.hyperscale import ScalingConfig, frontier_margin, pareto_frontier
from repro.data import tasks
from repro.serving.engine import Engine, evaluate_hyperscale
from repro.models import transformer as tfm
from repro.optim import adamw


def _trained_reasoner(steps=260, window=4, target_cr=4.0, seed=0):
    """Train a tiny model on chain_arith, then DMS-retrofit it."""
    arch = get_smoke("qwen-r1-1.5b")
    arch = dataclasses.replace(
        arch, vocab_size=64,
        dms=DMSConfig(enabled=True, window=window, target_cr=target_cr,
                      steps_per_cr_unit=max(steps // 8, 5)))
    task = tasks.TaskConfig(kind="chain_arith", vocab_size=64,
                            prompt_len=32, chain_len=5, seed=seed)

    # supervised pretrain on the task (vanilla attention)
    base = dataclasses.replace(arch, dms=DMSConfig(enabled=False))
    params = tfm.init_model(jax.random.PRNGKey(seed), base)
    opt = adamw.init(params)
    from repro.launch import steps as steps_lib
    import jax.numpy as jnp
    step_fn = jax.jit(steps_lib.make_train_step(
        base, adamw.AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=steps)),
        donate_argnums=(0, 1))
    for s in range(steps):
        b = tasks.make_train_batch(task, s, 32)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        params, opt, m = step_fn(params, opt, batch, jnp.asarray(s, jnp.int32))

    # DMS retrofit via distillation (paper §4) on the same data
    from repro.core import distill as distill_lib
    teacher = jax.tree_util.tree_map(jnp.copy, params)
    ropt = adamw.init(params)
    rstep = jax.jit(steps_lib.make_retrofit_step(
        arch, adamw.AdamWConfig(lr=1e-3, warmup_steps=10,
                                total_steps=steps // 2)),
        donate_argnums=(0, 2))
    for s in range(steps // 2):
        b = tasks.make_train_batch(task, 10_000 + s, 32)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        params, ropt, m = rstep(params, teacher, ropt, batch,
                                jnp.asarray(s, jnp.int32))
    return arch, params, task, float(m["alpha_mean"])


def run(n_eval=24, quick=False):
    arch, params, task, alpha = _trained_reasoner(steps=120 if quick else 260)
    prompts, answers = tasks.make_eval_set(task, n_eval)
    grid = [ScalingConfig(task.prompt_len + 8, w, 1.0) for w in (1, 2, 4)]
    results = {}
    # enumerate the full KVPolicy registry: every policy gets a frontier,
    # with per-policy kv_reads/peak_tokens from the uniform metrics() contract
    for label in available_policies():
        policy = KVPolicyConfig(
            kind=label,
            cr=arch.dms.target_cr if label.startswith("dms") else 2.0,
            window=arch.dms.window, quest_page_size=4)
        engine = Engine(arch, params, policy, temperature=0.7)
        pts = []
        for cfg in grid:
            r = evaluate_hyperscale(engine, prompts, answers, cfg)
            pts.append(r)
            emit(f"pareto/{label}/{cfg.label}", 0.0, r)
        results[label] = pts

    front = {k: pareto_frontier([(p["kv_reads"], p["accuracy"]) for p in v])
             for k, v in results.items()}
    margin = frontier_margin(front["dms"], front["vanilla"])
    mfront = {k: pareto_frontier([(p["peak_tokens"], p["accuracy"]) for p in v])
              for k, v in results.items()}
    mmargin = frontier_margin(mfront["dms"], mfront["vanilla"])
    summary = {"alpha_mean": alpha,
               "margin_reads_dms_vs_vanilla": margin,
               "margin_peak_dms_vs_vanilla": mmargin}
    emit("pareto/summary", 0.0, summary)
    save_json("pareto", {"results": results, "summary": summary})
    return summary


if __name__ == "__main__":
    run()
