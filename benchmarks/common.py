"""Shared benchmark harness utilities."""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Dict, List

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def emit(name: str, us_per_call: float, derived: Dict) -> str:
    """CSV row per the harness contract: name,us_per_call,derived."""
    row = f"{name},{us_per_call:.2f},{json.dumps(derived, sort_keys=True)}"
    print(row)
    return row


def save_json(name: str, payload) -> None:
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / f"{name}.json").write_text(json.dumps(payload, indent=1))


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6
