"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, Dict, List

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "bench"

# --check mode: suites still compute and emit everything, but save_json
# captures payloads here instead of overwriting the baselines they are about
# to be compared against (see benchmarks.run --check)
_CHECK = {"enabled": False}
CAPTURED: Dict[str, dict] = {}

#: metric keys never compared against baselines: wall-clock is machine-local
SKIP_KEY_TOKENS = ("us_", "_us", "wall")


def set_check_mode(enabled: bool) -> None:
    _CHECK["enabled"] = bool(enabled)
    CAPTURED.clear()


def emit(name: str, us_per_call: float, derived: Dict) -> str:
    """CSV row per the harness contract: name,us_per_call,derived."""
    row = f"{name},{us_per_call:.2f},{json.dumps(derived, sort_keys=True)}"
    print(row)
    return row


def save_json(name: str, payload) -> None:
    if _CHECK["enabled"]:
        CAPTURED[name] = payload
        return
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / f"{name}.json").write_text(json.dumps(payload, indent=1))


def _skip_key(key: str) -> bool:
    k = key.lower()
    return any(tok in k for tok in SKIP_KEY_TOKENS)


def compare_to_baseline(name: str, fresh, baseline, rtol: float = 0.1,
                        _path: str = "") -> List[str]:
    """Recursively compare a fresh metrics payload against its recorded
    baseline.  Numeric leaves must agree within ``rtol`` (wall-clock keys
    are skipped); added or removed keys are reported too, so metric-schema
    drift forces a deliberate baseline re-record.  Returns human-readable
    problem strings (empty == regression-free)."""
    problems: List[str] = []
    loc = f"{name}{_path}"
    if isinstance(baseline, dict) or isinstance(fresh, dict):
        if not (isinstance(baseline, dict) and isinstance(fresh, dict)):
            return [f"{loc}: structure changed "
                    f"({type(baseline).__name__} -> {type(fresh).__name__})"]
        for key in sorted(set(baseline) | set(fresh)):
            if _skip_key(key):
                continue
            if key not in fresh:
                problems.append(f"{loc}.{key}: missing from fresh run")
            elif key not in baseline:
                problems.append(f"{loc}.{key}: not in baseline "
                                "(re-record artifacts/bench)")
            else:
                problems += compare_to_baseline(name, fresh[key],
                                                baseline[key], rtol=rtol,
                                                _path=f"{_path}.{key}")
        return problems
    if isinstance(baseline, bool) or isinstance(fresh, bool) \
            or not isinstance(baseline, (int, float)) \
            or not isinstance(fresh, (int, float)):
        if fresh != baseline:
            problems.append(f"{loc}: {baseline!r} -> {fresh!r}")
        return problems
    tol = rtol * max(abs(baseline), 1e-12)
    if abs(fresh - baseline) > tol:
        problems.append(
            f"{loc}: baseline={baseline!r} fresh={fresh!r} "
            f"|Δ|={abs(fresh - baseline):.6g} exceeds "
            f"tolerance {tol:.6g} (rtol={rtol:.0%} of baseline)")
    return problems


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6
