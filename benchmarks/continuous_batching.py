"""Benchmark: continuous batching vs lockstep serving.

Serves a trace of staggered-arrival, mixed-prompt-length, EOS-early-exit
requests two ways and compares *honest* budget accounting:

* **continuous** — the scheduler: mid-flight admission into reclaimed lanes,
  chunked prefill interleaved with decode, per-request meters.
* **lockstep (seed behaviour)** — pad every prompt to the longest, decode
  every chain the full ``max_new``: what ``Engine.generate`` did before the
  scheduler existed.  Its KV reads are what the seed engine would have
  *reported*, biased by dead lanes and W× re-prefill.

Also measures the shared-prefill fork: hyperscale W=4 prefill reads vs W
independent prefills.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.configs import get_smoke
from repro.core.config import KVPolicyConfig
from repro.core.hyperscale import ScalingConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine
from repro.serving.scheduler import Request


def _trace(rng, n, pmax, vocab):
    return [rng.integers(3, vocab, size=(int(rng.integers(pmax // 2, pmax + 1)),)
                         ).astype(np.int32) for _ in range(n)]


def run(policy_kind="dms", n_requests=6, num_lanes=3, pmax=24, max_new=12,
        quick=False):
    arch = get_smoke("qwen-r1-1.5b")
    arch = dataclasses.replace(
        arch, dms=dataclasses.replace(arch.dms, window=4))
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    policy = KVPolicyConfig(kind=policy_kind, cr=2.0, window=arch.dms.window)
    engine = Engine(arch, params, policy)
    rng = np.random.default_rng(0)
    prompts = _trace(rng, n_requests, pmax, arch.vocab_size)
    eos_id = 7  # arbitrary: some chains will emit it, some won't

    def serve_continuous():
        sched = engine.scheduler(num_lanes=num_lanes, max_len=pmax + max_new)
        for i, p in enumerate(prompts):
            sched.submit(Request(uid=i, prompt=p, max_new=max_new,
                                 eos_id=eos_id, arrival=i))
        return sched.run()

    results = serve_continuous()
    cont_reads = sum(r.meter.kv_reads for r in results)
    cont_steps = sum(r.decode_meter.steps for r in results)
    gen = sum(int(r.lengths.sum()) for r in results)

    # lockstep: pad to longest prompt, no EOS, full max_new per lane
    padded = np.stack([np.pad(p, (pmax - len(p), 0), constant_values=2)
                       for p in prompts])
    lock = engine.generate(padded, max_new)
    lock_reads = lock.meter.kv_reads
    lock_gen = lock.meter.generated_tokens

    us = timeit(lambda: serve_continuous(), warmup=1, iters=1 if quick else 3)
    summary = {
        "requests": n_requests, "lanes": num_lanes,
        "continuous_kv_reads": cont_reads,
        "continuous_generated": gen,
        "continuous_reads_per_token": cont_reads / max(gen, 1),
        "lockstep_kv_reads": lock_reads,
        "lockstep_generated": lock_gen,
        "reads_saved_frac": 1.0 - cont_reads / lock_reads,
        "us_per_trace": us,
        "decode_steps": cont_steps,
    }
    emit(f"continuous_batching/{policy_kind}", us, summary)

    # shared-prefill fork: W=4 one prefill vs 4 tiled prefills
    prompt = prompts[0]
    w = 4
    fork = engine.hyperscale_generate(
        prompt, ScalingConfig(len(prompt) + max_new, w))
    tiled = engine.generate(np.tile(prompt[None], (w, 1)), max_new)
    fork_pre = fork.requests[0].prefill_meter.kv_reads
    tile_pre = sum(r.prefill_meter.kv_reads for r in tiled.requests)
    fork_summary = {
        "width": w,
        "fork_prefill_reads": fork_pre,
        "tiled_prefill_reads": tile_pre,
        "prefill_reads_ratio": tile_pre / max(fork_pre, 1e-9),
    }
    emit(f"continuous_batching/fork_w{w}/{policy_kind}", 0.0, fork_summary)
    save_json("continuous_batching",
              {"serve": summary, "fork": fork_summary})
    return summary


if __name__ == "__main__":
    run()
