"""Benchmark: block-table flash-decode — HBM traffic ∝ live tokens.

The paper's decode-side claim is that CR× KV compression buys CR× less HBM
read traffic per decode step.  The repo's budget meters (``reads_tokens``)
have always said so; this suite checks the *kernel* now does too, via the
block-table contract (docs/kernels.md):

* **fill sweep** (the serving headline) — a DMS arena is provisioned once
  for the request's ``max_len``; through most of a request's life occupancy
  is far below capacity.  The block-table kernel's fetched K/V bytes track
  the *live* blocks at every fill level, while the seed kernel DMA'd the
  full provisioned arena from token 1.
* **CR sweep** — the same 512-token stream at CR 1/2/4/8 with per-CR
  provisioned arenas, driven through the *real* ``SlotDMSCache.step``
  (delayed eviction, free-list holes, incremental tables): fetched bytes at
  CR 8 are a small fraction of CR 1, and every config stays within 1.25× of
  the live-block lower bound — the bytes ANY ``block_p``-granular kernel
  must move for that liveness pattern.
* **fragmentation sweep** — the same live mass packed, clustered, or
  scattered: fetched bytes track the number of live *blocks* (scatter
  legitimately touches every block — that IS its lower bound), never the
  arena capacity.
* **zero-copy step path** — the jaxpr of the block-table wrapper contains
  **zero** full-arena ``pad``/``concatenate`` copies and zero ``valid``
  dtype recasts (the seed wrapper re-padded and re-reshaped the whole arena
  and recast the bitmap every step of every layer).  Counted from the
  jaxpr, not eyeballed; the legacy/dense path is recorded as the contrast.
* **policy sweep** — every *registered* policy's real ``decode_update``
  stream (registry caches, fragmented tables) measured against the same
  contract: fetched K/V bytes vs the visible-block lower bound.  The three
  score-based policies (TOVA/H2O/Keyformer) are pinned ≤ 1.25× of it — they
  used to fall back silently to the reference path in kernel mode, which
  streamed the whole provisioned arena; the weights-out kernel makes the
  block-table byte model hold for them too, with zero arena copies on the
  ``need_weights=True`` wrapper path.
* **wall-clock columns** — per-step decode latency for the table vs dense
  path (``us_*`` keys: machine-local, skipped by ``--check``; on CPU both
  run in Pallas interpret mode, which executes every grid step regardless
  — the byte model is the portable claim).

Baseline: ``artifacts/bench/decode_path.json`` (committed); CI runs
``benchmarks.run --only decode_path --check``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.configs import get_smoke
from repro.core import policy as policy_lib
from repro.core.config import KVPolicyConfig
from repro.core.kv_cache import BlockTable, SlotDMSCache
from repro.kernels.dms_decode import ops as dkops

B, HKV, HQ, DH = 2, 2, 4, 32
MAX_LEN = 512                    # provisioning horizon for the DMS arenas
WINDOW = 8
BLOCK_P = 16

POLICY_STEPS = 20                # decode stream length for the policy sweep
WEIGHT_POLICIES = ("tova", "h2o", "keyformer")


# -- jaxpr traffic counters --------------------------------------------------
# shared with the static-analysis lint passes: repro.analysis counts the
# same ops the same way, so the audit and these baselines can't drift apart.

from repro.analysis.jaxpr import count_arena_copies  # noqa: E402


# -- arena construction ------------------------------------------------------


def _dms_arena(cr: float, steps: int):
    """Drive a real SlotDMSCache (provisioned for MAX_LEN at ``cr``) with a
    random eviction stream for ``steps`` tokens — free-list holes, pending
    rings, and the *incremental* block table land exactly as production
    decode leaves them."""
    slots = min(SlotDMSCache.provision_slots(MAX_LEN, cr, WINDOW), MAX_LEN + 1)
    cache = SlotDMSCache.init(B, HKV, slots, DH, WINDOW, jnp.float32,
                              block_p=BLOCK_P)
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    ks = jax.random.normal(k1, (steps, B, HKV, 1, DH), jnp.float32)
    vs = jax.random.normal(k2, (steps, B, HKV, 1, DH), jnp.float32)
    alphas = jax.random.bernoulli(k3, 1.0 - 1.0 / cr, (steps, B, HKV))

    def body(c, xs):
        kk, vv, aa = xs
        return c.step(kk, vv, aa), None

    cache, _ = jax.jit(lambda c: jax.lax.scan(body, c, (ks, vs, alphas)))(cache)
    return cache


def _valid_pattern(rng, p, live_frac, pattern):
    """A (B, HKV, p) live bitmap at ~live_frac occupancy: 'packed' prefix,
    'clustered' contiguous runs, or 'scatter' uniform holes."""
    n_live = max(int(p * live_frac), 1)
    valid = np.zeros((B, HKV, p), bool)
    for b in range(B):
        for h in range(HKV):
            if pattern == "packed":
                idx = np.arange(n_live)
            elif pattern == "clustered":
                runs = max(n_live // (2 * BLOCK_P), 1)
                starts = rng.choice(p // BLOCK_P, size=runs, replace=False)
                idx = []
                for s in starts:
                    idx.extend(range(s * BLOCK_P,
                                     min(s * BLOCK_P + n_live // runs, p)))
                idx = np.asarray(sorted(set(idx)))[:n_live]
            else:
                idx = rng.choice(p, size=n_live, replace=False)
            valid[b, h, idx] = True
    return jnp.asarray(valid)


def _bytes_per_block():
    return BLOCK_P * DH * 2 * 4          # K + V, fp32


def _traffic(valid, n):
    """(fetched, lower_bound, dense) K/V bytes for one decode step."""
    fetched = dkops.modeled_hbm_bytes(n, BLOCK_P, DH, jnp.float32, jnp.float32)
    p = valid.shape[-1]
    live_blocks = int(jnp.sum(jnp.any(
        valid.reshape(B, HKV, p // BLOCK_P, BLOCK_P), axis=-1)))
    lower = live_blocks * _bytes_per_block()
    dense = B * HKV * (p // BLOCK_P) * _bytes_per_block()
    return fetched, lower, dense


def _q(p_seed=0):
    return jax.random.normal(jax.random.PRNGKey(p_seed), (B, 1, HQ, DH),
                             jnp.float32)


def _row(cache, iters):
    tbl, n, bp = cache.block_spec()
    assert bp == BLOCK_P
    q = _q()
    fetched, lower, dense = _traffic(cache.valid, n)
    # acceptance: fetched K/V bytes within 1.25x of the live-block lower
    # bound (what any block-granular kernel must move) — NOT arena capacity
    assert fetched <= 1.25 * lower, (fetched, lower)
    table_fn = jax.jit(
        lambda q, k, v, valid, tbl, n: dkops.dms_decode_attention(
            q, k, v, valid, block_tbl=tbl, block_n=n, block_p=BLOCK_P))
    dense_fn = jax.jit(lambda q, k, v, valid: dkops.dms_decode_attention(
        q, k, v, valid, block_p=BLOCK_P))
    us_tbl = timeit(lambda: table_fn(q, cache.k, cache.v, cache.valid, tbl, n
                                     ).block_until_ready(), iters=iters)
    us_dense = timeit(lambda: dense_fn(q, cache.k, cache.v, cache.valid
                                       ).block_until_ready(), iters=iters)
    return {
        "arena_slots": int(cache.k.shape[2]),
        "live_tokens": int(jnp.sum(cache.valid)),
        "fetched_bytes": fetched,
        "lower_bound_bytes": lower,
        "dense_bytes": dense,
        "fetched_over_lower": fetched / lower,
        "fetched_over_dense": fetched / dense,
        "us_per_step_table": us_tbl,
        "us_per_step_dense": us_dense,
    }


# -- policy sweep: the block-table byte contract per registered policy -------


def _policy_spec(kind):
    """Drive a registry policy's real ``decode_update`` stream for
    ``POLICY_STEPS`` tokens (evictions, free-list holes, incremental tables)
    and return the last AttendSpec + the matching query."""
    arch = get_smoke("qwen-r1-1.5b")
    arch = dataclasses.replace(
        arch, dms=dataclasses.replace(arch.dms, window=4, target_cr=4.0,
                                      steps_per_cr_unit=5))
    cfg = KVPolicyConfig(kind=kind, cr=2.0, window=4, block_p=8,
                         quest_page_size=8, quest_top_pages=2)
    pc = policy_lib.init_policy_cache(arch, 2, 32, cfg)
    pol = policy_lib.get_policy(pc.policy)
    a = arch.attn
    dt = jnp.dtype(arch.dtype)
    key = jax.random.PRNGKey(7)
    cache, spec, q = pc.cache, None, None
    for i in range(POLICY_STEPS):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        q = jax.random.normal(k1, (2, 1, a.num_heads, a.head_dim), dt)
        k_new = jax.random.normal(k2, (2, a.num_kv_heads, 1, a.head_dim), dt)
        v_new = jax.random.normal(k3, (2, a.num_kv_heads, 1, a.head_dim), dt)
        aux = {"alpha_bin": jax.random.bernoulli(k4, 0.5,
                                                 (2, a.num_kv_heads)),
               "pos_t": jnp.full((2,), i, jnp.int32),
               "attn_cfg": a, "arch": arch, "dtype": dt}
        cache, spec = pol.decode_update(cache, q, k_new, v_new, aux)
        if spec.needs_weights:
            w = jax.random.uniform(k4, spec.visible.shape, jnp.float32)
            cache = pol.post_attend(cache, jnp.where(spec.visible, w, 0.0))
    return spec, q, a


def _policy_row(kind):
    spec, q, a = _policy_spec(kind)
    bp = spec.block_p
    row = {"needs_weights": bool(spec.needs_weights),
           "live_tokens": int(jnp.sum(spec.visible))}
    if not bp:
        return row
    fetched = dkops.modeled_hbm_bytes(spec.block_n, bp, a.head_dim,
                                      spec.k.dtype, spec.v.dtype)
    p = spec.visible.shape[-1]
    blk_live = jnp.any(
        spec.visible.reshape(*spec.visible.shape[:2], p // bp, bp), axis=-1)
    per_blk = bp * a.head_dim * (spec.k.dtype.itemsize + spec.v.dtype.itemsize)
    lower = int(jnp.sum(blk_live)) * per_blk
    row.update(fetched_bytes=int(fetched), lower_bound_bytes=lower,
               fetched_over_lower=fetched / lower)
    if spec.needs_weights:
        # the weights-out wrapper path must be as copy-free as the plain one
        arena_elems = int(np.prod(spec.k.shape))
        copies = count_arena_copies(
            lambda q, k, v, vis, tbl, n: dkops.dms_decode_attention(
                q, k, v, vis, block_tbl=tbl, block_n=n, block_p=bp,
                need_weights=True)[0],
            q, spec.k, spec.v, spec.visible, spec.block_tbl, spec.block_n,
            arena_elems=arena_elems)
        assert copies["arena_pad_copies"] == 0, (kind, copies)
        assert copies["valid_recasts"] == 0, (kind, copies)
        row["weights_out_arena_copies"] = copies["arena_pad_copies"]
    return row


def run(quick=False):
    iters = 1 if quick else 3
    payload = {}

    # -- fill sweep: one provisioned arena, growing occupancy ---------------
    fill = {}
    for steps in (32, 128, MAX_LEN):
        row = _row(_dms_arena(4.0, steps), iters)
        fill[f"t{steps}"] = row
        emit(f"decode_path/fill_t{steps}", row["us_per_step_table"], row)
    # early in a request the arena is mostly empty: fetched bytes must track
    # occupancy, not the provisioned capacity the seed kernel streamed
    assert fill["t32"]["fetched_over_dense"] <= 0.30, fill["t32"]
    assert fill["t32"]["fetched_bytes"] < fill[f"t{MAX_LEN}"]["fetched_bytes"]
    payload["dms_fill"] = fill

    # -- CR sweep: per-CR provisioned arenas at full length -----------------
    by_cr = {}
    for cr in (1.0, 2.0, 4.0, 8.0):
        row = _row(_dms_arena(cr, MAX_LEN), iters)
        by_cr[f"cr{cr:g}"] = row
        emit(f"decode_path/dms_cr{cr:g}", row["us_per_step_table"], row)
    # 8x compression must show up as ~8x fewer fetched bytes
    assert by_cr["cr8"]["fetched_bytes"] <= 0.25 * by_cr["cr1"]["fetched_bytes"], by_cr
    payload["dms_by_cr"] = by_cr

    # -- fragmentation sweep: same live mass, different hole layouts --------
    rng = np.random.default_rng(11)
    frag = {}
    q, p = _q(2), 256
    k = jax.random.normal(jax.random.PRNGKey(3), (B, HKV, p, DH), jnp.float32)
    for pattern in ("packed", "clustered", "scatter"):
        valid = _valid_pattern(rng, p, live_frac=0.25, pattern=pattern)
        bt = BlockTable.from_valid(valid, BLOCK_P)
        fetched, lower, dense = _traffic(valid, bt.n)
        assert fetched <= 1.25 * lower, (pattern, fetched, lower)
        frag[pattern] = {
            "live_tokens": int(jnp.sum(valid)),
            "fetched_bytes": fetched,
            "lower_bound_bytes": lower,
            "dense_bytes": dense,
            "fetched_over_dense": fetched / dense,
        }
        emit(f"decode_path/frag_{pattern}", 0.0, frag[pattern])
    # packed occupancy at 25% live fetches ~25% of the arena; scatter may
    # legitimately touch every block (that IS its lower bound)
    assert frag["packed"]["fetched_over_dense"] <= 0.30
    payload["fragmentation"] = frag

    # -- policy sweep: every registered policy, same byte contract ----------
    pol = {}
    for kind in policy_lib.available_policies():
        row = _policy_row(kind)
        pol[kind] = row
        emit(f"decode_path/policy_{kind}", 0.0, row)
    # acceptance: the newly kernel-enabled weight policies fetch within
    # 1.25x of the visible-block lower bound — the silent reference
    # fallback used to stream the whole provisioned arena here
    for kind in WEIGHT_POLICIES:
        assert pol[kind]["needs_weights"], pol[kind]
        assert pol[kind]["fetched_over_lower"] <= 1.25, (kind, pol[kind])
    payload["policy_sweep"] = pol

    # -- zero full-arena copies on the step path ----------------------------
    cache = _dms_arena(4.0, 128)
    tbl, n, _ = cache.block_spec()
    q = _q()
    arena_elems = int(np.prod(cache.k.shape))
    copies_tbl = count_arena_copies(
        lambda q, k, v, valid, tbl, n: dkops.dms_decode_attention(
            q, k, v, valid, block_tbl=tbl, block_n=n, block_p=BLOCK_P),
        q, cache.k, cache.v, cache.valid, tbl, n, arena_elems=arena_elems)
    copies_dense = count_arena_copies(
        lambda q, k, v, valid: dkops.dms_decode_attention(
            q, k, v, valid, block_p=BLOCK_P),
        q, cache.k, cache.v, cache.valid, arena_elems=arena_elems)
    # acceptance: the block-table step path copies the arena zero extra times
    assert copies_tbl["arena_pad_copies"] == 0, copies_tbl
    assert copies_tbl["valid_recasts"] == 0, copies_tbl
    payload["step_path_copies"] = {"table": copies_tbl, "dense": copies_dense}
    emit("decode_path/step_path_copies", 0.0, payload["step_path_copies"])

    save_json("decode_path", payload)


if __name__ == "__main__":
    run()
