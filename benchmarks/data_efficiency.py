"""Benchmark ↔ paper Fig. 5 (right): DMS vs DMC data efficiency.

Retrofit the same tiny LM with (a) DMS (delayed eviction) and (b) a DMC-style
objective (immediate merge pressure — modelled here as immediate eviction
with the same aux loss, the harder objective the paper identifies), tracking
teacher-match KL vs training steps.  Claim to reproduce: DMS reaches a given
quality/CR with far fewer steps.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.configs import get_smoke
from repro.core.config import DMSConfig
from repro.core import distill as distill_lib
from repro.data.pipeline import DataConfig, make_batch
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.optim import adamw


def _retrofit_curve(arch, immediate, window, total, data, probe_every=20):
    a = dataclasses.replace(
        arch, dms=DMSConfig(enabled=True, window=window, target_cr=4.0,
                            immediate_eviction=immediate,
                            steps_per_cr_unit=max(total // 6, 4)))
    params = tfm.init_model(jax.random.PRNGKey(0), a)
    teacher = jax.tree_util.tree_map(jnp.copy, params)
    opt = adamw.init(params)
    rstep = jax.jit(steps_lib.make_retrofit_step(
        a, adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=total)),
        donate_argnums=(0, 2))
    hb = {k: jnp.asarray(v) for k, v in make_batch(data, 88_888).items()}
    t_logits, _ = tfm.model_forward(teacher, hb["tokens"], a, mode="vanilla")
    curve = []
    for s in range(total):
        batch = {k: jnp.asarray(v) for k, v in make_batch(data, s).items()}
        params, opt, m = rstep(params, teacher, opt, batch,
                               jnp.asarray(s, jnp.int32))
        if (s + 1) % probe_every == 0:
            s_logits, aux = tfm.model_forward(params, hb["tokens"], a,
                                              mode="dms_eval")
            kl = float(distill_lib.kl_logit_distillation(s_logits, t_logits))
            curve.append({"step": s + 1, "kl": kl,
                          "alpha": float(aux["alpha_sum"] / aux["alpha_count"])})
    return curve


def run(total=80, quick=False):
    if quick:
        total = 40
    arch = get_smoke("llama32-1b")
    data = DataConfig(vocab_size=arch.vocab_size, seq_len=64, global_batch=16)
    dms_curve = _retrofit_curve(arch, immediate=False, window=8, total=total,
                                data=data)
    dmc_curve = _retrofit_curve(arch, immediate=True, window=8, total=total,
                                data=data)
    # steps needed to reach the DMS end-quality
    target = dms_curve[-1]["kl"]
    dms_steps = next((c["step"] for c in dms_curve if c["kl"] <= target), total)
    dmc_steps = next((c["step"] for c in dmc_curve if c["kl"] <= target), None)
    out = {"dms": dms_curve, "immediate": dmc_curve,
           "dms_steps_to_target": dms_steps,
           "immediate_steps_to_target": dmc_steps,
           "immediate_never_reached": dmc_steps is None,
           "final_kl_dms": dms_curve[-1]["kl"],
           "final_kl_immediate": dmc_curve[-1]["kl"]}
    emit("data_efficiency/summary", 0.0,
         {k: out[k] for k in ("dms_steps_to_target", "immediate_steps_to_target",
                              "final_kl_dms", "final_kl_immediate")})
    save_json("data_efficiency", out)
    return out


if __name__ == "__main__":
    run()
