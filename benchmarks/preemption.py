"""Benchmark: preemptive lane eviction vs naive restart under pool pressure.

Serves an oversubscribed paged trace (pool = 8 pages, worst-case solo demand
= 6 pages/lane, ``oversub=2.0`` admits two lanes anyway) with the preemption
layer on, and reports what the snapshot→resume path buys:

* every request finishes ``ok`` and bitwise-equal to its solo run — the
  pool never exhausts, no write is ever dropped (the seed behaviour this
  layer replaces corrupted tokens silently);
* zero re-prefill: a resumed request imports its host snapshot instead of
  re-running prefill, so the KV reads a restart-from-scratch policy would
  re-pay (preempt_count × that request's prefill reads) are saved outright.

The lifecycle counters and tick counts are deterministic (host-driven
scheduler, greedy decode), so ``run.py --check`` gates them against the
committed baseline; only the wall-clock key is tolerance-skipped.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.configs import get_smoke
from repro.core.config import KVPolicyConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine
from repro.serving.scheduler import Request

POOL_BLOCKS = 8     # worst-case solo demand at max_len=24 is 6 pages/lane
NUM_LANES = 2
MAX_LEN = 24
MAX_NEW = 8
N_REQUESTS = 3


def run(quick=False):
    arch = get_smoke("qwen-r1-1.5b")
    arch = dataclasses.replace(
        arch, dms=dataclasses.replace(arch.dms, window=4))
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    policy = KVPolicyConfig(kind="dms", cr=2.0, window=arch.dms.window,
                            paged=True, block_p=8, pool_blocks=POOL_BLOCKS)
    engine = Engine(arch, params, policy, chunk=4)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, arch.vocab_size, size=(10,)).astype(np.int32)
               for _ in range(N_REQUESTS)]

    def solo(i):
        sched = engine.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN)
        sched.submit(Request(uid=i, prompt=prompts[i], max_new=MAX_NEW))
        return sched.run()[0].tokens

    solo_tokens = [solo(i) for i in range(N_REQUESTS)]

    def serve():
        sched = engine.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN,
                                 oversub=2.0, on_pressure="preempt")
        for i, p in enumerate(prompts):
            sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW,
                                 arrival=i))
        return sched, sched.run()

    sched, results = serve()
    results = {r.uid: r for r in results}
    stats = sched.pool_stats()
    life = stats["lifecycle"]

    statuses_ok = all(results[i].status == "ok" for i in range(N_REQUESTS))
    tokens_match = statuses_ok and all(
        np.array_equal(results[i].tokens, solo_tokens[i])
        for i in range(N_REQUESTS))
    # what restart-from-scratch would re-pay: each preemption of request i
    # discards and re-runs its whole prefill (snapshot resume re-reads zero)
    restart_reprefill = sum(
        results[i].preempt_count * results[i].prefill_meter.kv_reads
        for i in range(N_REQUESTS))

    us = timeit(lambda: serve()[1], warmup=1, iters=1 if quick else 3)
    summary = {
        "requests": N_REQUESTS, "lanes": NUM_LANES,
        "pool_blocks": POOL_BLOCKS, "oversub": 2.0,
        "preemptions": life["preemptions"],
        "resumes": life["resumes"],
        "completed": life["completed"],
        "failures": life["failures"],
        "timeouts": life["timeouts"],
        "statuses_ok": bool(statuses_ok),
        "tokens_match_solo": bool(tokens_match),
        "pool_exhausted": bool(stats["exhausted"]),
        "scheduler_ticks": sched.ticks,
        "prefill_reads_total": sum(
            results[i].prefill_meter.kv_reads for i in range(N_REQUESTS)),
        "reprefill_reads_saved_vs_restart": restart_reprefill,
        "us_per_trace": us,
    }
    emit("preemption/dms", us, summary)
    save_json("preemption", summary)
    return summary


if __name__ == "__main__":
    run()
