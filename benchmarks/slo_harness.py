"""Benchmark: SLO-driven overload control vs an uncontrolled scheduler.

Serves the SAME seeded 2x-overload burst trace (mixed prompt lengths,
mixed hyperscale widths, per-request deadlines) through two schedulers:

* **uncontrolled** — ``slo=None``: every request is queued and admitted
  FIFO at its full width; overload shows up as post-prefill deadline
  timeouts (capacity burned on requests that were already doomed);
* **controlled** — an :class:`~repro.serving.scheduler.SLOSpec` with a
  TTFT target, a bounded submit queue, and width degradation: doomed
  requests are shed BEFORE admission (zero prefill reads), hyperscale
  widths throttle W -> min_width under pressure, and the freed capacity
  lands on requests that can still meet the SLO.

Both result sets are scored by ``compute_slo_stats`` against the same
SLO; the harness asserts the control ladder strictly beats laissez-faire
on goodput, that every offered request ends in a definite status, that
shed requests never touched the device, and that every ``ok`` request is
bitwise token-equal to a solo run at its SERVED width (degradation
changes width, never tokens).  An under-load Poisson trace pins the
no-false-positive side: with headroom, the controller sheds and degrades
nothing and goodput is 1.0.

All counters are deterministic (host-driven scheduler, seeded workload,
greedy decode), so ``run.py --check`` gates them against the committed
baseline; only the wall-clock key is tolerance-skipped.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.configs import get_smoke
from repro.core.config import KVPolicyConfig
from repro.models import transformer as tfm
from repro.serving import workload
from repro.serving.engine import Engine
from repro.serving.scheduler import SLOSpec, compute_slo_stats

NUM_LANES = 2
MAX_LEN = 24
CHUNK = 4
N_REQUESTS = 12

SLO = SLOSpec(ttft_ticks=6, max_queue=4, min_width=1, cooldown_ticks=4)

SPEC = workload.WorkloadSpec(
    vocab=64, max_len=MAX_LEN, prompt_len=(6, 10), max_new=(4, 6),
    widths=(1, 2), deadline=12)


def _overload_trace():
    """~2x overload: burst windows arrive faster than two lanes drain."""
    return workload.burst_trace(0, N_REQUESTS, rate=2.0, on_ticks=4,
                                off_ticks=4, spec=SPEC)


def _solo_tokens(engine, req, width):
    """Oracle: the request alone on the arena at its SERVED width."""
    sched = engine.scheduler(num_lanes=max(NUM_LANES, width),
                             max_len=MAX_LEN)
    sched.submit(dataclasses.replace(req, width=width, arrival=0,
                                     deadline=None))
    return sched.run()[0]


def run(quick=False):
    arch = get_smoke("qwen-r1-1.5b")
    arch = dataclasses.replace(
        arch, dms=dataclasses.replace(arch.dms, window=4))
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    policy = KVPolicyConfig(kind="dms", cr=2.0, window=arch.dms.window)
    engine = Engine(arch, params, policy, chunk=CHUNK)
    reqs = _overload_trace()

    def serve(slo):
        sched = engine.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN,
                                 slo=slo)
        for r in reqs:
            sched.submit(r)
        return sched, sched.run()

    _, base_results = serve(None)
    sched, ctrl_results = serve(SLO)

    # both runs scored against the same SLO the controller enforced
    base = compute_slo_stats(base_results, SLO, offered=len(reqs))
    ctrl = sched.slo_stats()
    life = ctrl["lifecycle"]

    definite = {"ok", "failed", "timeout", "rejected"}
    statuses_definite = (
        all(r.status in definite for r in base_results)
        and all(r.status in definite for r in ctrl_results))
    shed_zero_prefill = all(
        r.prefill_meter.kv_reads == 0 and r.admitted_tick == -1
        for r in ctrl_results if r.status == "rejected")

    by_uid = {r.uid: r for r in reqs}
    tokens_match = True
    for r in ctrl_results:
        if r.status != "ok":
            continue
        solo = _solo_tokens(engine, by_uid[r.uid], len(r.lengths))
        tokens_match &= (np.array_equal(r.tokens, solo.tokens)
                         and np.array_equal(r.lengths, solo.lengths))

    # under load headroom the controller must be invisible: nothing shed,
    # nothing degraded, goodput 1.0
    calm_reqs = workload.poisson_trace(
        1, 6, rate=0.2,
        spec=dataclasses.replace(SPEC, deadline=None, widths=(1,),
                                 width_weights=None))
    calm_sched = engine.scheduler(num_lanes=NUM_LANES, max_len=MAX_LEN,
                                  slo=SLO)
    for r in calm_reqs:
        calm_sched.submit(r)
    calm_sched.run()
    calm = calm_sched.slo_stats()

    us = timeit(lambda: serve(SLO)[1], warmup=1, iters=1 if quick else 3)
    summary = {
        "requests": N_REQUESTS, "lanes": NUM_LANES,
        "slo_ttft_ticks": SLO.ttft_ticks, "max_queue": SLO.max_queue,
        "goodput_uncontrolled": base["goodput"],
        "goodput_controlled": ctrl["goodput"],
        "controlled_beats_uncontrolled":
            bool(ctrl["goodput"] > base["goodput"]),
        "uncontrolled_statuses": base["statuses"],
        "controlled_statuses": ctrl["statuses"],
        "shed": life["shed"], "rejected": life["rejected"],
        "degraded": life["degraded"],
        "statuses_definite": bool(statuses_definite),
        "shed_zero_prefill_reads": bool(shed_zero_prefill),
        "ok_tokens_match_solo": bool(tokens_match),
        "controlled_ttft_p90": ctrl["ttft"]["p90"],
        "calm_goodput": calm["goodput"],
        "calm_shed": calm["lifecycle"]["shed"],
        "calm_degraded": calm["lifecycle"]["degraded"],
        "us_per_trace": us,
    }
    assert summary["controlled_beats_uncontrolled"], summary
    assert statuses_definite and shed_zero_prefill and tokens_match, summary
    assert calm["goodput"] == 1.0 and calm["lifecycle"]["shed"] == 0 \
        and calm["lifecycle"]["degraded"] == 0, calm
    emit("slo_harness/dms", us, summary)
    save_json("slo_harness", summary)
    return summary


if __name__ == "__main__":
    run()
