"""Roofline table (deliverable g): collates the dry-run artifacts into the
per-(arch × shape) baseline table used in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit, save_json

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_records():
    recs = []
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        try:
            recs.append(json.loads(f.read_text()))
        except Exception:
            pass
    return recs


def run(quick=False):
    recs = load_records()
    rows = []
    for r in recs:
        if "compute_s" not in r:
            continue
        row = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "variant": r.get("variant", "vanilla"),
            "compute_s": round(r["compute_s"], 6),
            "memory_s": round(r.get("memory_model_s", r["memory_s"]), 6),
            "memory_hlo_s": round(r["memory_s"], 6),
            "collective_s": round(r["collective_s"], 6),
            "bottleneck": r["bottleneck"],
            "useful_flops": round(r["useful_flops_ratio"], 3),
            "hw_util": round(r["hw_util"], 4),
            "fits": r.get("memory_fit", {}).get("fits_hbm_16g"),
            "peak_gb": round(r.get("memory_fit", {}).get("peak_bytes", 0) / 1e9, 2),
        }
        rows.append(row)
        emit(f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}"
             f"/{row['variant']}",
             r.get("step_time_s", 0) * 1e6, row)
    save_json("roofline_table", rows)
    return rows


if __name__ == "__main__":
    run()
