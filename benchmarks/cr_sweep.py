"""Benchmark ↔ paper Table 1: method × compression-ratio sweep.

One retrofitted tiny LM, evaluated with every KV policy at CR ∈ {2, 3, 4} on
(a) teacher-match KL on held-out text, (b) the needle task (NIAH-like).
The paper's qualitative rows to reproduce: DMS degrades least as CR grows;
Quest tracks vanilla (it keeps everything in memory) but saves only reads;
TOVA/H2O fall off fastest; DMC struggles at small capacity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.configs import get_smoke
from repro.core.config import DMSConfig, KVPolicyConfig
from repro.core.policy import available_policies
from repro.data import tasks
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.optim import adamw
from repro.serving.engine import Engine


def _train_needle_model(steps=240, seed=0):
    arch = get_smoke("llama32-1b")
    arch = dataclasses.replace(
        arch, vocab_size=64,
        dms=DMSConfig(enabled=True, window=4, target_cr=4.0,
                      steps_per_cr_unit=max(steps // 8, 5)))
    task = tasks.TaskConfig(kind="needle", vocab_size=64, prompt_len=48,
                            seed=seed)
    base = dataclasses.replace(arch, dms=DMSConfig(enabled=False))
    params = tfm.init_model(jax.random.PRNGKey(seed), base)
    opt = adamw.init(params)
    step_fn = jax.jit(steps_lib.make_train_step(
        base, adamw.AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=steps)),
        donate_argnums=(0, 1))
    for s in range(steps):
        b = tasks.make_train_batch(task, s, 32)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        params, opt, _ = step_fn(params, opt, batch, jnp.asarray(s, jnp.int32))
    # retrofit
    teacher = jax.tree_util.tree_map(jnp.copy, params)
    ropt = adamw.init(params)
    rstep = jax.jit(steps_lib.make_retrofit_step(
        arch, adamw.AdamWConfig(lr=1e-3, warmup_steps=10,
                                total_steps=steps // 2)), donate_argnums=(0, 2))
    for s in range(steps // 2):
        b = tasks.make_train_batch(task, 50_000 + s, 32)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        params, ropt, _ = rstep(params, teacher, ropt, batch,
                                jnp.asarray(s, jnp.int32))
    return arch, params, task


def _needle_accuracy(engine: Engine, prompts, answers) -> float:
    hits = 0
    res = engine.generate(prompts, 1)
    for i in range(len(prompts)):
        hits += int(res.tokens[i, 0] == answers[i])
    return hits / len(prompts)


def run(n_eval=32, quick=False):
    arch, params, task = _train_needle_model(steps=120 if quick else 240)
    prompts, answers = tasks.make_eval_set(task, n_eval)
    table = {}
    # every policy in the registry, no hardcoded list: a newly registered
    # policy (e.g. keyformer) shows up in Table 1 automatically
    for method in available_policies():
        # vanilla and the masked-DMS oracle ignore cr (full arena; eviction
        # driven by trained alphas alone) — one row each, not three
        crs = [1.0] if method in ("vanilla", "dms_masked") else [2.0, 3.0, 4.0]
        for cr in crs:
            pol = KVPolicyConfig(kind=method, cr=cr, window=arch.dms.window,
                                 quest_page_size=4)
            engine = Engine(arch, params, pol)
            acc = _needle_accuracy(engine, prompts, answers)
            key = f"{method}_cr{cr:g}"
            table[key] = acc
            emit(f"cr_sweep/{key}", 0.0, {"needle_acc": acc})
    save_json("cr_sweep", table)
    return table


if __name__ == "__main__":
    run()
