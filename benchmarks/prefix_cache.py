"""Benchmark: cross-request radix prefix cache on shared-prefix traces.

Serving patterns where cross-request reuse dominates:

* **shared system prompt** — K requests share an L-token prefix (system
  prompt / few-shot header) with distinct suffixes.  With the prefix cache,
  request 0 pays the full prefix once; every later request imports the
  cached L-token snapshot and prefills only its suffix.  The acceptance
  identity checked here: warm paid prefill reads == cold reads minus
  (K-1) × the prefix's cold reads, i.e. **one full prefix plus per-request
  suffixes** — and every generated token is identical to the cold serve.
* **multi-turn chat** — turn t's prompt extends turn t-1's full prompt, so
  each turn hits at least its predecessor's prompt boundary and pays only
  the new tokens.
* **two-tier hot path** — the same shared-prefix trace with the
  device-resident slab and ``export_policy="second-miss"``.  Asserted from
  the cache's byte-traffic counters (not estimated): once warm, hits are
  served from the device slab with **zero host↔device snapshot bytes**
  (h2d == d2h == 0 across the whole repeat trace), while saved-vs-paid
  reads still satisfy the cold-serve identity exactly.
* **single-shot unshared prompts** — under ``second-miss`` a trace with no
  shared prefixes performs **zero boundary exports** (the seed behaviour
  paid one O(arena) device→host copy per prefill chunk here).

All run on the same engine/scheduler as production serving; savings are
measured from the per-request ``BudgetMeter`` (``kv_reads`` paid vs
``kv_reads_saved``) and the cache's traffic counters.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, save_json, timeit
from repro.configs import get_smoke
from repro.core.config import KVPolicyConfig
from repro.models import transformer as tfm
from repro.serving.engine import Engine
from repro.serving.scheduler import Request


def _serve(engine, prompts, max_new, max_len, num_lanes=1):
    sched = engine.scheduler(num_lanes=num_lanes, max_len=max_len)
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=max_new, arrival=i))
    return {r.uid: r for r in sched.run()}


def _assert_identity(warm, cold):
    """Paid + saved reads == the cold-serve reads, exactly, per request —
    and identical generations.  The honesty invariant for every trace."""
    for i in sorted(cold):
        w, c = warm[i], cold[i]
        np.testing.assert_array_equal(w.tokens, c.tokens, err_msg=str(i))
        assert abs((w.prefill_meter.kv_reads + w.prefill_meter.kv_reads_saved)
                   - c.prefill_meter.kv_reads) < 1e-6, i


def run(policy_kind="dms", n_requests=5, prefix_len=16, suffix_max=12,
        max_new=8, chunk=8, quick=False):
    if quick:
        n_requests = 3
    assert prefix_len % chunk == 0, "shared prefix must be chunk-aligned"
    arch = get_smoke("qwen-r1-1.5b")
    arch = dataclasses.replace(
        arch, dms=dataclasses.replace(arch.dms, window=4))
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    policy = KVPolicyConfig(kind=policy_kind, cr=2.0, window=arch.dms.window)
    warm_engine = Engine(arch, params, policy, chunk=chunk,
                         prefix_cache_mb=64)
    cold_engine = Engine(arch, params, policy, chunk=chunk)

    rng = np.random.default_rng(0)
    shared = rng.integers(3, arch.vocab_size, size=(prefix_len,)).astype(np.int32)
    prompts = [np.concatenate([
        shared,
        rng.integers(3, arch.vocab_size,
                     size=(int(rng.integers(4, suffix_max + 1)),)
                     ).astype(np.int32)]) for _ in range(n_requests)]
    max_len = prefix_len + suffix_max + max_new

    warm = _serve(warm_engine, prompts, max_new, max_len)
    cold = _serve(cold_engine, prompts, max_new, max_len)

    # acceptance: identical generations, and paid reads == one full prefix
    # plus per-request suffixes (checked via the cold-serve identity)
    _assert_identity(warm, cold)
    prefix_reads = warm[1].prefill_meter.kv_reads_saved
    assert prefix_reads > 0
    for i in range(n_requests):
        want_saved = 0.0 if i == 0 else prefix_reads
        assert abs(warm[i].prefill_meter.kv_reads_saved - want_saved) < 1e-6, i
    warm_pre = sum(r.prefill_meter.kv_reads for r in warm.values())
    cold_pre = sum(r.prefill_meter.kv_reads for r in cold.values())
    stats = warm_engine.prefix_cache.stats()

    us = timeit(lambda: _serve(warm_engine, prompts, max_new, max_len),
                warmup=0, iters=1 if quick else 3)
    summary = {
        "requests": n_requests, "prefix_len": prefix_len,
        "warm_prefill_reads": warm_pre,
        "cold_prefill_reads": cold_pre,
        "prefill_reads_saved_frac": 1.0 - warm_pre / cold_pre,
        "prefix_cold_reads": prefix_reads,
        "hit_rate": stats["hit_rate"],
        "token_hit_rate": stats["token_hit_rate"],
        "cache_bytes": stats["bytes"],
        "us_per_trace_warm": us,
    }
    emit(f"prefix_cache/shared_prefix/{policy_kind}", us, summary)

    # -- two-tier hot path: device slab + miss-driven exports ---------------
    hot_engine = Engine(arch, params, policy, chunk=chunk, prefix_cache_mb=64,
                        prefix_cache_device_mb=64,
                        export_policy="second-miss")
    pcache = hot_engine.prefix_cache
    hot1 = _serve(hot_engine, prompts, max_new, max_len)   # warms the slab
    _assert_identity(hot1, cold)                           # identity: trace 1
    t_warm = dict(pcache.traffic())
    hot_before = pcache.hot_hits
    us_hot = timeit(lambda: _serve(hot_engine, prompts, max_new, max_len),
                    warmup=0, iters=1 if quick else 3)
    hot2 = _serve(hot_engine, prompts, max_new, max_len)   # fully hot trace
    _assert_identity(hot2, cold)                           # identity: repeats
    t_hot = dict(pcache.traffic())
    # acceptance (a): once warm, the hit path is device-resident — zero
    # host↔device snapshot bytes across entire repeat traces (exports that
    # still happen are deferred d2d slab stores, hits are d2d slab fetches)
    assert pcache.hot_hits > hot_before, pcache.stats()
    assert t_hot["h2d_bytes"] == t_warm["h2d_bytes"], (t_warm, t_hot)
    assert t_hot["d2h_bytes"] == t_warm["d2h_bytes"], (t_warm, t_hot)
    hot_stats = pcache.stats()
    hot_summary = {
        "requests": n_requests,
        "hot_hits": hot_stats["hot_hits"],
        "hot_inserts": hot_stats["hot_inserts"],
        "demotions": hot_stats["demotions"],
        "promotions": hot_stats["promotions"],
        "h2d_bytes": hot_stats["h2d_bytes"],
        "d2h_bytes": hot_stats["d2h_bytes"],
        "d2d_bytes": hot_stats["d2d_bytes"],
        "hot_trace_h2d_bytes": t_hot["h2d_bytes"] - t_warm["h2d_bytes"],
        "hot_trace_d2h_bytes": t_hot["d2h_bytes"] - t_warm["d2h_bytes"],
        "device_bytes": hot_stats["device_bytes"],
        "us_per_trace_hot": us_hot,
    }
    emit(f"prefix_cache/hot_path/{policy_kind}", us_hot, hot_summary)

    # -- single-shot unshared prompts: second-miss exports nothing ----------
    single_engine = Engine(arch, params, policy, chunk=chunk,
                           prefix_cache_mb=64, prefix_cache_device_mb=64,
                           export_policy="second-miss")
    singles = [rng.integers(3, arch.vocab_size,
                            size=(prefix_len + 4,)).astype(np.int32)
               for _ in range(n_requests)]
    single_warm = _serve(single_engine, singles, max_new, max_len)
    single_cold = _serve(cold_engine, singles, max_new, max_len)
    _assert_identity(single_warm, single_cold)             # identity: singles
    s_stats = single_engine.prefix_cache.stats()
    # acceptance (b): zero boundary exports, zero snapshot traffic of any
    # kind — a cold unshared stream costs literally nothing extra
    assert s_stats["inserts"] == 0, s_stats
    assert s_stats["h2d_bytes"] == 0 and s_stats["d2h_bytes"] == 0 \
        and s_stats["d2d_bytes"] == 0, s_stats
    single_summary = {
        "requests": n_requests,
        "inserts": s_stats["inserts"],
        "h2d_bytes": s_stats["h2d_bytes"],
        "d2h_bytes": s_stats["d2h_bytes"],
        "d2d_bytes": s_stats["d2d_bytes"],
        "lookups": s_stats["lookups"],
    }
    emit(f"prefix_cache/single_shot/{policy_kind}", 0.0, single_summary)

    # -- multi-turn chat: each turn's prompt extends the previous one -------
    chat_engine = Engine(arch, params, policy, chunk=chunk,
                         prefix_cache_mb=64)
    turns = 2 if quick else 4
    prompt = rng.integers(3, arch.vocab_size, size=(10,)).astype(np.int32)
    # one max_len for every turn: snapshots are only interchangeable between
    # identically-shaped arenas (the signature guard), so the conversation
    # must live in one arena geometry
    chat_max_len = len(prompt) + turns * (max_new + 6) + max_new
    chat_paid, chat_saved = 0.0, 0.0
    for t in range(turns):
        sched = chat_engine.scheduler(num_lanes=1, max_len=chat_max_len)
        sched.submit(Request(uid=t, prompt=prompt, max_new=max_new))
        r = sched.run()[0]
        chat_paid += r.prefill_meter.kv_reads
        chat_saved += r.prefill_meter.kv_reads_saved
        assert (t == 0) == (r.prefill_meter.kv_reads_saved == 0.0), t
        new_user = rng.integers(3, arch.vocab_size, size=(6,)).astype(np.int32)
        prompt = np.concatenate([prompt, r.tokens[0][:int(r.lengths[0])],
                                 new_user])
    chat_summary = {
        "turns": turns,
        "paid_prefill_reads": chat_paid,
        "saved_prefill_reads": chat_saved,
        "saved_frac": chat_saved / (chat_paid + chat_saved),
        "hit_rate": chat_engine.prefix_cache.stats()["hit_rate"],
    }
    emit(f"prefix_cache/multi_turn/{policy_kind}", 0.0, chat_summary)
    save_json("prefix_cache", {"shared_prefix": summary,
                               "hot_path": hot_summary,
                               "single_shot": single_summary,
                               "multi_turn": chat_summary})
    return summary
