"""Benchmark ↔ paper Fig. 6: measured CR vs sequence position and per-layer
retention of a retrofitted model — the emergent compression structure."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_smoke
from repro.core.config import DMSConfig
from repro.data.pipeline import DataConfig, make_batch
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.optim import adamw


def run(steps=120, quick=False):
    if quick:
        steps = 60
    arch = get_smoke("qwen-r1-7b")
    arch = dataclasses.replace(
        arch, num_layers=4,
        dms=DMSConfig(enabled=True, window=8, target_cr=4.0,
                      steps_per_cr_unit=max(steps // 6, 5)))
    data = DataConfig(vocab_size=arch.vocab_size, seq_len=128, global_batch=8)
    params = tfm.init_model(jax.random.PRNGKey(0), arch)
    teacher = jax.tree_util.tree_map(jnp.copy, params)
    opt = adamw.init(params)
    rstep = jax.jit(steps_lib.make_retrofit_step(
        arch, adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)),
        donate_argnums=(0, 2))
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(data, s).items()}
        params, opt, m = rstep(params, teacher, opt, batch,
                               jnp.asarray(s, jnp.int32))

    # measure binarised retention per position and per layer on held-out text
    hb = {k: jnp.asarray(v) for k, v in make_batch(data, 77_777).items()}
    _, aux = tfm.model_forward(params, hb["tokens"], arch, mode="dms_eval",
                               collect_kv=True)
    ret = np.asarray(aux["layer_kv"]["0"]["retained"])      # (L, B, H, T)
    per_pos = ret.mean(axis=(0, 1, 2))                      # retention vs position
    per_layer = ret.mean(axis=(1, 2, 3))                    # retention vs layer
    t = per_pos.shape[0]
    thirds = [float(per_pos[: t // 3].mean()),
              float(per_pos[t // 3: 2 * t // 3].mean()),
              float(per_pos[2 * t // 3:].mean())]
    out = {
        "alpha_mean_final": float(m["alpha_mean"]),
        "retention_by_third": thirds,
        "retention_per_layer": per_layer.tolist(),
        # Fig. 6 pattern: later positions compressed more aggressively
        "later_compressed_more": thirds[0] >= thirds[-1],
        "measured_cr": float(1.0 / max(ret.mean(), 1e-3)),
    }
    emit("cr_profile/summary", 0.0, out)
    save_json("cr_profile", out)
    return out


if __name__ == "__main__":
    run()
