"""Benchmark ↔ paper Appendix G / Fig. 7: share of decode latency attributable
to KV-cache reads, re-derived for TPU v5e and validated against the compiled
dry-run artifacts where available.

Paper Eq. (2)-(6) with our constants:
    FLOPS(B, L) ≈ n·B·(6·d·d_ff·g + 4·d² + 4·d·d_kv + 4·d_kv·L·r) + 2·B·d·V
    Reads(B, L) ≈ params_bytes + 2·n·B·L·d_kv·2
    latency ≈ max(FLOPS / peak, Reads / hbm_bw)
"""
from __future__ import annotations


from benchmarks.common import emit, save_json
from repro.configs import get_arch
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def decode_step_model(arch, batch, seq_len, cr=1.0):
    a = arch.attn
    d = arch.d_model
    n = arch.num_layers
    d_kv = (a.num_kv_heads * a.head_dim) if a else 0
    d_q = (a.num_heads * a.head_dim) if a else 0
    if arch.mlp is not None:
        glu = 3 if arch.mlp.kind in ("swiglu", "geglu") else 2
        moe = arch.mlp.moe
        d_ff_active = arch.mlp.d_ff * (moe.top_k if moe else 1)
    else:
        glu, d_ff_active = 0, 0
    l_eff = seq_len / cr
    flops = n * batch * (2 * glu * d * d_ff_active + 2 * d * d_q + 2 * d_q * d
                         + 4 * d * d_kv + 4 * d_kv * l_eff) \
        + 2 * batch * d * arch.vocab_size
    params_bytes = arch.param_count(active_only=True) * 2
    kv_bytes = 2 * n * batch * l_eff * d_kv * 2
    reads = params_bytes + kv_bytes
    lat = max(flops / PEAK_FLOPS, reads / HBM_BW)
    return {
        "latency_s": lat,
        "kv_share": kv_bytes / reads,
        "kv_dominates": kv_bytes > params_bytes,
        "flops": flops, "reads": reads,
    }


def run(quick=False):
    out = {}
    for arch_name in ["qwen-r1-1.5b", "qwen-r1-7b", "phi3-mini-3.8b"]:
        arch = get_arch(arch_name)
        for batch in (1, 32, 256):
            for seq in (8192, 32768):
                for cr in (1.0, 4.0, 8.0):
                    m = decode_step_model(arch, batch, seq, cr)
                    key = f"{arch_name}/b{batch}/s{seq}/cr{cr:g}"
                    out[key] = m
                    emit(f"latency_model/{key}", m["latency_s"] * 1e6,
                         {"kv_share": round(m["kv_share"], 4)})
    # paper's headline check (§5.1): at batch 256 / long seq the KV share of
    # memory reads exceeds 80-90% for the small Qwen models at CR=1
    share = out["qwen-r1-1.5b/b256/s32768/cr1"]["kv_share"]
    emit("latency_model/headline", 0.0,
         {"qwen1.5b_b256_s32k_kv_share": round(share, 4), "gt_0.9": share > 0.9})
    save_json("latency_model", {k: {kk: float(vv) for kk, vv in v.items()}
                                for k, v in out.items()})
    return out


if __name__ == "__main__":
    run()
