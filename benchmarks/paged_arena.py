"""Benchmark: paged KV block pool — footprint ∝ live tokens, CoW fork.

Fixed per-lane arenas make peak device KV bytes scale with *provisioned*
capacity: every lane owns ``ceil(max_len/CR)`` slots from admission to EOS
even while it holds a handful of live tokens (the capacity twin of the
dead-block-DMA pitfall — docs/kernels.md).  The paged pool
(``repro.core.block_pool``) allocates ``block_p``-sized pages on first
write and frees them when the incremental block table reports a block dead,
so a lane's footprint IS its live blocks.  This suite pins the three
capacity claims:

* **footprint timeline** — lanes admitted staggered into one pooled
  SlotDMS cache: allocated pool blocks track the live-block population
  *exactly* (the allocator invariant, sampled every step in-graph), while
  the fixed-arena provisioning for the same lanes is a flat line an order
  of magnitude up.
* **lanes at a fixed byte budget** — the pool is sized to what TWO fixed
  per-lane arenas would reserve; 8 CR8 lanes then decode concurrently to
  full depth without exhausting it (≥ 4× the concurrent lanes per byte).
* **zero-copy fork** — a width-4 shared-prefill fork of a pooled lane
  moves **zero** pool-arena bytes at fork time: the CoW copy counter does
  not tick and the fork jaxpr contains no pool-sized op (counted, not
  eyeballed; the fixed-arena fork's W-way arena gather is the contrast).
  Divergent decode afterwards ticks the counter — pages copy exactly when
  chains first diverge, never before.

Baseline: ``artifacts/bench/paged_arena.json`` (committed); CI runs
``benchmarks.run --only paged_arena --check`` (paged-pool-smoke job).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.analysis.jaxpr import count_big_float_ops, trace_jaxpr
from repro.core import block_pool, policy as policy_lib
from repro.core.kv_cache import SlotDMSCache, _round_up

LANES, HKV, DH = 8, 2, 32
MAX_LEN = 4096                   # provisioning horizon for the arenas
CR = 8.0
WINDOW = 8
BLOCK_P = 16


def _geometry():
    slots = min(SlotDMSCache.provision_slots(MAX_LEN, CR, WINDOW), MAX_LEN + 1)
    padded = _round_up(slots, BLOCK_P)
    nb = padded // BLOCK_P                     # logical blocks per (lane, head)
    return slots, nb


def _block_bytes():
    return BLOCK_P * DH * 2 * 4              # K + V pages, fp32


def _streams(steps, seed=7):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    ks = jax.random.normal(k1, (steps, LANES, HKV, 1, DH), jnp.float32)
    vs = jax.random.normal(k2, (steps, LANES, HKV, 1, DH), jnp.float32)
    alphas = jax.random.bernoulli(k3, 1.0 - 1.0 / CR, (steps, LANES, HKV))
    return ks, vs, alphas


def _lane_select(mask, on_true, on_false):
    """Serving's inactive-lane rollback for a bare (batch-leading) cache:
    per-lane leaves of frozen lanes roll back wholesale, the shared pool is
    kept (its mutations were already event-masked inside the step)."""
    def sel(a, b):
        if isinstance(a, block_pool.BlockPool):
            return a
        m = jnp.reshape(mask, (-1,) + (1,) * (a.ndim - 1))
        return jnp.where(m, a, b)
    return jax.tree_util.tree_map(
        sel, on_true, on_false,
        is_leaf=lambda x: isinstance(x, block_pool.BlockPool))


def _drive(cache, steps, active):
    """scan ``steps`` SlotDMS steps under a per-step (steps, LANES) active
    mask, emitting per-step in-graph pool telemetry (no host round-trips)."""
    ks, vs, alphas = _streams(steps)

    def body(c, xs):
        kk, vv, aa, act = xs
        c = _lane_select(act, c.step(kk, vv, aa, active=act), c)
        return c, (jnp.sum(c.pool.ref > 0), jnp.sum(c.blocks.n),
                   jnp.sum(c.blocks.count))

    cache, ys = jax.jit(
        lambda c, xs: jax.lax.scan(body, c, xs))(cache, (ks, vs, alphas,
                                                         jnp.asarray(active)))
    alloc, live_blocks, live_tokens = (np.asarray(y) for y in ys)
    return cache, alloc, live_blocks, live_tokens


def run(quick=False):
    steps = 64 if quick else 128
    slots, nb = _geometry()
    fixed_lane_blocks = HKV * nb             # blocks ONE fixed arena reserves
    provisioned = LANES * fixed_lane_blocks  # fixed provisioning, all lanes
    payload = {"geometry": {"slots": slots, "blocks_per_lane": fixed_lane_blocks,
                            "block_bytes": _block_bytes()}}

    # -- footprint timeline: staggered admissions, default (parity) pool ----
    active = np.zeros((steps, LANES), bool)
    for lane in range(LANES):
        active[lane * (steps // LANES):, lane] = True
    cache = SlotDMSCache.init(LANES, HKV, slots, DH, WINDOW, jnp.float32,
                              block_p=BLOCK_P, paged=True)
    cache, alloc, live_blocks, _ = _drive(cache, steps, active)
    # allocator invariant, sampled every step: allocated pool pages == blocks
    # with >= 1 live slot (no fork here, so no page is shared)
    assert np.array_equal(alloc, live_blocks), (alloc, live_blocks)
    peak = int(np.asarray(cache.pool.high_water))
    frac = peak / provisioned
    # footprint tracks live tokens: peak allocation is a sliver of what the
    # fixed layout reserves for the same lanes from step 0
    assert frac <= 0.35, (peak, provisioned)
    timeline = [{"step": int(t), "allocated_blocks": int(alloc[t]),
                 "allocated_bytes": int(alloc[t]) * _block_bytes()}
                for t in range(0, steps, max(steps // 8, 1))]
    footprint = {
        "timeline": timeline,
        "peak_blocks": peak,
        "peak_bytes": peak * _block_bytes(),
        "provisioned_blocks": provisioned,
        "provisioned_bytes": provisioned * _block_bytes(),
        "peak_over_provisioned": frac,
    }
    emit("paged_arena/footprint", 0.0, {k: v for k, v in footprint.items()
                                        if k != "timeline"})
    payload["footprint"] = footprint

    # -- 8 lanes inside TWO fixed lanes' byte budget ------------------------
    pool_blocks = 2 * fixed_lane_blocks
    cache = SlotDMSCache.init(LANES, HKV, slots, DH, WINDOW, jnp.float32,
                              block_p=BLOCK_P, paged=True,
                              pool_blocks=pool_blocks)
    cache, alloc, _, _ = _drive(cache, steps,
                                np.ones((steps, LANES), bool))
    exhausted = bool(np.asarray(cache.pool.exhausted))
    lanes_fixed = pool_blocks // fixed_lane_blocks
    budget = {
        "pool_blocks": pool_blocks,
        "pool_bytes": pool_blocks * _block_bytes(),
        "lanes_paged": LANES,
        "lanes_fixed_same_budget": lanes_fixed,
        "lane_multiplier": LANES / lanes_fixed,
        "decode_steps": steps,
        "high_water_blocks": int(np.asarray(cache.pool.high_water)),
        "exhausted": exhausted,
    }
    # acceptance: CR8 sustains >= 4x the concurrent lanes of fixed arenas
    # under the same pool byte budget, never running the pool dry
    assert not exhausted, budget
    assert budget["lane_multiplier"] >= 4.0, budget
    emit("paged_arena/lanes_at_budget", 0.0, budget)
    payload["lanes_at_budget"] = budget

    # -- width-4 fork moves zero pool bytes ---------------------------------
    # A SMALL arena whose slot ring has already wrapped when the fork lands:
    # the forked chains' first divergent writes then reuse eviction holes
    # inside *shared* pages — the CoW path proper, not fresh-page allocs.
    slots_small = 4 * BLOCK_P
    cache = SlotDMSCache.init(LANES, HKV, slots_small, DH, WINDOW,
                              jnp.float32, block_p=BLOCK_P, paged=True)
    warm = np.zeros((steps, LANES), bool)
    warm[:, 0] = True                        # prefill one lane only
    cache, _, _, _ = _drive(cache, steps, warm)
    pol = policy_lib.get_policy("dms")
    src = jnp.asarray([0, 0, 0, 0] + list(range(4, LANES)), jnp.int32)
    fork_fn = jax.jit(lambda c: pol.gather_cache(c, src, axis=0))
    forked = fork_fn(cache)

    def _kv_sized_ops(tree_in, min_elems):
        # float ops at least min_elems big = actual K/V bytes moving; the
        # shared counter deliberately skips integer metadata (the refcount
        # recompute builds a pool-squared int32 one-hot)
        return count_big_float_ops(
            trace_jaxpr(lambda c: pol.gather_cache(c, src, axis=0), tree_in),
            min_elems)

    big_ops = _kv_sized_ops(cache, int(np.prod(cache.pool.k.shape)))
    cow_at_fork = (int(np.asarray(forked.pool.cow_copies))
                   - int(np.asarray(cache.pool.cow_copies)))
    # contrast: the fixed-arena fork gathers the full per-lane arenas
    fixed = SlotDMSCache.init(LANES, HKV, slots_small, DH, WINDOW,
                              jnp.float32, block_p=BLOCK_P)
    big_ops_fixed = _kv_sized_ops(fixed, int(np.prod(fixed.k.shape)))
    # divergence: the four chains now write different tokens — CoW pages
    # copy exactly at each chain's first divergent write, never at fork
    div_act = np.zeros((steps, LANES), bool)
    div_act[:32, :4] = True
    forked, _, _, _ = _drive(forked, steps, div_act)
    fork = {
        "fork_width": 4,
        "cow_copies_at_fork": cow_at_fork,
        "pool_sized_ops_in_fork_jaxpr": big_ops,
        "arena_sized_ops_in_fixed_fork_jaxpr": big_ops_fixed,
        "cow_copies_after_divergence": int(np.asarray(forked.pool.cow_copies)),
        "shared_blocks_at_fork": int(np.asarray(
            jnp.sum(fork_fn(cache).pool.ref > 1))),
    }
    assert fork["cow_copies_at_fork"] == 0, fork
    assert fork["pool_sized_ops_in_fork_jaxpr"] == 0, fork
    assert fork["arena_sized_ops_in_fixed_fork_jaxpr"] > 0, fork
    assert fork["cow_copies_after_divergence"] > 0, fork
    assert fork["shared_blocks_at_fork"] > 0, fork
    emit("paged_arena/fork_zero_copy", 0.0, fork)
    payload["fork_zero_copy"] = fork

    save_json("paged_arena", payload)


if __name__ == "__main__":
    run()
