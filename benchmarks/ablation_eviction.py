"""Benchmark ↔ paper Fig. 5 (left): delayed vs immediate eviction.

Retrofits the same tiny LM with both policies across window sizes and
compares held-out distillation quality (teacher-match) + task accuracy.
The paper's key mechanism to reproduce: immediate eviction degrades rapidly;
delayed eviction stays close to the teacher even with small windows.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.configs import get_smoke
from repro.core.config import DMSConfig
from repro.core import distill as distill_lib
from repro.data.pipeline import DataConfig, make_batch
from repro.launch import steps as steps_lib
from repro.models import transformer as tfm
from repro.optim import adamw


def _retrofit_quality(arch, immediate: bool, window: int, steps: int,
                      data: DataConfig, seed=0):
    a = dataclasses.replace(
        arch, dms=DMSConfig(enabled=True, window=window, target_cr=4.0,
                            immediate_eviction=immediate,
                            steps_per_cr_unit=max(steps // 6, 4)))
    params = tfm.init_model(jax.random.PRNGKey(seed), a)
    teacher = jax.tree_util.tree_map(jnp.copy, params)
    opt = adamw.init(params)
    rstep = jax.jit(steps_lib.make_retrofit_step(
        a, adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=steps)),
        donate_argnums=(0, 2))
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in make_batch(data, s).items()}
        params, opt, m = rstep(params, teacher, opt, batch,
                               jnp.asarray(s, jnp.int32))
    # held-out teacher-match (KL) with *binarised* decisions (inference mode)
    hb = {k: jnp.asarray(v) for k, v in make_batch(data, 99_999).items()}
    s_logits, aux = tfm.model_forward(params, hb["tokens"], a, mode="dms_eval")
    t_logits, _ = tfm.model_forward(teacher, hb["tokens"], a, mode="vanilla")
    kl = float(distill_lib.kl_logit_distillation(s_logits, t_logits))
    achieved_cr = 1.0 / max(1.0 - float(aux["alpha_sum"] / aux["alpha_count"]),
                            1e-3)
    return {"kl_vs_teacher": kl, "achieved_cr": achieved_cr,
            "alpha_mean": float(aux["alpha_sum"] / aux["alpha_count"])}


def run(steps=60, quick=False):
    if quick:
        steps = 30
    arch = get_smoke("llama32-1b")
    data = DataConfig(vocab_size=arch.vocab_size, seq_len=64, global_batch=16)
    out = {}
    for window in (4, 16):
        for immediate in (False, True):
            tag = f"win{window}_{'immediate' if immediate else 'delayed'}"
            r = _retrofit_quality(arch, immediate, window, steps, data)
            out[tag] = r
            emit(f"ablation_eviction/{tag}", 0.0, r)
    # directionality check (Fig. 5): delayed beats immediate at equal window
    for window in (4, 16):
        d = out[f"win{window}_delayed"]["kl_vs_teacher"]
        i = out[f"win{window}_immediate"]["kl_vs_teacher"]
        emit(f"ablation_eviction/gap_win{window}", 0.0,
             {"kl_delayed": d, "kl_immediate": i, "immediate_worse": i > d})
    save_json("ablation_eviction", out)
    return out


if __name__ == "__main__":
    run()
