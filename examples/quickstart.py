"""Quickstart: retrofit a small LM with DMS and serve it compressed.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper end-to-end in ~2 minutes on CPU:
  1. pretrain a tiny LM,
  2. DMS-retrofit it (logit distillation, Gumbel-sigmoid relaxed eviction,
     CR schedule 1 → 4),
  3. serve with the slot-compacted cache and print the budget savings.
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_smoke
from repro.core.config import DMSConfig, KVPolicyConfig
from repro.data.pipeline import DataConfig
from repro.serving.engine import Engine
from repro.train.loop import TrainConfig, train

arch = get_smoke("qwen-r1-1.5b")
arch = dataclasses.replace(
    arch, dms=DMSConfig(enabled=True, window=8, target_cr=4.0,
                        steps_per_cr_unit=10))
data = DataConfig(vocab_size=arch.vocab_size, seq_len=64, global_batch=8)

print("== 1. pretrain (vanilla) ==")
base = dataclasses.replace(arch, dms=DMSConfig(enabled=False))
out = train(base, data, TrainConfig(total_steps=60, log_every=20),
            log_fn=lambda m: print(f"  step {m['step']:3d} ce={m['ce']:.3f}"))

print("== 2. DMS retrofit (distill from the vanilla teacher) ==")
out = train(arch, data,
            TrainConfig(total_steps=60, log_every=20, retrofit=True),
            params=out["params"],
            log_fn=lambda m: print(f"  step {m['step']:3d} "
                                   f"kd={m['loss_main']:.3f} "
                                   f"alpha={m['alpha_mean']:.2f} "
                                   f"CR(t)={m['cr_schedule']:.1f}"))

print("== 3. serve compressed vs vanilla ==")
prompts = np.random.default_rng(0).integers(
    3, arch.vocab_size, size=(2, 32)).astype(np.int32)
for label, pol in [("vanilla", KVPolicyConfig(kind="vanilla")),
                   ("dms cr4", KVPolicyConfig(kind="dms", cr=4.0, window=8))]:
    res = Engine(arch, out["params"], pol).generate(prompts, 24)
    print(f"  {label:9s} kv_reads={res.meter.kv_reads:9.0f} "
          f"peak_tokens={res.meter.peak_tokens:6.0f}")
print("done — DMS trades a little accuracy for a large KV budget cut;")
print("hyper-scaling spends that budget on more/longer chains (benchmarks/pareto.py)")
