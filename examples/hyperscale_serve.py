"""Inference-time hyper-scaling demo (paper §5.1): same compute budget,
more reasoning chains via KV compression.

    PYTHONPATH=src python examples/hyperscale_serve.py

Trains a tiny chain-arithmetic reasoner, retrofits DMS, then compares
accuracy at (roughly) matched KV-read budgets:
    vanilla  L-W-CR = 40-1-1
    DMS      L-W-CR = 40-4-4   (4 chains for the budget of ~1, majority vote)

The W=4 chains share ONE prefill: the engine forks the compressed cache
after prefilling the prompt once (KVPolicy.fork_cache), so the prefill-phase
KV reads are 4x lower than re-prefilling per chain — and the meters report
exactly that.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.pareto import _trained_reasoner
from repro.core.config import KVPolicyConfig
from repro.core.hyperscale import ScalingConfig
from repro.data import tasks
from repro.serving.engine import Engine, evaluate_hyperscale

arch, params, task, alpha = _trained_reasoner(steps=200)
print(f"retrofitted reasoner ready (alpha={alpha:.2f})")
prompts, answers = tasks.make_eval_set(task, 16)

v_engine = Engine(arch, params, KVPolicyConfig(kind="vanilla"), temperature=0.7)
d_engine = Engine(arch, params,
                  KVPolicyConfig(kind="dms", cr=arch.dms.target_cr,
                                 window=arch.dms.window), temperature=0.7)

r1 = evaluate_hyperscale(v_engine, prompts, answers,
                         ScalingConfig(task.prompt_len + 8, 1, 1.0))
r4 = evaluate_hyperscale(d_engine, prompts, answers,
                         ScalingConfig(task.prompt_len + 8, 4,
                                       arch.dms.target_cr))
print(f"vanilla 1-chain : acc={r1['accuracy']:.2f} kv_reads={r1['kv_reads']:.0f}")
print(f"DMS 4-chain     : acc={r4['accuracy']:.2f} kv_reads={r4['kv_reads']:.0f}")

res = d_engine.hyperscale_generate(prompts[0],
                                   ScalingConfig(task.prompt_len + 8, 4,
                                                 arch.dms.target_cr))
req = res.requests[0]
print(f"shared prefill  : {req.prefill_meter.kv_reads:.0f} prefill reads for "
      f"4 chains (one prefill, forked), {req.decode_meter.kv_reads:.0f} decode")
print("hyper-scaling: the compressed model affords W=4 voting chains at a "
      "comparable read budget — the paper's Figure 3 mechanism.")
