"""End-to-end training driver: pretrain a ~100M-param model for a few hundred
steps with checkpointing + auto-resume, then DMS-retrofit it.

    PYTHONPATH=src python examples/retrofit_train.py [--steps 300] [--big]

``--big`` uses a ~100M-parameter llama-family config (slower on CPU); the
default is a smaller stand-in with the identical code path.
"""
import argparse
import dataclasses
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_smoke
from repro.core.config import ArchConfig, AttentionConfig, DMSConfig, MLPConfig
from repro.data.pipeline import DataConfig
from repro.train.loop import TrainConfig, train


def build_arch(big: bool) -> ArchConfig:
    if big:   # ~100M params
        return ArchConfig(
            name="demo-100m", num_layers=8, d_model=768, vocab_size=32000,
            attn=AttentionConfig(num_heads=12, num_kv_heads=4, head_dim=64),
            mlp=MLPConfig(d_ff=2048, kind="swiglu"),
            tie_embeddings=True,
            dms=DMSConfig(enabled=True, window=32, target_cr=4.0,
                          steps_per_cr_unit=25))
    arch = get_smoke("llama32-1b")
    return dataclasses.replace(
        arch, dms=DMSConfig(enabled=True, window=8, target_cr=4.0,
                            steps_per_cr_unit=20))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    arch = build_arch(args.big)
    data = DataConfig(vocab_size=arch.vocab_size, seq_len=args.seq_len,
                      global_batch=16)
    with tempfile.TemporaryDirectory() as ckpt:
        print(f"== pretrain {arch.name} for {args.steps} steps "
              f"(ckpt+resume enabled) ==")
        base = dataclasses.replace(arch, dms=DMSConfig(enabled=False))
        out = train(base, data,
                    TrainConfig(total_steps=args.steps, log_every=25,
                                ckpt_every=100, ckpt_dir=ckpt),
                    log_fn=lambda m: print(f"  {m['step']:4d} ce={m['ce']:.3f} "
                                           f"gnorm={m['grad_norm']:.2f}"))
        print("== DMS retrofit ==")
        out2 = train(arch, data,
                     TrainConfig(total_steps=args.steps // 2, log_every=25,
                                 retrofit=True, phase1_steps=10),
                     params=out["params"],
                     log_fn=lambda m: print(
                         f"  {m['step']:4d} kd={m.get('loss_main', 0):.3f} "
                         f"alpha={m.get('alpha_mean', 0):.2f}"))
        final = out2["history"][-1]
        print(f"final: alpha={final.get('alpha_mean', 0):.2f} "
              f"(target {1 - 1/arch.dms.target_cr:.2f})")


if __name__ == "__main__":
    main()
